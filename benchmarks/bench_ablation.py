"""Paper §3.5.1 ablation: number of spilled assignments (1 vs 2 vs 3).

The paper forgoes >2 assignments: "the first spilled assignment is
generally sufficient ... the additional memory and indexing cost increases
linearly". This ablation reproduces the claim: points-to-recall improves
strongly none→soar(1 spill) and only marginally with further spills, while
index size grows linearly.
"""
from __future__ import annotations

import jax

from benchmarks.common import C, K, LAM, Timer, dataset, emit, neighbors
from repro.core import build_ivf, kmr_curve, points_to_recall


def main():
    ds, tn = dataset(), neighbors()
    prev = None
    for n_spills in (0, 1, 2, 3):
        with Timer() as t:
            mode = "none" if n_spills == 0 else "soar"
            idx = build_ivf(jax.random.PRNGKey(1), ds.X, C, spill_mode=mode,
                            lam=LAM, n_spills=max(n_spills, 1), train_iters=8)
            cv = kmr_curve(idx, ds.Q, tn, k=K)
        pts = {r: points_to_recall(cv, r) for r in (0.85, 0.95)}
        marg = ""
        if prev is not None:
            marg = (f" marginal_gain@95={prev / pts[0.95]:.3f}x")
        prev = pts[0.95]
        emit(f"ablation_spills{n_spills}", t.us,
             f"pts@85={pts[0.85]:.0f} pts@95={pts[0.95]:.0f} "
             f"assignments={idx.n_assignments}{marg}")


if __name__ == "__main__":
    main()
