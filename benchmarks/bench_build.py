"""Build + mutation pipeline benchmark — emits ``BENCH_build.json``.

Covers the three claims of the sharded build/mutation subsystem
(DESIGN.md §3.7):

1. **build throughput** — monolithic `build_ivf` vs streamed
   `build_ivf_sharded` (sample-trained codebook, O(shard) tiles), wall
   time and vectors/s;
2. **incremental-add latency** — per-batch `MutableIVF.add` (fused
   assignment against the frozen codebook + PQ encode + padded insert) at
   online (64) and bulk (1024) batch sizes, plus remove+compact latency;
3. **recall after mutation** — recall@10 of an index mutated through
   build → add → delete → compact vs a FULL REBUILD (fresh codebook) on
   the same surviving vectors. Acceptance: |Δrecall| ≤ 0.005.

A fixed-shape GEMM calibration row (`build_calib_gemm`) is emitted so the
CI regression gate (check_regression.py) can normalize latencies across
machines before applying its 25% tolerance.

    PYTHONPATH=src python -m benchmarks.bench_build [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import Timer, emit, write_rows
from repro.core import (MutableIVF, build_ivf, build_ivf_sharded, pack_ivf,
                        search_jit, true_neighbors)
from repro.data.vectors import glove_like

RECALL_TOL = 0.005


def _best_of(fn, reps: int = 5) -> float:
    best = float("inf")
    for _ in range(reps):
        with Timer() as t:
            jax.block_until_ready(fn())
        best = min(best, t.us)
    return best


def _recall(packed, Q, tn, top_t: int, budget: int, id_map=None) -> float:
    ids, _ = search_jit(packed, jnp.asarray(Q), top_t=top_t, final_k=10,
                        rerank_budget=budget)
    ids = np.asarray(ids)
    if id_map is not None:
        ids = np.where(ids >= 0, id_map[np.maximum(ids, 0)], -1)
    return float((ids[:, :, None] == tn[:, None, :10]).any(-1).mean())


def run(n: int, c: int, train_iters: int, top_t: int, budget: int,
        label: str):
    ds = glove_like(n=n, d=100, nq=min(400, max(64, n // 100)))
    X, Q = ds.X, ds.Q
    n_base = int(n * 0.9)
    base, extra = X[:n_base], X[n_base:]

    # calibration row: fixed-shape GEMM, machine-speed proxy for the gate
    A = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2048, 256)), jnp.float32)
    B = jnp.asarray(np.random.default_rng(1).standard_normal(
        (256, 2048)), jnp.float32)
    emit(f"build_calib_gemm_{label}", _best_of(lambda: A @ B),
         "2048x256x2048 f32 GEMM (gate normalization row)")

    with Timer() as t_mono:
        build_ivf(jax.random.PRNGKey(1), base, c, spill_mode="soar",
                  pq_subspaces=25, train_iters=train_iters)
    emit(f"build_monolithic_{label}", t_mono.us,
         f"n={n_base} c={c} {n_base / (t_mono.us / 1e6):.0f} vec/s")

    with Timer() as t_sh:
        idx = build_ivf_sharded(jax.random.PRNGKey(1), base, c,
                                spill_mode="soar", pq_subspaces=25,
                                train_iters=train_iters,
                                train_sample=min(n_base, 32_768),
                                shard_size=16_384)
    emit(f"build_sharded_{label}", t_sh.us,
         f"n={n_base} c={c} {n_base / (t_sh.us / 1e6):.0f} vec/s "
         f"speedup={t_mono.us / t_sh.us:.2f}x")

    # ---- incremental mutation: add 10%, delete 10%, compact ----
    mut = MutableIVF.from_index(idx)
    for b in (64, 1024):
        if extra.shape[0] < 2 * b:
            continue
        warm = mut.add(extra[:b])         # compile fused assign + encode
        mut.remove(warm)                  # at this batch's tile shapes
        mut.compact()
        with Timer() as t_add:
            ids_b = mut.add(extra[:b])
        emit(f"incremental_add_b{b}_{label}", t_add.us,
             f"{b / (t_add.us / 1e6):.0f} vec/s per-batch")
        mut.remove(ids_b)
        mut.compact()

    new_ids = mut.add(extra)
    rng = np.random.default_rng(0)
    victims = np.concatenate([
        rng.choice(n_base, n // 10, replace=False),
        rng.choice(new_ids, max(extra.shape[0] // 10, 1), replace=False)])
    with Timer() as t_rm:
        mut.remove(victims)
        mut.compact()
    emit(f"remove_compact_{label}", t_rm.us,
         f"{victims.size} removals + compaction")

    # ---- recall after mutation vs full rebuild on the survivors ----
    live = np.flatnonzero(mut.alive[:mut.n_total])
    id_map = np.full(mut.n_total, -1, np.int64)
    id_map[live] = np.arange(live.size)
    X_surv = mut.rerank[live]
    tn = true_neighbors(X_surv, Q, k=10)

    rec_mut = _recall(mut.pack(), Q, tn, top_t, budget, id_map=id_map)
    # full rebuild of the serving index on the survivors against the same
    # frozen codebook/PQ — the operational comparator (codebook retraining
    # is a separate offline event, DESIGN.md §3.7); acceptance |Δ| ≤ 0.005
    with Timer() as t_rb:
        rebuilt = mut.rebuild_reference()
    rec_rb = _recall(pack_ivf(rebuilt), Q, tn, top_t, budget)
    emit(f"recall_mutated_{label}", 0.0,
         f"recall@10={rec_mut:.4f} after add+delete+compact")
    emit(f"recall_rebuild_{label}", t_rb.us,
         f"recall@10={rec_rb:.4f} full rebuild (frozen codebook) "
         f"d_recall={rec_mut - rec_rb:+.4f}")
    # informational: a from-scratch retrain of the codebook on the
    # survivors — noisy at few Lloyd iterations, so no symmetric gate;
    # the mutated index must only never LOSE meaningful recall to it
    retrained = build_ivf_sharded(jax.random.PRNGKey(2), X_surv, c,
                                  spill_mode="soar", pq_subspaces=25,
                                  train_iters=train_iters,
                                  train_sample=min(live.size, 32_768),
                                  shard_size=16_384)
    rec_rt = _recall(pack_ivf(retrained), Q, tn, top_t, budget)
    # deliberately NOT in the gate's "recall@10=" format: few-iteration
    # retrains are noisy, so check_regression must not pin this row
    emit(f"recall_retrain_{label}", 0.0,
         f"retrain-recall {rec_rt:.4f} fresh codebook "
         f"d={rec_mut - rec_rt:+.4f} (informational, ungated)")
    assert abs(rec_mut - rec_rb) <= RECALL_TOL, (
        f"mutated recall {rec_mut:.4f} vs rebuild {rec_rb:.4f} "
        f"drifts beyond {RECALL_TOL}")
    assert rec_mut >= rec_rt - 0.02, (
        f"mutated recall {rec_mut:.4f} lost >0.02 to a fresh retrain "
        f"{rec_rt:.4f}")
    return rec_mut, rec_rb


def main(smoke: bool = False, out: str = "BENCH_build.json"):
    mark = len(common.ROWS)
    if smoke:
        run(n=10_000, c=64, train_iters=3, top_t=6, budget=256,
            label="smoke")
    else:
        run(n=100_000, c=500, train_iters=8, top_t=10, budget=300,
            label="100k")
    if out:
        write_rows(out, common.ROWS[mark:], smoke=smoke)
        print(f"# wrote {len(common.ROWS) - mark} rows to {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI shape (n=10k)")
    ap.add_argument("--out", default="BENCH_build.json",
                    help="JSON artifact path ('' to disable)")
    main(**vars(ap.parse_args()))
