"""Build + mutation pipeline benchmark — emits ``BENCH_build.json``.

Covers the claims of the build-path overhaul (DESIGN.md §3.7/§3.8):

1. **build throughput** — monolithic `build_ivf` (fused Lloyd sweeps,
   batched PQ training, one-pass residual encode) vs streamed
   `build_ivf_sharded`. The headline rows report STEADY-STATE wall time
   (a first identical build warms the jit caches; a production build
   farm reuses compiled executables); the one-time compile cost is
   emitted separately as ``build_monolithic_cold``. Per-phase rows
   (kmeans / spill_assign / pq_train / encode / csr) localize
   regressions to the responsible stage;
2. **incremental-add latency** — per-batch `MutableIVF.add` (fused
   assignment against the frozen codebook + PQ encode + padded insert) at
   online (64) and bulk (1024) batch sizes, plus remove+compact latency;
3. **add+retrieve cadence** — the kNN-memory serving loop (add a batch,
   pack, search): the delta `pack()` path vs a forced full re-pack each
   step. The delta path wins at serving scale (~2x at n=100k) where a
   full re-pack re-uploads O(index); at smoke scale the fixed per-pack
   dispatch overhead exceeds the tiny repack, so the smoke rows document
   the crossover rather than a win;
4. **recall after mutation** — recall@10 of an index mutated through
   build → add → delete → compact vs a FULL REBUILD (fresh codebook) on
   the same surviving vectors. Acceptance: |Δrecall| ≤ 0.005.

A fixed-shape GEMM calibration row (`build_calib_gemm`) is emitted so the
CI regression gate (check_regression.py) can normalize latencies across
machines before applying its tolerance.

    PYTHONPATH=src python -m benchmarks.bench_build [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import Timer, emit, write_rows
from repro.core import (MutableIVF, build_ivf, build_ivf_sharded, pack_ivf,
                        search_jit, true_neighbors)
from repro.data.vectors import glove_like

RECALL_TOL = 0.005
BUILD_PHASES = ("kmeans", "spill_assign", "pq_train", "encode", "csr",
                "rerank")


def _best_of(fn, reps: int = 5) -> float:
    best = float("inf")
    for _ in range(reps):
        with Timer() as t:
            jax.block_until_ready(fn())
        best = min(best, t.us)
    return best


def _recall(packed, Q, tn, top_t: int, budget: int, id_map=None) -> float:
    ids, _ = search_jit(packed, jnp.asarray(Q), top_t=top_t, final_k=10,
                        rerank_budget=budget)
    ids = np.asarray(ids)
    if id_map is not None:
        ids = np.where(ids >= 0, id_map[np.maximum(ids, 0)], -1)
    return float((ids[:, :, None] == tn[:, None, :10]).any(-1).mean())


def run(n: int, c: int, train_iters: int, top_t: int, budget: int,
        label: str):
    ds = glove_like(n=n, d=100, nq=min(400, max(64, n // 100)))
    X, Q = ds.X, ds.Q
    n_base = int(n * 0.9)
    base, extra = X[:n_base], X[n_base:]

    # calibration row: fixed-shape GEMM, machine-speed proxy for the gate.
    # Sampled at the start, middle and end of the run (median emitted at
    # the end): a single-point sample under bursty co-tenant load can
    # catch a quiet (or loaded) instant that misrepresents the machine
    # state the actual rows ran under, corrupting the gate normalization.
    A = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2048, 256)), jnp.float32)
    B = jnp.asarray(np.random.default_rng(1).standard_normal(
        (256, 2048)), jnp.float32)
    calib_samples = [_best_of(lambda: A @ B)]

    def mono():
        tm = {}
        with Timer() as t:
            idx = build_ivf(jax.random.PRNGKey(1), base, c, spill_mode="soar",
                            pq_subspaces=25, train_iters=train_iters,
                            timings=tm)
        return idx, t.us, tm

    _, cold_us, _ = mono()                      # jit-cache warmup pass
    emit(f"build_monolithic_cold_{label}", cold_us,
         f"n={n_base} c={c} first build incl. one-time jit compiles")
    mono_idx, mono_us, mono_tm = mono()
    emit(f"build_monolithic_{label}", mono_us,
         f"n={n_base} c={c} {n_base / (mono_us / 1e6):.0f} vec/s "
         f"(steady-state)")
    for ph in BUILD_PHASES:
        emit(f"build_phase_{ph}_{label}", mono_tm.get(ph, 0.0) * 1e6,
             f"monolithic {ph} phase")
    tn_base = true_neighbors(base, Q, k=10)
    rec_build = _recall(pack_ivf(mono_idx), Q, tn_base, top_t, budget)
    emit(f"recall_build_{label}", 0.0,
         f"recall@10={rec_build:.4f} fresh default-flag monolithic build")
    del mono_idx

    def sharded():
        with Timer() as t:
            idx = build_ivf_sharded(jax.random.PRNGKey(1), base, c,
                                    spill_mode="soar", pq_subspaces=25,
                                    train_iters=train_iters,
                                    train_sample=min(n_base, 32_768),
                                    shard_size=16_384)
        return idx, t.us

    sharded()                                   # warmup (shard-tile shapes)
    idx, sh_us = sharded()
    emit(f"build_sharded_{label}", sh_us,
         f"n={n_base} c={c} {n_base / (sh_us / 1e6):.0f} vec/s "
         f"speedup={mono_us / sh_us:.2f}x")

    # ---- incremental mutation: add 10%, delete 10%, compact ----
    mut = MutableIVF.from_index(idx)
    for b in (64, 1024):
        if extra.shape[0] < 2 * b:
            continue
        warm = mut.add(extra[:b])         # compile fused assign + encode
        mut.remove(warm)                  # at this batch's tile shapes
        mut.compact()
        best = float("inf")
        for _ in range(3):                # best-of: ms-scale rows are
            with Timer() as t_add:        # contention-spike prone
                ids_b = mut.add(extra[:b])
            best = min(best, t_add.us)
            mut.remove(ids_b)
            mut.compact()
        emit(f"incremental_add_b{b}_{label}", best,
             f"{b / (best / 1e6):.0f} vec/s per-batch (best of 3)")

    # ---- add+retrieve cadence: delta pack vs full re-pack each step ----
    steps = 8
    qcad = jnp.asarray(Q[:32])
    kw = dict(top_t=top_t, final_k=10, rerank_budget=budget)

    def cadence(full_repack: bool) -> float:
        # like-for-like state: every run starts compacted with a freshly
        # seeded snapshot, so accumulated tombstones from a previous run
        # can't bias the comparison (and capacity growth can't silently
        # turn a delta step into a timed full repack)
        mut.compact()
        mut.pack()
        t_total = 0.0
        for i in range(steps):
            lo = (i + 2) * 64
            batch = extra[lo:lo + 64]
            with Timer() as t:
                ids_s = mut.add(batch)
                if full_repack:
                    mut.invalidate_snapshots()
                jax.block_until_ready(search_jit(mut.pack(), qcad, **kw))
            t_total += t.us
            mut.remove(ids_s)
        return t_total / steps

    cadence(True)                         # warm both pack/search programs
    cadence(False)
    full_us = min(cadence(True), cadence(True))
    delta_us = min(cadence(False), cadence(False))
    emit(f"cadence_add_search_fullpack_{label}", full_us,
         f"64-row add + full re-pack + search, per step")
    emit(f"cadence_add_search_delta_{label}", delta_us,
         f"64-row add + delta pack + search, per step "
         f"speedup={full_us / max(delta_us, 1e-9):.2f}x")

    calib_samples.append(_best_of(lambda: A @ B))      # mid-run sample

    new_ids = mut.add(extra)
    rng = np.random.default_rng(0)
    victims = np.concatenate([
        rng.choice(n_base, n // 10, replace=False),
        rng.choice(new_ids, max(extra.shape[0] // 10, 1), replace=False)])
    with Timer() as t_rm:
        mut.remove(victims)
        mut.compact()
    emit(f"remove_compact_{label}", t_rm.us,
         f"{victims.size} removals + compaction")

    # ---- durability: snapshot save throughput + reopen-to-first-query ----
    import os
    import shutil
    import tempfile

    from repro.ckpt.index_store import load_snapshot, save_snapshot
    snap_dir = tempfile.mkdtemp(prefix="bench_snap_")
    try:
        sp = os.path.join(snap_dir, "index")
        best_save = float("inf")
        for _ in range(3):
            with Timer() as t_sv:
                save_snapshot(sp, mut)
            best_save = min(best_save, t_sv.us)
        nbytes = sum(os.path.getsize(os.path.join(sp, f))
                     for f in os.listdir(sp))
        emit(f"snapshot_save_{label}", best_save,
             f"{nbytes / 1e6:.1f} MB atomic snapshot, "
             f"{nbytes / best_save:.0f} MB/s (fsync + checksum included)")
        qf = jnp.asarray(Q[:32])
        jax.block_until_ready(search_jit(mut.pack(), qf, **kw))  # warm jit
        best_ro = float("inf")
        for _ in range(3):
            with Timer() as t_ro:
                idx2, _ = load_snapshot(sp)
                jax.block_until_ready(search_jit(idx2.pack(), qf, **kw))
            best_ro = min(best_ro, t_ro.us)
        emit(f"snapshot_reopen_{label}", best_ro,
             "integrity-checked load + pack + first query (warm jit)")
    finally:
        shutil.rmtree(snap_dir)

    # ---- recall after mutation vs full rebuild on the survivors ----
    live = np.flatnonzero(mut.alive[:mut.n_total])
    id_map = np.full(mut.n_total, -1, np.int64)
    id_map[live] = np.arange(live.size)
    X_surv = mut.rerank[live]
    tn = true_neighbors(X_surv, Q, k=10)

    rec_mut = _recall(mut.pack(), Q, tn, top_t, budget, id_map=id_map)
    # full rebuild of the serving index on the survivors against the same
    # frozen codebook/PQ — the operational comparator (codebook retraining
    # is a separate offline event, DESIGN.md §3.7); acceptance |Δ| ≤ 0.005
    with Timer() as t_rb:
        rebuilt = mut.rebuild_reference()
    rec_rb = _recall(pack_ivf(rebuilt), Q, tn, top_t, budget)
    emit(f"recall_mutated_{label}", 0.0,
         f"recall@10={rec_mut:.4f} after add+delete+compact")
    emit(f"recall_rebuild_{label}", t_rb.us,
         f"recall@10={rec_rb:.4f} full rebuild (frozen codebook) "
         f"d_recall={rec_mut - rec_rb:+.4f}")
    # informational: a from-scratch retrain of the codebook on the
    # survivors — noisy at few Lloyd iterations, so no symmetric gate;
    # the mutated index must only never LOSE meaningful recall to it
    retrained = build_ivf_sharded(jax.random.PRNGKey(2), X_surv, c,
                                  spill_mode="soar", pq_subspaces=25,
                                  train_iters=train_iters,
                                  train_sample=min(live.size, 32_768),
                                  shard_size=16_384)
    rec_rt = _recall(pack_ivf(retrained), Q, tn, top_t, budget)
    # deliberately NOT in the gate's "recall@10=" format: few-iteration
    # retrains are noisy, so check_regression must not pin this row
    emit(f"recall_retrain_{label}", 0.0,
         f"retrain-recall {rec_rt:.4f} fresh codebook "
         f"d={rec_mut - rec_rt:+.4f} (informational, ungated)")
    calib_samples.append(_best_of(lambda: A @ B))      # end-of-run sample
    emit(f"build_calib_gemm_{label}", sorted(calib_samples)[1],
         "2048x256x2048 f32 GEMM (gate normalization row; median of "
         "start/mid/end samples)")
    assert abs(rec_mut - rec_rb) <= RECALL_TOL, (
        f"mutated recall {rec_mut:.4f} vs rebuild {rec_rb:.4f} "
        f"drifts beyond {RECALL_TOL}")
    assert rec_mut >= rec_rt - 0.02, (
        f"mutated recall {rec_mut:.4f} lost >0.02 to a fresh retrain "
        f"{rec_rt:.4f}")
    return rec_mut, rec_rb


def main(smoke: bool = False, out: str = "BENCH_build.json"):
    mark = len(common.ROWS)
    if smoke:
        run(n=10_000, c=64, train_iters=3, top_t=6, budget=256,
            label="smoke")
    else:
        run(n=100_000, c=500, train_iters=8, top_t=10, budget=300,
            label="100k")
    if out:
        write_rows(out, common.ROWS[mark:], smoke=smoke)
        print(f"# wrote {len(common.ROWS) - mark} rows to {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI shape (n=10k)")
    ap.add_argument("--out", default="BENCH_build.json",
                    help="JSON artifact path ('' to disable)")
    main(**vars(ap.parse_args()))
