"""Paper Figures 1, 2, 4, 7, 8: the mechanism-level statistics.

- Fig 1: mean <q,r> rises with primary-centroid RANK (search difficulty).
- Fig 2: cos(theta) correlates with <q,r> far more than ||r|| does.
- Fig 4 vs 7: cos-angle correlation, naive spill vs SOAR spill.
- Fig 8: spilled-centroid rank conditioned on primary rank.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, dataset, emit, index, neighbors
from repro.core.analysis import (angle_correlation, mean_qr_by_rank,
                                 pair_stats, pearson, score_error_correlation)
from repro.core.kmr import rank_statistics


def main():
    ds, tn = dataset(), neighbors()
    idx_naive = index("naive")
    idx_soar = index("soar")

    with Timer() as t:
        st_naive = pair_stats(ds.X, idx_naive.centroids,
                              idx_naive.assignments, ds.Q, tn)
        st_soar = pair_stats(ds.X, idx_soar.centroids,
                             idx_soar.assignments, ds.Q, tn)
    # Fig 2
    emit("fig2_corr_qr_costheta", t.us, f"{pearson(st_soar.qr, st_soar.cos1):.3f}")
    emit("fig2_corr_qr_rnorm", 0.0, f"{pearson(st_soar.qr, st_soar.rnorm):.3f}")
    # Fig 4 vs 7
    emit("fig4_angle_corr_naive", 0.0, f"{angle_correlation(st_naive):.3f}")
    emit("fig7_angle_corr_soar", 0.0, f"{angle_correlation(st_soar):.3f}")
    emit("score_err_corr_naive", 0.0, f"{score_error_correlation(st_naive):.3f}")
    emit("score_err_corr_soar", 0.0, f"{score_error_correlation(st_soar):.3f}")
    # Fig 1
    ranks, means = mean_qr_by_rank(ds.X, idx_soar.centroids,
                                   idx_soar.assignments, ds.Q, tn)
    lo, hi = means[0], means[-1]
    emit("fig1_mean_qr_low_rank", 0.0, f"{lo:.4f}")
    emit("fig1_mean_qr_high_rank", 0.0, f"{hi:.4f}")
    # Fig 8: mean spilled rank for hard pairs (primary rank >= 20)
    for name, idx in (("naive", idx_naive), ("soar", idx_soar)):
        pr, sr = rank_statistics(idx, ds.Q, tn)
        pr, sr = pr.reshape(-1), sr.reshape(-1)
        hard = pr >= 20
        if hard.sum():
            emit(f"fig8_spill_rank_hard_{name}", 0.0,
                 f"{float(np.median(sr[hard])):.1f}")
            emit(f"fig8_effective_rank_hard_{name}", 0.0,
                 f"{float(np.median(np.minimum(pr, sr)[hard])):.1f}")


if __name__ == "__main__":
    main()
