"""Paper Table 2 / Figure 6: datapoints read to reach recall targets,
for No-Spilling / Spilling-no-SOAR / SOAR."""
from __future__ import annotations

from benchmarks.common import K, Timer, dataset, emit, index, neighbors
from repro.core import kmr_curve, points_to_recall


def main():
    ds, tn = dataset(), neighbors()
    curves = {}
    for mode in ("none", "naive", "soar"):
        with Timer() as t:
            idx = index(mode)
            curves[mode] = kmr_curve(idx, ds.Q, tn, k=K, name=mode)
        emit(f"kmr_build_{mode}", t.us, f"n_assign={idx.n_assignments}")
    for target in (0.80, 0.85, 0.90, 0.95):
        pts = {m: points_to_recall(c, target) for m, c in curves.items()}
        gain = pts["none"] / pts["soar"]
        emit(f"kmr_points_r{int(target*100)}_none", 0.0, f"{pts['none']:.0f}")
        emit(f"kmr_points_r{int(target*100)}_naive", 0.0, f"{pts['naive']:.0f}")
        emit(f"kmr_points_r{int(target*100)}_soar", 0.0, f"{pts['soar']:.0f}")
        emit(f"kmr_gain_r{int(target*100)}", 0.0, f"{gain:.3f}x")


if __name__ == "__main__":
    main()
