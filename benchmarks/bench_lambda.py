"""Paper Figure 9: lambda sweep — VQ distortion E||r'||^2 rises with lambda
while the quantized-score-error correlation rho falls."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import Timer, dataset, emit, index, neighbors
from repro.core.analysis import pair_stats, score_error_correlation


def main():
    ds, tn = dataset(), neighbors()
    for lam in (0.0, 0.5, 1.0, 1.5, 2.0, 4.0):
        with Timer() as t:
            idx = index("soar", lam=lam)
            st = pair_stats(ds.X, idx.centroids, idx.assignments, ds.Q, tn)
            r2 = float(jnp.mean(jnp.asarray(st.r2norm) ** 2))
            rho = score_error_correlation(st)
        emit(f"fig9_lam{lam}_distortion", t.us, f"{r2:.4f}")
        emit(f"fig9_lam{lam}_rho", 0.0, f"{rho:.3f}")


if __name__ == "__main__":
    main()
