"""Paper Table 1 + §3.5 analytic memory model.

Validates measured index growth against the paper's closed forms
(+1/(2s+1) for int8 rerank data, +1/(8s+1) for float32), and reproduces the
paper's Table 1 relative-growth numbers analytically for the real datasets'
dimensions/configs.
"""
from __future__ import annotations

from benchmarks.common import D, N, Timer, emit, index


def main():
    with Timer() as t:
        m_none = index("none", pq=25).memory_bytes(rerank="f32")
        m_soar = index("soar", pq=25).memory_bytes(rerank="f32")
    s = D // 25
    growth = (m_soar["total"] - m_none["total"]) / m_none["total"]
    emit("table1_bench_f32_growth", t.us,
         f"{growth*100:.1f}% (analytic {100/(8*s+1):.1f}%)")
    m_none8 = index("none", pq=25).memory_bytes(rerank="int8")
    m_soar8 = index("soar", pq=25).memory_bytes(rerank="int8")
    growth8 = (m_soar8["total"] - m_none8["total"]) / m_none8["total"]
    emit("table1_bench_int8_growth", 0.0,
         f"{growth8*100:.1f}% (analytic {100/(2*s+1):.1f}%)")

    # paper configs, analytic: Glove d=100, s=2, f32  → ~5.9% (paper: 7.7%)
    #                          SPACEV/Turing d=100, s=2, int8 → ~20% (16.8/17.3%)
    for name, d, s_sub, rer, paper in (
            ("glove1m", 100, 2, "f32", 7.7),
            ("spacev", 100, 2, "int8", 17.3),
            ("turing", 100, 2, "int8", 16.8)):
        per_assign = 4 + d / (2 * s_sub)
        base = {"f32": 4 * d, "int8": d + 4}[rer] + per_assign
        growth_pct = per_assign / base * 100
        emit(f"table1_analytic_{name}", 0.0,
             f"{growth_pct:.1f}% (paper measured {paper}%)")


if __name__ == "__main__":
    main()
