"""Closed-loop serving QPS + latency percentiles through AnnEngine.

Rewritten for the current serving surface (the seed-era version called
search_numpy on a bare IVFIndex; serving has since become
serve/engine.AnnEngine over a MutableIVF with the jit batched pipeline,
bucket-padded queries, and a pluggable probe router — DESIGN.md §3.7/§3.10).

Measures what a serving operator actually sees: closed-loop single-stream
throughput (next request issues when the previous returns) and per-call
p50/p95/p99 latency, per batch size, flat vs tree-routed probe.

Hardware caveat (DESIGN.md §3): 1-core CPU container — ABSOLUTE numbers are
a proxy; the flat-vs-tree and batch-scaling ratios are the portable signal.

    PYTHONPATH=src python -m benchmarks.bench_qps [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import Timer, emit
from repro.core import true_neighbors
from repro.data.vectors import glove_like
from repro.serve.engine import AnnEngine


def recall_at(ids, tn, k=10):
    return float((ids[:, :k, None] == tn[:, None, :k]).any(-1).mean())


def _closed_loop(eng: AnnEngine, Q: np.ndarray, batch: int, reps: int):
    """Closed-loop drive: issue `reps` batched requests back-to-back,
    rotating through the query set. Returns (lat_us list, ids of the
    last call)."""
    nq = Q.shape[0]
    lat, ids = [], None
    for i in range(reps):
        off = (i * batch) % max(1, nq - batch + 1)
        qb = Q[off:off + batch]
        with Timer() as t:
            ids, _ = eng.search(qb, k=10)
        lat.append(t.us)
    return lat, ids


def run(n: int, c: int, nq: int, train_iters: int, reps: int, label: str,
        batches=(1, 16, 128)):
    ds = glove_like(n=n, d=100, nq=nq)
    tn = true_neighbors(ds.X, ds.Q, k=10)
    for router, rkw, tag in ((None, None, "flat"),
                             ("tree", dict(t_route=2), "tree")):
        eng = AnnEngine.build(jax.random.PRNGKey(0), ds.X, c,
                              spill_mode="soar", pq_subspaces=25,
                              top_t=max(6, round(c / 200)),
                              rerank_budget=300, router=router,
                              router_kw=rkw, train_iters=train_iters)
        full_ids, _ = eng.search(ds.Q, k=10)          # quality + warmup
        rec = recall_at(full_ids, tn)
        for b in batches:
            _closed_loop(eng, ds.Q, b, 2)             # compile this bucket
            lat, _ = _closed_loop(eng, ds.Q, b, reps)
            qps = b * len(lat) / (sum(lat) / 1e6)
            p50, p95, p99 = np.percentile(lat, [50, 95, 99])
            emit(f"qps_engine_{tag}_b{b}_{label}", p50 / b,
                 f"recall@10={rec:.3f} qps={qps:.0f} p50={p50:.0f}us "
                 f"p95={p95:.0f}us p99={p99:.0f}us batch={b}")


def main(smoke: bool = False, out: str = ""):
    from benchmarks import common
    mark = len(common.ROWS)
    if smoke:
        run(n=10_000, c=64, nq=160, train_iters=3, reps=15, label="smoke")
    else:
        run(n=100_000, c=500, nq=400, train_iters=8, reps=60, label="100k")
    if out:
        from benchmarks.common import write_rows
        write_rows(out, common.ROWS[mark:], smoke=smoke)
        print(f"# wrote {len(common.ROWS) - mark} rows to {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down shape (n=10k)")
    ap.add_argument("--out", default="",
                    help="standalone JSON artifact path")
    main(**vars(ap.parse_args()))
