"""Paper Figures 11/12: recall–throughput tradeoff (CPU proxy).

Hardware caveat (DESIGN.md §3): the paper's QPS numbers come from AVX2 LUT16
kernels on Xeon; this container measures the host-orchestrated numpy engine
on 1 core, so ABSOLUTE throughput is not comparable — the figures here
establish (a) the recall/points-read tradeoff shape and (b) SOAR vs
no-spill at matched recall, which are hardware-independent. The TPU-target
kernels are exercised via tests (interpret mode) and the dry-run.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import K, Timer, dataset, emit, index, neighbors
from repro.core import search_numpy


def recall_at(ids, tn, k=10):
    return float((ids[:, :k, None] == tn[:, None, :k]).any(-1).mean())


def main():
    ds, tn = dataset(), neighbors()
    for mode in ("none", "soar"):
        idx = index(mode, pq=25)
        for top_t in (2, 5, 10, 20, 40):
            t0 = time.perf_counter()
            ids, stats = search_numpy(idx, ds.Q, top_t=top_t, final_k=10,
                                      rerank_budget=300)
            dt = time.perf_counter() - t0
            qps = len(ds.Q) / dt
            r = recall_at(ids, tn, k=10)
            emit(f"qps_{mode}_t{top_t}", dt / len(ds.Q) * 1e6,
                 f"recall@10={r:.3f} qps={qps:.0f} "
                 f"pts={stats.points_read.mean():.0f}")


if __name__ == "__main__":
    main()
