"""Multi-client serving benchmark: front-end dynamic batching vs direct
engine calls under mixed traffic (ISSUE 8, DESIGN.md §3.12).

Drives N concurrent closed-loop clients — a mix of unfiltered and
tenant-filtered traffic, plus a mutator interleaving adds and soft
removes — against the SAME AnnEngine two ways:

- **direct**: every client calls `engine.search` itself, serialized by a
  lock (the engine is a single-caller edge; this is what an operator
  without the front-end deploys). Each single-query call pays a full
  padded bucket-8 jit dispatch, and tenant filtering pays a host
  compose + device upload per call.
- **frontend**: clients go through ServingFrontend. Concurrent
  singletons coalesce into one padded call (~Nx less compute at the
  same bucket), and tenant filters are served from the epoch-cached
  device bitmap.

Reported per mode/client-count: p50/p95/p99 request latency, raw QPS,
and QPS-at-SLO (goodput: only requests finishing within SLO_MS count).
The acceptance gate of ISSUE 8 is asserted inline at >=8 clients:
frontend throughput must exceed direct at equal-or-better p99. A
determinism sanity check (coalesced == solo, bitwise) runs before the
timed phases.

Hardware caveat (DESIGN.md §3): 1-core CPU container — ABSOLUTE numbers
are a proxy; the frontend-vs-direct ratios are the portable signal. A
fixed-shape GEMM calibration row (`qps_calib_gemm_*`) lets the CI gate
normalize across machines.

**Overload scenario** (`--overload`, ISSUE 9 / DESIGN.md §3.13): instead
of the direct-vs-frontend comparison, measure serving under admission
control. Phase 1 establishes goodput capacity (8 closed-loop clients,
explicit `deadline_ms=SLO`); phase 2 offers ≥4x that rate open-loop at a
bounded queue (`max_queue`, shed-oldest) and reports
``goodput_ratio`` (overload goodput / capacity goodput — the load-
shedding acceptance metric, asserted >= 0.8 inline), ``shed_rate``, and
the p99 of ADMITTED requests (the row value — deadline enforcement must
keep it bounded even at 4x offered load). Every Future is awaited: a
hung Future fails the run. The resilience counters
(shed/expired/retries/degraded) are also appended to the regular
frontend rows, so BENCH_qps.json records them per run.

    PYTHONPATH=src python -m benchmarks.bench_qps [--smoke] [--overload]
        [--out PATH]
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core import true_neighbors
from repro.data.vectors import glove_like
from repro.serve.api import (DeadlineExceededError, OverloadedError,
                             SearchParams, ServingError)
from repro.serve.engine import AnnEngine
from repro.serve.frontend import ServingFrontend

SLO_MS = 50.0        # per-request latency objective for the goodput metric
K = 10


def recall_at(ids, tn, k=K):
    return float((ids[:, :k, None] == tn[:, None, :k]).any(-1).mean())


def _best_of(fn, n=3):
    out = []
    for _ in range(n):
        with Timer() as t:
            jax.block_until_ready(fn())
        out.append(t.us)
    return min(out)


def _percentiles(lat_us):
    p50, p95, p99 = np.percentile(lat_us, [50, 95, 99])
    return float(p50), float(p95), float(p99)


def _mixed_traffic(search_one, mutate, n_clients: int, reps: int,
                   Q: np.ndarray):
    """Closed-loop drive: `n_clients` threads each issue `reps`
    single-query requests (odd-numbered clients under a tenant filter),
    while a mutator thread interleaves an add and soft removes. Returns
    (per-request latencies us, wall seconds)."""
    lat = [[] for _ in range(n_clients)]
    nq = Q.shape[0]
    stop = threading.Event()

    def client(cid):
        tenant = cid % 2 == 1
        for i in range(reps):
            q = Q[(cid * reps + i) % nq][None]
            t0 = time.perf_counter()
            search_one(q, tenant)
            lat[cid].append((time.perf_counter() - t0) * 1e6)

    def mutator():
        j = 0
        while not stop.is_set():
            mutate(j)
            j += 1
            if stop.wait(0.05):
                return

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    mt = threading.Thread(target=mutator)
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    mt.start()
    for t in threads:
        t.join()
    stop.set()
    mt.join()
    wall = time.perf_counter() - t0
    return np.concatenate(lat), wall


def _report(name: str, lat_us: np.ndarray, wall_s: float, extra: str = ""):
    p50, p95, p99 = _percentiles(lat_us)
    qps = len(lat_us) / wall_s
    good = int((lat_us <= SLO_MS * 1e3).sum()) / wall_s
    emit(name, p50,
         f"qps={qps:.0f} qps@slo{SLO_MS:.0f}ms={good:.0f} "
         f"p50={p50:.0f}us p95={p95:.0f}us p99={p99:.0f}us{extra}")
    return qps, good, p99


def run(n: int, c: int, nq: int, train_iters: int, reps: int, label: str,
        client_counts=(1, 8)):
    ds = glove_like(n=n, d=100, nq=nq)
    tn = true_neighbors(ds.X, ds.Q, k=K)
    eng = AnnEngine.build(jax.random.PRNGKey(0), ds.X, c,
                          spill_mode="soar", pq_subspaces=25,
                          top_t=max(6, round(c / 200)), rerank_budget=300,
                          train_iters=train_iters)
    tenant_ids = np.arange(0, n, 2)
    tenant_mask = np.zeros(n, np.uint8)
    tenant_mask[tenant_ids] = 1

    # calibration row: fixed-shape GEMM, machine-speed proxy for the gate
    # (median of start/mid/end samples — see bench_build.py)
    A = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2048, 256)), jnp.float32)
    B = jnp.asarray(np.random.default_rng(1).standard_normal(
        (256, 2048)), jnp.float32)
    calib = [_best_of(lambda: A @ B)]

    # quality + warmup: full batch, singleton bucket, tenant filter
    full_ids, _ = eng.search(ds.Q, k=K)
    rec = recall_at(full_ids, tn)
    eng.search(ds.Q[:1], k=K)
    eng.search(ds.Q[:1], k=K, filter_mask=tenant_mask)
    with Timer() as t_full:
        eng.search(ds.Q, k=K)
    emit(f"qps_serve_full_{label}", t_full.us / ds.Q.shape[0],
         f"recall@10={rec:.3f} full-batch engine reference "
         f"({ds.Q.shape[0]} queries/call)")

    lock = threading.Lock()

    def direct_search(q, tenant):
        with lock:
            if tenant:
                eng.search(q, k=K, filter_mask=tenant_mask)
            else:
                eng.search(q, k=K)

    def direct_mutate(j):
        with lock:
            if j % 4 == 3:
                eng.add(ds.X[:8] + np.float32(0.01 * j))
            else:
                eng.remove([(7 * j) % n], hard=False)

    for n_clients in client_counts:
        lat_d, wall_d = _mixed_traffic(direct_search, direct_mutate,
                                       n_clients, reps, ds.Q)
        qps_d, good_d, p99_d = _report(
            f"qps_direct_c{n_clients}_{label}", lat_d, wall_d,
            f" clients={n_clients}")

        fe = ServingFrontend(eng, policy="local",
                             max_batch=max(n_clients, 2),
                             default_deadline_ms=SLO_MS)
        fe.register_tenant("t", mask=tenant_mask.astype(bool))
        # determinism sanity: a coalesced front-end answer is bitwise the
        # solo engine answer at the same epoch
        futs = [fe.submit(ds.Q[i:i + 1], SearchParams(k=K))
                for i in range(4)]
        got = np.concatenate([f.result().ids for f in futs])
        ref, _ = eng.search(ds.Q[:4], k=K)
        assert np.array_equal(got, ref), "coalesced != solo (determinism)"
        fe.search(ds.Q[:1], SearchParams(k=K, tenant="t"))   # warm tenant

        # best-effort traffic (no explicit deadline): pacing comes from
        # default_deadline_ms, and the comparison with direct stays
        # apples-to-apples (nothing shed). Enforcement is exercised by
        # the --overload scenario. ServingError is counted, not raised —
        # a shed request must not kill a bench client thread.
        errs = [0]

        def fe_search(q, tenant, fe=fe):
            try:
                fe.search(q, SearchParams(
                    k=K, tenant="t" if tenant else None))
            except ServingError:
                errs[0] += 1

        def fe_mutate(j, fe=fe):
            if j % 4 == 3:
                fe.add(ds.X[:8] + np.float32(0.01 * j))
            else:
                fe.remove([(7 * j) % n], hard=False)

        lat_f, wall_f = _mixed_traffic(fe_search, fe_mutate,
                                       n_clients, reps, ds.Q)
        stats = dict(fe.stats)
        fe.close()
        gain = (len(lat_f) / wall_f) / max(len(lat_d) / wall_d, 1e-9)
        qps_f, good_f, p99_f = _report(
            f"qps_frontend_c{n_clients}_{label}", lat_f, wall_f,
            f" clients={n_clients} gain={gain:.2f}x "
            f"coalesced={stats['coalesced']}/{stats['requests']} "
            f"shed={stats['shed']} expired={stats['expired']} "
            f"retries={stats['retries']} degraded={stats['degraded']} "
            f"errs={errs[0]}")
        if n_clients >= 8:
            # ISSUE 8 acceptance: batching beats direct dispatch at >=8
            # concurrent clients WITHOUT giving up tail latency
            assert qps_f > qps_d, (
                f"frontend qps {qps_f:.0f} <= direct {qps_d:.0f} "
                f"at {n_clients} clients")
            assert p99_f <= p99_d, (
                f"frontend p99 {p99_f:.0f}us worse than direct "
                f"{p99_d:.0f}us at {n_clients} clients")
        calib.append(_best_of(lambda: A @ B))

    emit(f"qps_calib_gemm_{label}", sorted(calib)[len(calib) // 2],
         "2048x256x2048 f32 GEMM (gate normalization row; median of "
         "per-phase samples)")


def run_overload(n: int, c: int, nq: int, train_iters: int, label: str,
                 n_clients: int = 8, reps_base: int = 40,
                 reps_over: int = 80, factor: float = 4.0):
    """Serving under admission control (ISSUE 9, DESIGN.md §3.13)."""
    ds = glove_like(n=n, d=100, nq=nq)
    eng = AnnEngine.build(jax.random.PRNGKey(0), ds.X, c,
                          spill_mode="soar", pq_subspaces=25,
                          top_t=max(6, round(c / 200)), rerank_budget=300,
                          train_iters=train_iters)
    A = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2048, 256)), jnp.float32)
    B = jnp.asarray(np.random.default_rng(1).standard_normal(
        (256, 2048)), jnp.float32)
    calib = [_best_of(lambda: A @ B)]
    Q = ds.Q

    def warm(fe):
        for s in (1, 2, 4, 8):       # every bucket coalescing can hit
            fe.search(Q[:s], SearchParams(k=K))

    # ---- phase 1: goodput capacity (closed loop, explicit SLO deadline)
    fe = ServingFrontend(eng, policy="local", max_batch=n_clients,
                         default_deadline_ms=SLO_MS)
    warm(fe)
    lat_ok = [[] for _ in range(n_clients)]
    miss = [0] * n_clients

    def closed_client(cid):
        for i in range(reps_base):
            q = Q[(cid * reps_base + i) % nq][None]
            t0 = time.perf_counter()
            try:
                fe.search(q, SearchParams(k=K, deadline_ms=SLO_MS))
            except ServingError:
                miss[cid] += 1
                continue
            lat_ok[cid].append((time.perf_counter() - t0) * 1e6)

    threads = [threading.Thread(target=closed_client, args=(cid,))
               for cid in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_base = time.perf_counter() - t0
    fe.close()
    base = np.concatenate([np.asarray(x) for x in lat_ok])
    assert base.size, "capacity phase served nothing"
    good_base = int((base <= SLO_MS * 1e3).sum()) / wall_base
    qps_base = (base.size + sum(miss)) / wall_base
    p99_base = float(np.percentile(base, 99))
    emit(f"qps_overload_base_{label}", p99_base,
         f"goodput={good_base:.0f}/s qps={qps_base:.0f} "
         f"clients={n_clients} closed-loop deadline={SLO_MS:.0f}ms "
         f"missed={sum(miss)} (value = p99 us)")

    # ---- phase 2: open loop at `factor` x capacity, bounded queue.
    # Size the queue so its drain time at measured capacity stays under
    # HALF the SLO — admission control only preserves goodput if what it
    # admits can still finish inside the budget (queue delay + one
    # dispatch < SLO). An oversized queue admits requests that complete
    # successfully but too late to count.
    max_queue = max(n_clients,
                    min(4 * n_clients,
                        int(qps_base * SLO_MS * 1e-3 / 2)))
    fe = ServingFrontend(eng, policy="local", max_batch=n_clients,
                         default_deadline_ms=SLO_MS,
                         max_queue=max_queue, overload="shed-oldest")
    warm(fe)
    offered_qps = factor * max(qps_base, 1.0)
    interval = n_clients / offered_qps      # per-thread inter-arrival
    done: list = []                         # list.append is GIL-atomic
    rejected = [0] * n_clients

    def open_client(cid):
        next_at = time.perf_counter()
        for i in range(reps_over):
            now = time.perf_counter()
            if now < next_at:
                time.sleep(next_at - now)
            next_at += interval
            t0 = time.perf_counter()
            try:
                f = fe.submit(Q[(cid * reps_over + i) % nq][None],
                              SearchParams(k=K, deadline_ms=SLO_MS))
            except OverloadedError:
                rejected[cid] += 1
                continue
            # stamp completion at callback time, not at join time
            f.add_done_callback(lambda fut, t0=t0: done.append(
                (t0, time.perf_counter(), fut)))

    threads = [threading.Thread(target=open_client, args=(cid,))
               for cid in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fe.flush()
    wall_over = time.perf_counter() - t0
    stats = dict(fe.stats)
    fe.close()

    adm, n_shed, n_exp = [], 0, 0
    for ts, td, fut in done:
        exc = fut.exception(timeout=60)     # zero hung Futures, enforced
        if exc is None:
            adm.append((td - ts) * 1e6)
        elif isinstance(exc, OverloadedError):
            n_shed += 1
        elif isinstance(exc, DeadlineExceededError):
            n_exp += 1
        else:
            raise AssertionError(f"unexpected failure: {exc!r}")
    adm = np.asarray(adm)
    n_rej = sum(rejected)
    offered = len(done) + n_rej
    assert offered == n_clients * reps_over, "lost track of a request"
    good_over = (int((adm <= SLO_MS * 1e3).sum()) / wall_over
                 if adm.size else 0.0)
    ratio = good_over / max(good_base, 1e-9)
    shed_rate = (n_shed + n_exp + n_rej) / max(offered, 1)
    p99_adm = float(np.percentile(adm, 99)) if adm.size else 0.0
    emit(f"qps_overload_{factor:.0f}x_{label}", p99_adm,
         f"goodput_ratio={ratio:.2f} shed_rate={shed_rate:.2f} "
         f"goodput={good_over:.0f}/s offered={offered / wall_over:.0f}/s "
         f"ok={adm.size} shed={n_shed} expired={n_exp} rejected={n_rej} "
         f"retries={stats['retries']} degraded={stats['degraded']} "
         f"(value = p99 of admitted, us)")
    # ISSUE 9 acceptance: shedding keeps goodput near capacity and
    # deadline enforcement keeps the admitted tail bounded
    assert ratio >= 0.8, (
        f"overload goodput {good_over:.0f}/s < 0.8x capacity "
        f"{good_base:.0f}/s (ratio {ratio:.2f})")
    assert p99_adm <= 4 * SLO_MS * 1e3, (
        f"admitted p99 {p99_adm:.0f}us unbounded under overload")
    calib.append(_best_of(lambda: A @ B))
    emit(f"qps_calib_gemm_overload_{label}", sorted(calib)[len(calib) // 2],
         "2048x256x2048 f32 GEMM (gate normalization row)")


def main(smoke: bool = False, overload: bool = False, out: str = ""):
    from benchmarks import common
    mark = len(common.ROWS)
    if overload:
        if smoke:
            run_overload(n=10_000, c=64, nq=160, train_iters=3,
                         label="smoke")
        else:
            run_overload(n=100_000, c=500, nq=400, train_iters=8,
                         label="100k", reps_base=80, reps_over=160)
    elif smoke:
        run(n=10_000, c=64, nq=160, train_iters=3, reps=20, label="smoke")
    else:
        run(n=100_000, c=500, nq=400, train_iters=8, reps=50,
            label="100k", client_counts=(1, 8, 16))
    if out:
        from benchmarks.common import write_rows
        write_rows(out, common.ROWS[mark:], smoke=smoke)
        print(f"# wrote {len(common.ROWS) - mark} rows to {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down shape (n=10k)")
    ap.add_argument("--overload", action="store_true",
                    help="run the admission-control overload scenario "
                         "instead of the direct-vs-frontend comparison")
    ap.add_argument("--out", default="",
                    help="standalone JSON artifact path")
    main(**vars(ap.parse_args()))
