"""Paper Figure 10: SOAR's read-ratio benefit vs dataset size and recall
target (400 datapoints/partition maintained across sizes, as in the paper)."""
from __future__ import annotations

import jax

from benchmarks.common import D, K, NQ, Timer, emit
from repro.core import build_ivf, kmr_curve, points_to_recall, true_neighbors
from repro.data.vectors import glove_like


def main():
    for n in (25_000, 50_000, 100_000, 200_000):
        c = max(n // 400, 32)
        ds = glove_like(n=n, d=D, nq=NQ)
        tn = true_neighbors(ds.X, ds.Q, k=K)
        with Timer() as t:
            curves = {}
            for mode in ("none", "soar"):
                idx = build_ivf(jax.random.PRNGKey(1), ds.X, c,
                                spill_mode=mode, train_iters=8)
                curves[mode] = kmr_curve(idx, ds.Q, tn, k=K, name=mode)
        for target in (0.85, 0.95):
            ratio = (points_to_recall(curves["none"], target)
                     / points_to_recall(curves["soar"], target))
            emit(f"fig10_n{n//1000}k_r{int(target*100)}", t.us,
                 f"{ratio:.3f}x")


if __name__ == "__main__":
    main()
