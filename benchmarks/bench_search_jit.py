"""Candidate-local `search_jit` vs the seed dense-dedup implementation.

ISSUE 2 acceptance microbench: the rewrite replaces the seed's per-query
dense (n,)-scatter dedup + full-database top_k with sort-based dedup over
the t·pmax candidate window. This bench times both pipelines on the same
packed index (n=100k, nq=256, CPU) and reports the speedup and recall@10 —
the win must be ≥ 3x with recall unchanged (±0.002).

ISSUE 5 adds filtered-serving rows (DESIGN.md §3.9): subset search at
selectivities {0.9, 0.5, 0.1, 0.01} on both engines, with recall measured
against FILTERED exact search (the only honest comparator — unfiltered
ground truth is unreachable by definition once a filter applies).

ISSUE 6 adds router rows (DESIGN.md §3.10): flat vs two-level tree probe
(`--routers` sweeps c ∈ {1k, 8k, 32k} at n=100k and asserts the acceptance
bar — tree ≥ 0.95x flat recall@10 at ≤ 1/4 probe FLOPs at c=32k).

    PYTHONPATH=src python -m benchmarks.bench_search_jit [--smoke|--routers]

`--smoke` runs a scaled-down shape (n=10k, nq=32) as a CI sanity check.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core import build_ivf, pack_ivf, search_jit, true_neighbors
from repro.core.search import PackedIVF, search_jit_batched
from repro.data.vectors import glove_like
from repro.quant.pq import pq_lut


@functools.partial(jax.jit, static_argnames=("top_t", "final_k", "rerank_budget"))
def seed_search_jit(packed: PackedIVF, Q, top_t: int, final_k: int,
                    rerank_budget: int = 256):
    """The seed implementation, kept verbatim as the baseline: per-query
    closure, dense (n,)-scatter dedup, top_k over the whole database."""
    C, ids_all, codes_all = packed.centroids, packed.part_ids, packed.part_codes
    n = packed.rerank.shape[0]

    def one(q):
        sc = C @ q
        psc, parts = jax.lax.top_k(sc, top_t)
        ids = ids_all[parts].reshape(-1)
        valid = ids >= 0
        if codes_all is not None:
            lut = pq_lut(packed.pq, q)
            codes = codes_all[parts].reshape(ids.shape[0], -1)
            approx = jnp.sum(
                jnp.take_along_axis(lut[None], codes[:, :, None].astype(jnp.int32),
                                    axis=2)[:, :, 0], axis=-1)
            approx = approx + jnp.repeat(psc, ids_all.shape[1])
        else:
            approx = jnp.repeat(psc, ids_all.shape[1])
        approx = jnp.where(valid, approx, -jnp.inf)
        dense = jnp.full((n,), -jnp.inf, approx.dtype)
        dense = dense.at[jnp.where(valid, ids, n - 1)].max(
            jnp.where(valid, approx, -jnp.inf))
        bv, bi = jax.lax.top_k(dense, rerank_budget)
        exact = packed.rerank[bi] @ q
        exact = jnp.where(jnp.isfinite(bv), exact, -jnp.inf)
        fv, fpos = jax.lax.top_k(exact, final_k)
        return bi[fpos].astype(jnp.int32), fv

    return jax.vmap(one)(Q)


def _time(fn, reps: int = 5) -> float:
    """Best-of-reps wall time in µs (post-warmup; blocks on device results)."""
    best = float("inf")
    for _ in range(reps):
        with Timer() as t:
            r = fn()
            jax.block_until_ready(r)
        best = min(best, t.us)
    return best


def recall_at(ids: np.ndarray, tn: np.ndarray, k: int = 10) -> float:
    return float((ids[:, :k, None] == tn[:, None, :k]).any(-1).mean())


def _setup(n: int, nq: int, c: int, train_iters: int):
    """Shared dataset+index build for run()/run_filtered() — the same
    (seeded) build, so main() pays the multi-minute 100k Lloyd+PQ pass
    once, not per section."""
    ds = glove_like(n=n, d=100, nq=nq)
    idx = build_ivf(jax.random.PRNGKey(1), ds.X, c, spill_mode="soar",
                    pq_subspaces=25, train_iters=train_iters)
    return ds, idx, pack_ivf(idx)


def run(n: int, nq: int, c: int, top_t: int, rerank_budget: int,
        train_iters: int, label: str, prebuilt=None):
    ds, idx, packed = prebuilt or _setup(n, nq, c, train_iters)
    tn = true_neighbors(ds.X, ds.Q, k=10)
    Q = jnp.asarray(ds.Q)
    kw = dict(top_t=top_t, final_k=10, rerank_budget=rerank_budget)

    new_ids, _ = search_jit(packed, Q, **kw)              # compile + warmup
    seed_ids, _ = seed_search_jit(packed, Q, **kw)
    tiled_ids, _ = search_jit_batched(packed, Q, bq=64, **kw)
    t_new = _time(lambda: search_jit(packed, Q, **kw))
    t_seed = _time(lambda: seed_search_jit(packed, Q, **kw))
    t_tiled = _time(lambda: search_jit_batched(packed, Q, bq=64, **kw))

    r_new = recall_at(np.asarray(new_ids), tn)
    r_seed = recall_at(np.asarray(seed_ids), tn)
    speedup = t_seed / t_new
    emit(f"search_jit_seed_{label}", t_seed / nq,
         f"recall@10={r_seed:.3f} n={n} nq={nq}")
    emit(f"search_jit_new_{label}", t_new / nq,
         f"recall@10={r_new:.3f} speedup={speedup:.2f}x "
         f"d_recall={r_new - r_seed:+.4f}")
    emit(f"search_jit_tiled_{label}", t_tiled / nq,
         f"recall@10={recall_at(np.asarray(tiled_ids), tn):.3f} bq=64")
    return speedup, r_new, r_seed


def run_filtered(n: int, nq: int, c: int, top_t: int, rerank_budget: int,
                 train_iters: int, label: str,
                 sels=(0.9, 0.5, 0.1, 0.01), prebuilt=None):
    """Filtered-serving rows: per selectivity, time the filtered jit path
    (with its fixed escalation pass) and the host engine (with its
    host-driven escalation loop); recall is vs FILTERED exact search."""
    from repro.core import search_numpy
    ds, idx, packed = prebuilt or _setup(n, nq, c, train_iters)
    Q = jnp.asarray(ds.Q)
    rng = np.random.default_rng(0)
    kw = dict(top_t=top_t, final_k=10, rerank_budget=rerank_budget)
    for sel in sels:
        mask = rng.random(n) < sel
        alive = np.flatnonzero(mask)
        tn = alive[np.asarray(true_neighbors(ds.X[alive], ds.Q, k=10))]
        f = jnp.asarray(mask.astype(np.uint8))
        jids, _ = search_jit(packed, Q, filter=f, **kw)      # compile+warm
        t_jit = _time(lambda: search_jit(packed, Q, filter=f, **kw))
        np_res = {}                 # ids from a TIMED call — a 4th untimed
                                    # run can be a near-full scan at s=0.01
        t_np = _time(lambda: np_res.setdefault(
            "ids", search_numpy(idx, ds.Q, filter_mask=mask, **kw)[0]),
            reps=3)
        nids = np_res["ids"]
        emit(f"search_jit_filtered_s{sel}_{label}", t_jit / nq,
             f"recall@10={recall_at(np.asarray(jids), tn):.3f} "
             f"selectivity={sel} (vs filtered exact)")
        emit(f"search_numpy_filtered_s{sel}_{label}", t_np / nq,
             f"recall@10={recall_at(nids, tn):.3f} "
             f"selectivity={sel} (vs filtered exact)")


def run_routers(n: int, nq: int, cs, rerank_budget: int, train_iters: int,
                label: str, check_acceptance_c: int = 0):
    """Router rows (ISSUE 6 / DESIGN.md §3.10): flat vs two-level tree
    probe at growing partition counts. top_t scales with c (a roughly
    constant candidate budget), so the probe stage's share of the work
    grows with c — the regime the TreeRouter exists for. Each tree row's
    derived string carries recall@10, the probe-FLOPs ratio vs flat, and
    the relative recall; the README recall-vs-probe-cost table and the CI
    regression gate read these rows."""
    from repro.core.router import FlatRouter, train_tree_router
    ds = glove_like(n=n, d=100, nq=nq)
    tn = true_neighbors(ds.X, ds.Q, k=10)
    Q = jnp.asarray(ds.Q)
    for c in cs:
        top_t = max(6, round(c / 200))
        idx = build_ivf(jax.random.PRNGKey(1), ds.X, c, spill_mode="soar",
                        pq_subspaces=25, train_iters=train_iters,
                        # exact k-means++ is c sequential picks — at 8k+
                        # centroids the k-means|| init is the only sane one
                        init="pp" if c <= 1024 else "parallel")
        packed = pack_ivf(idx)
        flat = FlatRouter(packed.centroids)
        S = max(2, int(round(c ** 0.5)))
        tree = train_tree_router(jax.random.PRNGKey(2), idx.centroids,
                                 n_super=S, t_route=max(2, -(-S // 8)))
        treed = tree.device()
        kw = dict(top_t=top_t, final_k=10, rerank_budget=rerank_budget)
        fids, _ = search_jit(packed, Q, router=flat, **kw)   # compile+warm
        tids, _ = search_jit(packed, Q, router=treed, **kw)
        t_flat = _time(lambda: search_jit(packed, Q, router=flat, **kw))
        t_tree = _time(lambda: search_jit(packed, Q, router=treed, **kw))
        rf = recall_at(np.asarray(fids), tn)
        rt = recall_at(np.asarray(tids), tn)
        ratio = tree.probe_flops(top_t) / flat.probe_flops(top_t)
        rel = rt / max(rf, 1e-9)
        emit(f"search_router_flat_c{c}_{label}", t_flat / nq,
             f"recall@10={rf:.3f} top_t={top_t} "
             f"probe_flops={flat.probe_flops(top_t)}")
        emit(f"search_router_tree_c{c}_{label}", t_tree / nq,
             f"recall@10={rt:.3f} top_t={top_t} n_super={S} "
             f"t_route={tree.t_route} flops_ratio={ratio:.3f} "
             f"rel_recall={rel:.3f}")
        if c == check_acceptance_c:
            assert rel >= 0.95, (
                f"tree recall {rt:.3f} < 0.95x flat {rf:.3f} at c={c}")
            assert ratio <= 0.25, (
                f"tree probe FLOPs {ratio:.2f}x flat exceeds 1/4 at c={c}")


def main(smoke: bool = False, routers: bool = False, out: str = ""):
    from benchmarks import common
    mark = len(common.ROWS)
    if smoke:
        pre = _setup(n=10_000, nq=32, c=64, train_iters=3)
        run(n=10_000, nq=32, c=64, top_t=6, rerank_budget=256,
            train_iters=3, label="smoke", prebuilt=pre)
        run_filtered(n=10_000, nq=32, c=64, top_t=6, rerank_budget=256,
                     train_iters=3, label="smoke", prebuilt=pre)
        run_routers(n=10_000, nq=32, cs=(256,), rerank_budget=256,
                    train_iters=3, label="smoke")
    elif routers:
        run_routers(n=100_000, nq=64, cs=(1024, 8192, 32768),
                    rerank_budget=300, train_iters=5, label="100k",
                    check_acceptance_c=32768)
    else:
        pre = _setup(n=100_000, nq=256, c=500, train_iters=8)
        speedup, r_new, r_seed = run(n=100_000, nq=256, c=500, top_t=10,
                                     rerank_budget=300, train_iters=8,
                                     label="100k", prebuilt=pre)
        assert speedup >= 3.0, f"speedup {speedup:.2f}x < 3x acceptance bar"
        assert abs(r_new - r_seed) <= 0.002, (r_new, r_seed)
        run_filtered(n=100_000, nq=256, c=500, top_t=10, rerank_budget=300,
                     train_iters=8, label="100k", prebuilt=pre)
    if out:
        from benchmarks.common import write_rows
        write_rows(out, common.ROWS[mark:], smoke=smoke)
        print(f"# wrote {len(common.ROWS) - mark} rows to {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI shape (n=10k, nq=32)")
    ap.add_argument("--routers", action="store_true",
                    help="flat-vs-tree router sweep at c in {1k, 8k, 32k} "
                         "(n=100k; the ISSUE 6 acceptance run)")
    ap.add_argument("--out", default="",
                    help="standalone JSON artifact path (for the CI "
                         "regression gate)")
    main(**vars(ap.parse_args()))
