"""CI regression gate: hold the recall/latency line against a committed
baseline.

Compares a freshly generated bench artifact (rows of
``{name, us_per_call, derived}``) against a baseline JSON committed under
``benchmarks/baselines/``:

- **recall**: any ``recall@10=X`` value parsed from a row's derived string
  may not drop more than ``--recall-tol`` (default 0.005) below baseline;
- **latency**: a row's ``us_per_call`` may not exceed baseline by more
  than ``--latency-tol`` (default 1.25, i.e. a 25% regression budget).
  When ``--normalize-by ROW`` names a calibration row present in both
  runs (the benches emit fixed-shape GEMM / reference-implementation
  rows), all latencies are divided by it first, so the committed baseline
  transfers across machines of different speeds;
- **coverage**: a baseline row missing from the current run fails — a
  bench silently dropping a measurement must not pass the gate;
- **serving resilience** (ISSUE 9): any ``goodput_ratio=X`` in a row's
  derived string (the overload scenario of bench_qps — overload goodput
  over no-overload capacity) may not drop more than ``--goodput-tol``
  below baseline, and ``shed_rate=X`` may not rise more than
  ``--shed-tol`` above it. Both are dimensionless ratios, so they gate
  WITHOUT machine-speed normalization.

Exit code 1 on any failure. Regenerate baselines intentionally with:

    PYTHONPATH=src python -m benchmarks.bench_search_jit --smoke \
        --out benchmarks/baselines/BENCH_search.smoke.json
    PYTHONPATH=src python -m benchmarks.bench_build --smoke \
        --out benchmarks/baselines/BENCH_build.smoke.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys

RECALL_RE = re.compile(r"recall@10=([0-9.]+)")
GOODPUT_RE = re.compile(r"goodput_ratio=([0-9.]+)")
SHED_RE = re.compile(r"shed_rate=([0-9.]+)")


def _load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r for r in payload["rows"]}


def _recall_of(row) -> float | None:
    m = RECALL_RE.search(row.get("derived", ""))
    return float(m.group(1)) if m else None


def _derived_of(row, rx: re.Pattern) -> float | None:
    m = rx.search(row.get("derived", ""))
    return float(m.group(1)) if m else None


def check(current: dict, baseline: dict, *, latency_tol: float,
          recall_tol: float, normalize_by: str | None,
          min_us: float = 0.0, goodput_tol: float = 0.15,
          shed_tol: float = 0.20):
    failures, notes = [], []
    scale = 1.0
    if normalize_by:
        cur_n = current.get(normalize_by)
        base_n = baseline.get(normalize_by)
        if cur_n and base_n and cur_n["us_per_call"] > 0:
            # machine-speed ratio: >1 means this machine is slower than
            # the one that produced the baseline
            scale = cur_n["us_per_call"] / base_n["us_per_call"]
            notes.append(f"normalized by {normalize_by}: "
                         f"machine scale {scale:.2f}x")
        else:
            failures.append(f"normalization row '{normalize_by}' missing "
                            f"or unusable in current/baseline")
    for name, brow in baseline.items():
        crow = current.get(name)
        if crow is None:
            failures.append(f"{name}: missing from current run")
            continue
        b_rec, c_rec = _recall_of(brow), _recall_of(crow)
        if b_rec is not None:
            if c_rec is None:
                failures.append(f"{name}: baseline has recall@10 but "
                                f"current row does not")
            elif c_rec < b_rec - recall_tol:
                failures.append(f"{name}: recall@10 {c_rec:.4f} < baseline "
                                f"{b_rec:.4f} - {recall_tol}")
            else:
                notes.append(f"{name}: recall@10 {c_rec:.4f} "
                             f"(baseline {b_rec:.4f}) ok")
        b_gp = _derived_of(brow, GOODPUT_RE)
        if b_gp is not None:
            c_gp = _derived_of(crow, GOODPUT_RE)
            if c_gp is None:
                failures.append(f"{name}: baseline has goodput_ratio but "
                                f"current row does not")
            elif c_gp < b_gp - goodput_tol:
                failures.append(f"{name}: goodput_ratio {c_gp:.2f} < "
                                f"baseline {b_gp:.2f} - {goodput_tol}")
            else:
                notes.append(f"{name}: goodput_ratio {c_gp:.2f} "
                             f"(baseline {b_gp:.2f}) ok")
        b_sr = _derived_of(brow, SHED_RE)
        if b_sr is not None:
            c_sr = _derived_of(crow, SHED_RE)
            if c_sr is None:
                failures.append(f"{name}: baseline has shed_rate but "
                                f"current row does not")
            elif c_sr > b_sr + shed_tol:
                failures.append(f"{name}: shed_rate {c_sr:.2f} > baseline "
                                f"{b_sr:.2f} + {shed_tol}")
            else:
                notes.append(f"{name}: shed_rate {c_sr:.2f} "
                             f"(baseline {b_sr:.2f}) ok")
        if name == normalize_by:
            continue
        b_us, c_us = brow["us_per_call"], crow["us_per_call"]
        if b_us <= 0 or c_us <= 0:
            continue                       # recall-only / failure rows
        if b_us < min_us or (c_us / scale) < min_us:
            # sub-floor rows (e.g. per-phase timings that shrink to noise
            # at smoke scale): coverage is still enforced above, but a
            # latency ratio over microseconds of jitter is meaningless.
            # `or` (not `and`): a row whose baseline sits just under the
            # floor must not start flake-gating when run noise nudges the
            # current value over it. The current value is machine-scale
            # normalized first, so a faster runner cannot pull genuinely
            # gated rows under the raw floor
            notes.append(f"{name}: below --min-us floor, latency ungated")
            continue
        ratio = (c_us / scale) / b_us
        if ratio > latency_tol:
            failures.append(f"{name}: latency {c_us:.1f}us is {ratio:.2f}x "
                            f"baseline {b_us:.1f}us (tol {latency_tol}x, "
                            f"machine scale {scale:.2f}x)")
        else:
            notes.append(f"{name}: latency ratio {ratio:.2f}x ok")
    return failures, notes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--latency-tol", type=float, default=1.25,
                    help="max allowed current/baseline latency ratio")
    ap.add_argument("--recall-tol", type=float, default=0.005,
                    help="max allowed recall@10 drop vs baseline")
    ap.add_argument("--normalize-by", default=None,
                    help="calibration row name for cross-machine "
                         "latency normalization")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="skip latency gating (not coverage) for rows "
                         "under this many µs in either run (current "
                         "value machine-scale normalized first)")
    ap.add_argument("--goodput-tol", type=float, default=0.15,
                    help="max allowed goodput_ratio drop vs baseline")
    ap.add_argument("--shed-tol", type=float, default=0.20,
                    help="max allowed shed_rate rise vs baseline")
    args = ap.parse_args()
    failures, notes = check(
        _load_rows(args.current), _load_rows(args.baseline),
        latency_tol=args.latency_tol, recall_tol=args.recall_tol,
        normalize_by=args.normalize_by, min_us=args.min_us,
        goodput_tol=args.goodput_tol, shed_tol=args.shed_tol)
    for n in notes:
        print(f"  ok: {n}")
    if failures:
        print(f"\nREGRESSION GATE FAILED ({args.current} "
              f"vs {args.baseline}):", file=sys.stderr)
        for f in failures:
            print(f"  FAIL: {f}", file=sys.stderr)
        return 1
    print(f"regression gate passed: {args.current} vs {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
