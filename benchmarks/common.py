"""Shared benchmark fixtures: dataset + the three index variants, built once
per process and cached."""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.core import build_ivf, true_neighbors
from repro.data.vectors import glove_like

# benchmark scale (1-core CPU container): see DESIGN.md §7 — relative claims
# at 100k–200k scale; the paper's billion-scale gains extrapolate per Fig 10.
N = 100_000
D = 100
NQ = 400
K = 100
C = 500          # 200 points/partition
LAM = 1.0


@functools.lru_cache(maxsize=None)
def dataset():
    return glove_like(n=N, d=D, nq=NQ)


@functools.lru_cache(maxsize=None)
def neighbors():
    ds = dataset()
    return true_neighbors(ds.X, ds.Q, k=K)


@functools.lru_cache(maxsize=None)
def index(mode: str, lam: float = LAM, pq: int = 0, n: int = N, c: int = C):
    ds = dataset() if n == N else glove_like(n=n, d=D, nq=NQ)
    return build_ivf(jax.random.PRNGKey(1), ds.X[:n], c, spill_mode=mode,
                     lam=lam, pq_subspaces=pq, train_iters=8)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


# every emit()ed row is also collected here so run.py can write the
# consolidated BENCH_search.json artifact (perf trajectory across PRs)
ROWS = []


def emit(name: str, us: float, derived):
    ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                 "derived": str(derived)})
    print(f"{name},{us:.1f},{derived}")


def write_rows(path: str, rows, **meta):
    """Write a standalone bench artifact (the per-bench JSON files the CI
    regression gate consumes; run.py separately writes the consolidated
    artifact from ROWS)."""
    import json
    import platform

    import jax

    payload = {"unit": "us_per_call", "backend": jax.default_backend(),
               "platform": platform.platform(), **meta, "rows": list(rows)}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
