"""Render the §Roofline table from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(mesh: str):
    rows = []
    for p in sorted(glob.glob(f"artifacts/dryrun/*_{mesh}.json")):
        r = json.load(open(p))
        if "skipped" in r:
            rows.append(r)
            continue
        rows.append(r)
    return rows


def render(mesh: str = "single") -> str:
    rows = load(mesh)
    out = []
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "peak GiB | useful FLOPs ratio | roofline fraction |")
    out.append(hdr)
    out.append("|" + "---|" * 9)
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP: {r['skipped']} | — | — | — |")
            continue
        rf = r["roofline"]
        frac = rf["compute_s"] / max(rf["bound_step_s"], 1e-12)
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"{rf['dominant'].replace('_s','')} | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | "
            f"{rf['useful_flops_ratio']:.2f} | {frac:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    print(render(args.mesh))


if __name__ == "__main__":
    main()
