"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only kmr,qps] [--out BENCH_search.json]

Prints ``name,us_per_call,derived`` CSV rows (see each bench module's
docstring for the paper table/figure it reproduces) and writes every row to
a consolidated JSON artifact (default ``BENCH_search.json``) so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ("search_jit", "build", "kmr", "correlation", "lambda", "scaling",
           "qps", "memory", "ablation")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    ap.add_argument("--out", default="BENCH_search.json",
                    help="consolidated JSON output path ('' to disable)")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else BENCHES

    from benchmarks import common

    print("name,us_per_call,derived")
    failures = []
    for name in BENCHES:
        if name not in only:
            continue
        t0 = time.time()
        try:
            __import__(f"benchmarks.bench_{name}", fromlist=["main"]).main()
            print(f"# bench_{name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness going
            failures.append(name)
            print(f"bench_{name}_FAILED,0,{type(e).__name__}:{e}")

    if args.out:
        common.write_rows(args.out, common.ROWS,
                          benches_run=[b for b in BENCHES if b in only],
                          failed=failures)
        print(f"# wrote {len(common.ROWS)} rows to {args.out}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
