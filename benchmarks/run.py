"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only kmr,qps]

Prints ``name,us_per_call,derived`` CSV rows (see each bench module's
docstring for the paper table/figure it reproduces).
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ("kmr", "correlation", "lambda", "scaling", "qps", "memory",
           "ablation")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    only = args.only.split(",") if args.only else BENCHES

    print("name,us_per_call,derived")
    for name in BENCHES:
        if name not in only:
            continue
        t0 = time.time()
        try:
            __import__(f"benchmarks.bench_{name}", fromlist=["main"]).main()
            print(f"# bench_{name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness going
            print(f"bench_{name}_FAILED,0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
