"""Distributed SOAR serving demo: shard a vector database over 8 (virtual)
devices, search with the shard_map engine, compare spill modes.

    PYTHONPATH=src python examples/ann_serving.py
(sets XLA_FLAGS itself — run as a standalone script.)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time                                                    # noqa: E402

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from repro.core import true_neighbors                          # noqa: E402
from repro.core.distributed import (build_sharded_ivf,         # noqa: E402
                                    make_distributed_search)
from repro.data.vectors import make_manifold                   # noqa: E402
from repro.launch.mesh import set_mesh                         # noqa: E402


def main():
    n, d, nq = 64_000, 64, 256
    ds = make_manifold(jax.random.PRNGKey(0), n=n, d=d, nq=nq,
                       intrinsic_dim=10)
    tn = true_neighbors(ds.X, ds.Q, k=10)
    mesh = jax.make_mesh((8,), ("data",))
    print(f"database {ds.X.shape} sharded over {mesh.shape} mesh")

    for mode in ("none", "soar"):
        t0 = time.time()
        sharded = build_sharded_ivf(jax.random.PRNGKey(1), ds.X, n_shards=8,
                                    n_partitions=32, spill_mode=mode,
                                    train_iters=6)
        build_s = time.time() - t0
        search = make_distributed_search(mesh, ("data",), top_t=6, final_k=10)
        with set_mesh(mesh):
            jsearch = jax.jit(search)
            ids, _ = jsearch(sharded, jnp.asarray(ds.Q))   # compile
            t0 = time.time()
            for _ in range(3):
                ids, _ = jsearch(sharded, jnp.asarray(ds.Q))
            ids.block_until_ready()
            dt = (time.time() - t0) / 3 / nq
        rec = (np.asarray(ids)[:, :, None] == tn[:, None, :]).any(-1).mean()
        print(f"  {mode:5s} build {build_s:5.1f}s  recall@10={rec:.3f}  "
              f"{dt*1e6:.0f} us/query (8-way, incl. global merge)")


if __name__ == "__main__":
    main()
