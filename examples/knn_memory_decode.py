"""SOAR-kNN attention memory (memorizing-transformer-style serving).

Builds a long synthetic KV history for one attention head, indexes the keys
with SOAR, and compares retrieval-based attention against exact top-k
attention — the paper's technique acting as a first-class LM-serving
feature (see serve/knn_memory.py and DESIGN.md §5).

    PYTHONPATH=src python examples/knn_memory_decode.py
"""
import time

import jax
import numpy as np

from repro.serve.knn_memory import KNNMemory, exact_topk_attention


def main():
    hd, n_ctx, nq = 64, 100_000, 128
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    # keys near a low-dim manifold (realistic attention keys are structured)
    from repro.data.vectors import make_manifold
    ds = make_manifold(k1, n=n_ctx, d=hd, nq=nq, intrinsic_dim=10)
    keys = ds.X
    values = np.asarray(jax.random.normal(k2, (n_ctx, hd)), np.float32)
    queries = ds.Q

    exact_out, exact_ids = exact_topk_attention(queries, keys, values, k=32)

    for mode in ("none", "soar"):
        t0 = time.time()
        mem = KNNMemory.build(keys, values, n_partitions=256, lam=1.0,
                              spill_mode=mode)
        build_s = time.time() - t0
        out, ids = mem.attend(queries, k=32, top_t=8)
        key_recall = (ids[:, :, None] == exact_ids[:, None, :]).any(-1).mean()
        err = np.linalg.norm(out - exact_out, axis=1)
        base = np.linalg.norm(exact_out, axis=1)
        print(f"  {mode:5s} build {build_s:5.1f}s  key-recall@32={key_recall:.3f}  "
              f"attn-out rel err={np.mean(err/base):.4f}")


if __name__ == "__main__":
    main()
