"""Quickstart: build a SOAR index over synthetic embeddings, query it, and
see the paper's headline effect (spilled assignments rescue hard neighbors).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.core import (build_ivf, kmr_curve, points_to_recall, search_numpy,
                        true_neighbors)
from repro.data.vectors import glove_like


def main():
    print("== SOAR quickstart ==")
    ds = glove_like(n=50_000, d=100, nq=200)
    print(f"dataset: {ds.name}  X={ds.X.shape}  Q={ds.Q.shape}")

    tn = true_neighbors(ds.X, ds.Q, k=100)

    indexes = {}
    for mode in ("none", "soar"):
        t0 = time.time()
        indexes[mode] = build_ivf(jax.random.PRNGKey(0), ds.X, 250,
                                  spill_mode=mode, lam=1.0, pq_subspaces=25)
        print(f"built {mode!r} index in {time.time()-t0:.1f}s "
              f"({indexes[mode].n_assignments} assignments)")

    print("\ndatapoints that must be read for a recall target (KMR, Table 2):")
    for mode, idx in indexes.items():
        cv = kmr_curve(idx, ds.Q, tn, k=100)
        pts = {t: points_to_recall(cv, t) for t in (0.85, 0.95)}
        print(f"  {mode:5s}  R@85: {pts[0.85]:8.0f}   R@95: {pts[0.95]:8.0f}")

    print("\nend-to-end search (PQ + exact rerank), top_t=12:")
    for mode, idx in indexes.items():
        t0 = time.time()
        ids, stats = search_numpy(idx, ds.Q, top_t=12, final_k=10,
                                  rerank_budget=300)
        dt = (time.time() - t0) / len(ds.Q)
        rec = (ids[:, :, None] == tn[:, None, :10]).any(-1).mean()
        print(f"  {mode:5s}  recall@10={rec:.3f}  {dt*1e3:.2f} ms/query  "
              f"avg pts read={stats.points_read.mean():.0f}")


if __name__ == "__main__":
    main()
