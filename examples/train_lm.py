"""End-to-end training driver: train a ~100M-param granite-family model for a
few hundred steps on the synthetic markov stream, with checkpointing and
resume. CPU-runnable (this is the required e2e example).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import for_model
from repro.train.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="artifacts/ckpt/train_lm_example")
    args = ap.parse_args()

    # ~100M params: granite family, reduced width/depth
    cfg = get_config("granite-3-2b").replace(
        name="granite-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192)
    n_params = 2 * cfg.vocab_padded * cfg.d_model + cfg.n_layers * (
        4 * cfg.d_model * cfg.d_model + 3 * cfg.d_model * cfg.d_ff)
    print(f"config {cfg.name}: ~{n_params/1e6:.0f}M params")

    pipe = for_model(cfg, seq_len=256, global_batch=16, mode="markov")
    mgr = CheckpointManager(args.ckpt, keep=2)
    params, _, losses = train(cfg, pipe, steps=args.steps, lr=1e-3,
                              accum=2, ckpt_manager=mgr, ckpt_every=100,
                              log_every=20)
    print(f"first-10 mean loss {sum(losses[:10])/10:.3f} → "
          f"last-10 mean loss {sum(losses[-10:])/10:.3f}")


if __name__ == "__main__":
    main()
