"""Static contract analyzer (DESIGN.md §3.14): jaxpr invariant contracts,
recompile sentinel, and repo-specific AST lints, gated in CI via
`python -m repro.analysis.check`.

Import surface:
  jaxpr_shapes / jaxpr_outvals / iter_eqns    shared jaxpr walker (the
      single replacement for the test-side `_jaxpr_shapes` helpers)
  jaxpr_contract / check_all_contracts        declarative contract registry
  CacheWatch / run_serving_workload           recompile sentinel
  lint_source / lint_paths                    AST lint pass
  Finding / load_baseline                     findings + ratchet baseline
"""
from repro.analysis.findings import (Finding, load_baseline,  # noqa: F401
                                     partition_findings, save_baseline)
from repro.analysis.jaxpr_walk import (iter_eqns, jaxpr_outvals,  # noqa: F401
                                       jaxpr_primitives, jaxpr_shapes)


def __getattr__(name):
    # contracts/sentinel/lint import jax + serving layers — load lazily so
    # `from repro.analysis import jaxpr_shapes` stays import-cheap in tests
    if name in ("jaxpr_contract", "check_all_contracts", "check_contract",
                "TraceSpec", "REGISTRY", "HOST_CALLBACK_PRIMITIVES"):
        from repro.analysis import contracts
        return getattr(contracts, name)
    if name in ("CacheWatch", "run_serving_workload", "snapshot_caches",
                "cache_growth", "resolve_entry_points"):
        from repro.analysis import sentinel
        return getattr(sentinel, name)
    if name in ("lint_source", "lint_paths"):
        from repro.analysis import lint_ast
        return getattr(lint_ast, name)
    raise AttributeError(name)
