"""CLI: `python -m repro.analysis.check` — run the static contract
analyzer (jaxpr contracts + recompile sentinel + AST lints) against the
repo and exit nonzero on any finding not grandfathered by the committed
ratchet baseline (DESIGN.md §3.14).

    python -m repro.analysis.check                 # full run
    python -m repro.analysis.check --skip sentinel # passes are skippable
    python -m repro.analysis.check --report findings.json
    python -m repro.analysis.check --update-baseline   # re-ratchet
    python -m repro.analysis.check --inject f64-leak   # self-test: must
                                                       # exit nonzero

--inject runs a synthetic violation of the named class through the SAME
pass machinery (not a fabricated finding), so CI can verify each detector
actually detects: o-n-intermediate | f64-leak | cache-growth |
unlocked-call | falsy-default.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import textwrap
from typing import List, Optional

from repro.analysis.findings import (Finding, load_baseline,
                                     partition_findings, save_baseline)

PASSES = ("lint", "contracts", "sentinel")
INJECT_CLASSES = ("o-n-intermediate", "f64-leak", "cache-growth",
                  "unlocked-call", "falsy-default")


def _repo_root(explicit: Optional[str] = None) -> str:
    if explicit:
        return os.path.abspath(explicit)
    here = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
    if os.path.isdir(os.path.join(here, "src", "repro")):
        return here
    return os.getcwd()


# ------------------------------------------------------------- injections
# Each injector drives a deliberately-violating synthetic target through
# the real pass, proving the detector fires (acceptance criterion: the CLI
# exits nonzero on every class).

def _inject_o_n_intermediate() -> List[Finding]:
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.contracts import TraceSpec, jaxpr_contract, \
        check_contract

    reg: dict = {}

    @jaxpr_contract("injected_o_n", no_dims={"n"}, registry=reg)
    def _spec():
        X = jnp.asarray(np.zeros((521, 8), np.float32))
        # (n, n) similarity matrix: exactly the database-sized
        # intermediate the candidate-local pipeline forbids
        return TraceSpec(fn=lambda x: (x @ x.T).sum(axis=0), args=(X,),
                         dims={"n": 521})

    return check_contract(reg["injected_o_n"])


def _inject_f64_leak() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.contracts import TraceSpec, jaxpr_contract, \
        check_contract

    reg: dict = {}

    @jaxpr_contract("injected_f64", registry=reg)
    def _spec():
        X = jnp.asarray(np.zeros((16, 8), np.float32))
        return TraceSpec(fn=lambda x: x.astype(jnp.float64).sum(),
                         args=(X,), dims={})

    with jax.experimental.enable_x64():
        return check_contract(reg["injected_f64"])


def _inject_cache_growth() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.sentinel import cache_growth, snapshot_caches

    @jax.jit
    def toy(x):
        return (x * 2.0).sum()

    # the classic recompile storm: every distinct nq keys a fresh trace
    # (the bug class pad_queries' power-of-two buckets eliminate)
    toy(jnp.zeros((1,)))
    fns = {"injected_toy": toy}
    before = snapshot_caches(fns)
    for nq in range(2, 7):
        toy(jnp.asarray(np.zeros(nq, np.float32)))
    after = snapshot_caches(fns)
    return [Finding("cache-growth", "sentinel:injected", context=name,
                    snippet=name,
                    message=f"injected recompile storm grew cache {b}->{a}")
            for name, (b, a) in cache_growth(before, after).items()]


_UNLOCKED_SRC = textwrap.dedent("""\
    class Frontend:
        def _expire_locked(self):
            pass

        def poll(self):
            self._expire_locked()       # no lock held: must be flagged
""")

_FALSY_SRC = textwrap.dedent("""\
    def probe(self, top_t=None):
        top_t = top_t or self.top_t     # explicit 0 silently coalesced
        return top_t
""")


def _inject_unlocked_call() -> List[Finding]:
    from repro.analysis.lint_ast import lint_source
    return lint_source(_UNLOCKED_SRC, "src/repro/serve/_injected.py")


def _inject_falsy_default() -> List[Finding]:
    from repro.analysis.lint_ast import lint_source
    return lint_source(_FALSY_SRC, "src/repro/core/_injected.py")


_INJECTORS = {
    "o-n-intermediate": _inject_o_n_intermediate,
    "f64-leak": _inject_f64_leak,
    "cache-growth": _inject_cache_growth,
    "unlocked-call": _inject_unlocked_call,
    "falsy-default": _inject_falsy_default,
}


# -------------------------------------------------------------------- main

def run_passes(root: str, passes, verbose: bool = False) -> List[Finding]:
    findings: List[Finding] = []
    if "lint" in passes:
        from repro.analysis.lint_ast import lint_paths
        found = lint_paths(root)
        if verbose:
            print(f"[lint] {len(found)} finding(s)")
        findings.extend(found)
    if "contracts" in passes:
        from repro.analysis.contracts import REGISTRY, check_all_contracts
        found = check_all_contracts()
        if verbose:
            print(f"[contracts] {len(REGISTRY)} contract(s), "
                  f"{len(found)} finding(s)")
        findings.extend(found)
    if "sentinel" in passes:
        from repro.analysis.sentinel import run_serving_workload
        found = run_serving_workload(verbose=verbose)
        if verbose:
            print(f"[sentinel] {len(found)} finding(s)")
        findings.extend(found)
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Static contract analyzer (DESIGN.md §3.14)")
    ap.add_argument("--root", default=None, help="repo root (default: "
                    "inferred from this module's location)")
    ap.add_argument("--skip", action="append", default=[],
                    choices=PASSES, help="skip a pass (repeatable)")
    ap.add_argument("--only", action="append", default=[],
                    choices=PASSES, help="run only these passes")
    ap.add_argument("--report", default=None,
                    help="write the findings report (JSON) here")
    ap.add_argument("--baseline", default=None,
                    help="ratchet baseline path (default: committed "
                    "src/repro/analysis/baseline.json)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="grandfather all current findings and exit 0")
    ap.add_argument("--inject", choices=INJECT_CLASSES, default=None,
                    help="self-test: add a synthetic violation of this "
                    "class (the run must then exit nonzero)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    passes = [p for p in (args.only or PASSES) if p not in args.skip]
    root = _repo_root(args.root)
    findings = run_passes(root, passes, verbose=not args.quiet)
    if args.inject:
        injected = _INJECTORS[args.inject]()
        if not injected:
            print(f"INJECTION FAILED: synthetic `{args.inject}` violation "
                  f"was not detected", file=sys.stderr)
            return 2
        findings.extend(injected)

    baseline = load_baseline(args.baseline)
    new, grandfathered = partition_findings(findings, baseline)

    if args.report:
        with open(args.report, "w") as fh:
            json.dump({
                "passes": passes,
                "new": [f.to_dict() for f in new],
                "grandfathered": [f.to_dict() for f in grandfathered],
            }, fh, indent=2)
            fh.write("\n")

    for f in grandfathered:
        print(f.render(grandfathered=True))
    for f in new:
        print(f.render())
    if args.update_baseline:
        save_baseline(findings, args.baseline)
        print(f"baseline updated: {len(findings)} finding(s) "
              f"grandfathered")
        return 0
    if not args.quiet or new:
        print(f"repro.analysis.check: {len(new)} new finding(s), "
              f"{len(grandfathered)} grandfathered, passes: "
              f"{', '.join(passes)}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
