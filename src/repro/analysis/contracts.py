"""Declarative jaxpr contracts for every jit entry point (DESIGN.md §3.14).

Each contract is a trace-spec builder decorated with `@jaxpr_contract`:
the builder constructs a tiny-but-representative workload (index, queries,
codebooks) and returns a `TraceSpec`; the checker traces it with
`jax.make_jaxpr`, walks the jaxpr (analysis/jaxpr_walk.py) and enforces:

  no_dims={"n"}       no equation output is (n,)-shaped or carries n in a
                      non-leading axis — the SOAR candidate-local invariant
                      (no per-query intermediate scales with the database;
                      a leading-n axis is allowed: build-path ops stream
                      over all points by design, e.g. (n, d) input views).
  no_dims_1d={"n"}    only 1-D (n,) outputs are forbidden — the Lloyd
                      "no second-pass vector" rule.
  no_products={"n*c"} no output's element count reaches the named dims'
                      product — the "nothing dense in (points × centroids)"
                      build-path rule.
  forbid_dtypes       no output aval carries the dtype (f64 leak guard —
                      load-bearing under JAX_ENABLE_X64 hosts).
  forbid_primitives   no host-callback / debug primitives in the trace
                      (they would stall the serving pipeline on a host
                      round-trip).
  max_cache_growth=0  re-invoking the entry point with the same-bucket
                      concrete args adds no jit cache entries.

Trace sizes are deliberately prime (N_TRACE=3001) so a forbidden dim can't
collide with a legitimate product of small axes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_walk import (jaxpr_outvals,  # noqa: F401
                                       jaxpr_primitives, jaxpr_shapes)

# Primitives that bounce through the host mid-trace. None may appear in a
# serving or build trace: a host round-trip inside a jit region serializes
# the pipeline behind Python.
HOST_CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "outside_call",
    "host_callback_call", "callback",
})

# Shared tiny-fixture scale. N_TRACE and C_TRACE sized so contract checks
# run in seconds; N_TRACE prime so no product of smaller axes equals it.
N_TRACE, D_TRACE, C_TRACE = 3001, 16, 24
NQ_TRACE, TOP_T, FINAL_K = 5, 6, 5


@dataclass
class TraceSpec:
    """One traceable workload: `fn` closes over all static args and takes
    only array (pytree) positionals; `args` are those arrays. `dims` maps
    the contract's symbolic dim names to this trace's concrete sizes.
    `jit_fn`/`call` (optional) drive the cache-growth check: `call`
    executes the real entry point with concrete args, `jit_fn` is the
    underlying jit wrapper whose `_cache_size()` is observed."""
    fn: Callable
    args: Tuple
    dims: Dict[str, int] = field(default_factory=dict)
    jit_fn: Optional[Callable] = None
    call: Optional[Callable] = None


@dataclass
class JaxprContract:
    name: str
    build: Callable[[], TraceSpec]
    no_dims: frozenset = frozenset()
    no_dims_1d: frozenset = frozenset()
    no_products: frozenset = frozenset()
    forbid_dtypes: frozenset = frozenset({"float64"})
    forbid_primitives: frozenset = HOST_CALLBACK_PRIMITIVES
    max_cache_growth: Optional[int] = 0


REGISTRY: Dict[str, JaxprContract] = {}


def jaxpr_contract(name: Optional[str] = None, *, no_dims=(), no_dims_1d=(),
                   no_products=(), forbid_dtypes=("float64",),
                   forbid_primitives=HOST_CALLBACK_PRIMITIVES,
                   max_cache_growth: Optional[int] = 0,
                   registry: Optional[Dict[str, JaxprContract]] = None):
    """Declare + register a contract over a trace-spec builder."""
    def deco(build):
        cname = name or build.__name__.lstrip("_")
        contract = JaxprContract(
            cname, build, frozenset(no_dims), frozenset(no_dims_1d),
            frozenset(no_products), frozenset(forbid_dtypes),
            frozenset(forbid_primitives), max_cache_growth)
        (REGISTRY if registry is None else registry)[cname] = contract
        return build
    return deco


# ------------------------------------------------------------------ checker

def _dim_violation(shape, v: int) -> bool:
    """The candidate-local predicate: (v,) exactly, or v in any
    non-leading axis (a leading-v axis is a streamed-over-points view).
    Leading size-1 axes are stripped first — inside shard_map the local
    index view arrives as (1, n_local, d), the shard axis in front of the
    same legitimate leading-n database view."""
    while len(shape) > 1 and shape[0] == 1:
        shape = shape[1:]
    if shape == (v,):
        return True
    return len(shape) >= 2 and v in shape[1:]


def _product_threshold(spec_dims: Dict[str, int], prod: str) -> int:
    """Parse "n*c" / "2*n*d": tokens are dim names or integer literals."""
    out = 1
    for tok in prod.split("*"):
        out *= int(tok) if tok.isdigit() else spec_dims[tok]
    return out


def check_contract(contract: JaxprContract) -> List[Finding]:
    import jax

    spec = contract.build()
    path = f"contract:{contract.name}"
    closed = jax.make_jaxpr(spec.fn)(*spec.args)
    vals = jaxpr_outvals(closed.jaxpr)
    findings: List[Finding] = []

    for dim in sorted(contract.no_dims):
        v = spec.dims[dim]
        bad = sorted({o.shape for o in vals if _dim_violation(o.shape, v)})
        if bad:
            findings.append(Finding(
                "jaxpr-dim", path, context=contract.name,
                snippet=f"{dim}={v}:{bad}",
                message=(f"intermediates carry forbidden dim {dim}={v}: "
                         f"{bad}")))
    for dim in sorted(contract.no_dims_1d):
        v = spec.dims[dim]
        bad = sorted({o.shape for o in vals
                      if len(o.shape) == 1 and o.shape[0] >= v})
        if bad:
            findings.append(Finding(
                "jaxpr-dim", path, context=contract.name,
                snippet=f"{dim}(1d)={v}:{bad}",
                message=f"1-D intermediates of forbidden dim {dim}: {bad}"))
    for prod in sorted(contract.no_products):
        v = _product_threshold(spec.dims, prod)
        bad = sorted({o.shape for o in vals
                      if int(np.prod(o.shape, dtype=np.int64)) >= v})
        if bad:
            findings.append(Finding(
                "jaxpr-dim", path, context=contract.name,
                snippet=f"{prod}>={v}:{bad}",
                message=(f"intermediates reach forbidden size "
                         f"{prod}={v}: {bad}")))
    for o in vals:
        if o.dtype in contract.forbid_dtypes:
            findings.append(Finding(
                "jaxpr-dtype", path, context=contract.name,
                snippet=f"{o.primitive}:{o.dtype}{list(o.shape)}",
                message=(f"forbidden dtype {o.dtype} leaks from "
                         f"`{o.primitive}` (shape {list(o.shape)})")))
    # collect from every equation, not just outvals: effect-only
    # primitives like debug_callback bind zero outputs
    prims = jaxpr_primitives(closed.jaxpr)
    for p in sorted(prims & contract.forbid_primitives):
        findings.append(Finding(
            "jaxpr-callback", path, context=contract.name, snippet=p,
            message=f"host-callback primitive `{p}` in the trace"))

    if (contract.max_cache_growth is not None and spec.call is not None
            and hasattr(spec.jit_fn, "_cache_size")):
        spec.call()                       # first call may compile: allowed
        before = spec.jit_fn._cache_size()
        spec.call()
        spec.call()
        growth = spec.jit_fn._cache_size() - before
        if growth > contract.max_cache_growth:
            findings.append(Finding(
                "cache-growth", path, context=contract.name,
                snippet=f"growth={growth}",
                message=(f"repeat same-shape calls grew the jit cache by "
                         f"{growth} (> {contract.max_cache_growth})")))
    return findings


def check_all_contracts(names=None) -> List[Finding]:
    findings: List[Finding] = []
    for name, c in sorted(REGISTRY.items()):
        if names and name not in names:
            continue
        findings.extend(check_contract(c))
    return findings


# ------------------------------------------------------- shared tiny fixture

@functools.lru_cache(maxsize=None)
def _tiny_dataset():
    rng = np.random.default_rng(7)
    X = rng.standard_normal((N_TRACE, D_TRACE)).astype(np.float32)
    Q = rng.standard_normal((NQ_TRACE, D_TRACE)).astype(np.float32)
    return X, Q


@functools.lru_cache(maxsize=None)
def _tiny_index():
    import jax
    from repro.core.ivf import build_ivf
    from repro.core.search import pack_ivf
    X, _ = _tiny_dataset()
    idx = build_ivf(jax.random.PRNGKey(0), X, C_TRACE, spill_mode="soar",
                    pq_subspaces=8, train_iters=3)
    return idx, pack_ivf(idx)


@functools.lru_cache(maxsize=None)
def _tiny_mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]), ("a",))


# ------------------------------------------------------------ serving traces

@jaxpr_contract("search_jit", no_dims={"n"})
def _spec_search_jit():
    import jax.numpy as jnp
    from repro.core.search import search_jit
    _, Q = _tiny_dataset()
    _, packed = _tiny_index()
    jQ = jnp.asarray(Q)
    kw = dict(top_t=TOP_T, final_k=FINAL_K, rerank_budget=64,
              multiplicity=2)
    return TraceSpec(
        fn=lambda p, q: search_jit(p, q, **kw), args=(packed, jQ),
        dims={"n": N_TRACE}, jit_fn=search_jit,
        call=lambda: search_jit(packed, jQ, **kw))


@jaxpr_contract("search_jit_batched", no_dims={"n"})
def _spec_search_jit_batched():
    import jax.numpy as jnp
    from repro.core.search import pad_queries, search_jit_batched
    _, Q = _tiny_dataset()
    _, packed = _tiny_index()
    Qp, _, bq = pad_queries(Q, 128)
    jQ = jnp.asarray(Qp)
    kw = dict(top_t=TOP_T, final_k=FINAL_K, rerank_budget=64,
              multiplicity=2, bq=bq)
    return TraceSpec(
        fn=lambda p, q: search_jit_batched(p, q, **kw), args=(packed, jQ),
        dims={"n": N_TRACE}, jit_fn=search_jit_batched,
        call=lambda: search_jit_batched(packed, jQ, **kw))


@jaxpr_contract("search_jit_batched_filtered", no_dims={"n"})
def _spec_search_jit_batched_filtered():
    import jax.numpy as jnp
    from repro.core.search import pad_queries, search_jit_batched
    _, Q = _tiny_dataset()
    _, packed = _tiny_index()
    rng = np.random.default_rng(3)
    filt = jnp.asarray((rng.random(N_TRACE) < 0.3).astype(np.uint8))
    Qp, _, bq = pad_queries(Q, 128)
    jQ = jnp.asarray(Qp)
    kw = dict(top_t=TOP_T, final_k=FINAL_K, rerank_budget=64,
              multiplicity=2, bq=bq, escalate=True)
    return TraceSpec(
        fn=lambda p, q, f: search_jit_batched(p, q, filter=f, **kw),
        args=(packed, jQ, filt), dims={"n": N_TRACE},
        jit_fn=search_jit_batched,
        call=lambda: search_jit_batched(packed, jQ, filter=filt, **kw))


@jaxpr_contract("tree_route")
def _spec_tree_route():
    import jax.numpy as jnp
    from repro.kernels.tree_route import tree_route
    rng = np.random.default_rng(11)
    S, cmax = 5, 17
    SC = jnp.asarray(rng.standard_normal((S, D_TRACE)), jnp.float32)
    CC = jnp.asarray(rng.standard_normal((S, cmax, D_TRACE)), jnp.float32)
    CH = jnp.asarray(rng.integers(0, S * cmax, (S, cmax)), jnp.int32)
    _, Q = _tiny_dataset()
    jQ = jnp.asarray(Q)
    from repro.kernels.tree_route import tree_route_ref
    return TraceSpec(
        fn=lambda q, sc, cc, ch: tree_route(q, sc, cc, ch, t_route=2),
        args=(jQ, SC, CC, CH), dims={}, jit_fn=tree_route_ref,
        call=lambda: tree_route(jQ, SC, CC, CH, t_route=2))


# -------------------------------------------------------------- build traces

@jaxpr_contract("lloyd_sweep", no_dims_1d={"n"}, no_products={"n*c"})
def _spec_lloyd_sweep():
    import jax.numpy as jnp
    from repro.kernels.lloyd import lloyd_sweep
    X, _ = _tiny_dataset()
    rng = np.random.default_rng(5)
    C = jnp.asarray(X[rng.choice(N_TRACE, C_TRACE, replace=False)])
    jX = jnp.asarray(X)
    return TraceSpec(
        fn=lambda x, c: lloyd_sweep(x, c, C_TRACE, chunk=512),
        args=(jX, C), dims={"n": N_TRACE, "c": C_TRACE}, jit_fn=lloyd_sweep,
        call=lambda: lloyd_sweep(jX, C, C_TRACE, chunk=512))


@jaxpr_contract("assign_fused", no_dims_1d={"n"}, no_products={"n*c"})
def _spec_assign_fused():
    import jax.numpy as jnp
    from repro.kernels.soar_assign import assign_fused
    X, _ = _tiny_dataset()
    rng = np.random.default_rng(6)
    C = jnp.asarray(X[rng.choice(N_TRACE, C_TRACE, replace=False)])
    jX = jnp.asarray(X)
    return TraceSpec(
        fn=lambda x, c: assign_fused(x, c, lam=1.0, n_spills=1, chunk=512),
        args=(jX, C), dims={"n": N_TRACE, "c": C_TRACE},
        call=lambda: assign_fused(jX, C, lam=1.0, n_spills=1, chunk=512))


@jaxpr_contract("pq_encode", no_products={"2*n*d"})
def _spec_pq_encode():
    # threshold 2·n·d: the streamed encoder's largest legitimate buffers
    # are O(n·d) views of X (codes are n·m ≪ n·d); a dense all-subspace
    # distance matrix (n, m, 16) = 8·n·d trips the bound
    import jax.numpy as jnp
    from repro.quant.pq import pq_encode
    idx, _ = _tiny_index()
    X, _ = _tiny_dataset()
    jX = jnp.asarray(X)
    cb = idx.pq
    return TraceSpec(
        fn=lambda c, x: pq_encode(c, x, chunk=512), args=(cb, jX),
        dims={"n": N_TRACE, "d": D_TRACE}, jit_fn=pq_encode,
        call=lambda: pq_encode(cb, jX, chunk=512))


# -------------------------------------------------------- distributed makers

@jaxpr_contract("distributed_search", no_dims={"n"})
def _spec_distributed_search():
    import jax
    import jax.numpy as jnp
    from repro.core.distributed import build_sharded_ivf, \
        make_distributed_search
    X, Q = _tiny_dataset()
    sivf = build_sharded_ivf(jax.random.PRNGKey(2), X, 1, C_TRACE,
                             train_iters=3)
    fn = make_distributed_search(_tiny_mesh(), ("a",), top_t=TOP_T,
                                 final_k=FINAL_K, multiplicity=2)
    return TraceSpec(fn=fn, args=(sivf, jnp.asarray(Q)),
                     dims={"n": N_TRACE})


@jaxpr_contract("distributed_search_pq", no_dims={"n"})
def _spec_distributed_search_pq():
    import jax
    import jax.numpy as jnp
    from repro.core.distributed import build_sharded_ivf_pq, \
        make_distributed_search_pq
    X, Q = _tiny_dataset()
    sivf = build_sharded_ivf_pq(jax.random.PRNGKey(2), X, 1, C_TRACE, 8,
                                train_iters=3)
    fn = make_distributed_search_pq(_tiny_mesh(), ("a",), top_t=TOP_T,
                                    final_k=FINAL_K, rerank_k=32,
                                    q_chunk=NQ_TRACE, multiplicity=2)
    return TraceSpec(fn=fn, args=(sivf, jnp.asarray(Q)),
                     dims={"n": N_TRACE})


@jaxpr_contract("replicated_search", no_dims={"n"})
def _spec_replicated_search():
    import jax.numpy as jnp
    from repro.core.distributed import make_replicated_search
    _, Q = _tiny_dataset()
    _, packed = _tiny_index()
    fn = make_replicated_search(_tiny_mesh(), ("a",), top_t=TOP_T,
                                final_k=FINAL_K, rerank_budget=64,
                                multiplicity=2)
    return TraceSpec(fn=fn, args=(packed, jnp.asarray(Q)),
                     dims={"n": N_TRACE})


@jaxpr_contract("sharded_assign", no_dims_1d={"n"}, no_products={"n*c"})
def _spec_sharded_assign():
    import jax.numpy as jnp
    from repro.core.distributed import make_sharded_assign
    X, _ = _tiny_dataset()
    rng = np.random.default_rng(8)
    C = jnp.asarray(X[rng.choice(N_TRACE, C_TRACE, replace=False)])
    # shard_map in_specs require the sharded rows divisible by the mesh
    # axis (size 1 here) — N_TRACE prime is fine on the 1-device mesh
    fn = make_sharded_assign(_tiny_mesh(), ("a",), chunk=512)
    return TraceSpec(fn=fn, args=(jnp.asarray(X), C),
                     dims={"n": N_TRACE, "c": C_TRACE})
