"""Finding model + ratchet baseline for the static contract analyzer.

Every analysis pass (jaxpr contracts, recompile sentinel, AST lints)
reports `Finding`s. A finding's identity is its *fingerprint* — a hash of
(rule, path, context, snippet) that deliberately excludes line numbers, so
unrelated edits that shift a grandfathered violation down the file don't
resurrect it. The committed baseline (`baseline.json`, next to this
module) is the ratchet: fingerprints listed there are reported but don't
fail the build; anything new does (DESIGN.md §3.14).
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass, field, asdict
from typing import Iterable, List, Optional

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")


@dataclass(frozen=True)
class Finding:
    """One violation from one pass.

    rule:    stable rule id ("jaxpr-dim", "cache-growth", "lock-discipline",
             "falsy-int-default", "np-random-global", "pickle-ckpt",
             "validate-routing", ...).
    path:    repo-relative file path, or "contract:<name>" /
             "sentinel:<name>" for non-file findings.
    line:    1-based line for file findings, 0 otherwise (display only —
             not part of the fingerprint).
    context: enclosing scope: function qualname for lints, the traced
             entry point for contracts.
    snippet: the offending source fragment / shape / dtype — the part of
             the identity that survives reformatting around it.
    """
    rule: str
    path: str
    message: str
    line: int = 0
    context: str = ""
    snippet: str = ""

    @property
    def fingerprint(self) -> str:
        key = "|".join((self.rule, self.path, self.context, self.snippet))
        return hashlib.sha256(key.encode()).hexdigest()[:16]

    def render(self, grandfathered: bool = False) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        tag = " [grandfathered]" if grandfathered else ""
        ctx = f" (in {self.context})" if self.context else ""
        return f"{loc}: {self.rule}: {self.message}{ctx}{tag}"

    def to_dict(self) -> dict:
        d = asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


@dataclass
class Baseline:
    """The committed ratchet file: grandfathered fingerprints."""
    fingerprints: set = field(default_factory=set)
    entries: list = field(default_factory=list)

    def __contains__(self, f) -> bool:
        fp = f.fingerprint if isinstance(f, Finding) else f
        return fp in self.fingerprints


def load_baseline(path: Optional[str] = None) -> Baseline:
    path = path or BASELINE_PATH
    if not os.path.exists(path):
        return Baseline()
    with open(path) as fh:
        data = json.load(fh)
    entries = data.get("grandfathered", [])
    return Baseline({e["fingerprint"] for e in entries}, entries)


def save_baseline(findings: Iterable[Finding],
                  path: Optional[str] = None) -> None:
    """Rewrite the ratchet to grandfather exactly `findings`. Used by
    `python -m repro.analysis.check --update-baseline` after a deliberate
    decision to allowlist (rather than fix) surviving violations."""
    path = path or BASELINE_PATH
    entries = sorted(
        ({"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
          "context": f.context, "message": f.message} for f in findings),
        key=lambda e: (e["rule"], e["path"], e["fingerprint"]))
    with open(path, "w") as fh:
        json.dump({"version": 1, "grandfathered": entries}, fh, indent=2)
        fh.write("\n")


def partition_findings(findings: Iterable[Finding],
                       baseline: Baseline) -> tuple:
    """→ (new, grandfathered): only `new` fails the build."""
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        (old if f in baseline else new).append(f)
    return new, old
