"""Shared recursive jaxpr walker (DESIGN.md §3.14).

The single source of truth for "what does this trace materialize" —
previously three copy-pasted `_jaxpr_shapes` helpers in
tests/test_search_pipeline.py, tests/test_filtered_search.py and (by
import) tests/test_build_perf.py. The walker recurses into every nested
jaxpr an equation carries: pjit/scan/while bodies (`params["jaxpr"]`),
cond branches (`params["branches"]` — a tuple, which the old helpers
missed), and pallas_call kernels.

`jaxpr_shapes` keeps the original helper's contract (list of
equation-output shapes, recursively) so the migrated test assertions are
unchanged-or-stronger; `jaxpr_outvals` is the richer record the contract
checker consumes (primitive, shape, dtype per output).
"""
from __future__ import annotations

from typing import Any, Iterator, List, NamedTuple, Set, Tuple


class OutVal(NamedTuple):
    """One equation output: the primitive that produced it + its aval."""
    primitive: str
    shape: Tuple[int, ...]
    dtype: str


def _as_jaxpr(j):
    """ClosedJaxpr → Jaxpr; Jaxpr passes through."""
    return getattr(j, "jaxpr", j)


def _sub_jaxprs(param: Any) -> Iterator[Any]:
    """Nested jaxprs inside one equation param value. Params hold
    ClosedJaxprs (pjit/scan), bare Jaxprs (pallas_call grids), and tuples
    of either (cond branches)."""
    if isinstance(param, (tuple, list)):
        for p in param:
            yield from _sub_jaxprs(p)
    elif hasattr(param, "jaxpr"):          # ClosedJaxpr
        yield param.jaxpr
    elif hasattr(param, "eqns"):           # bare Jaxpr
        yield param


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Every equation in the (closed) jaxpr, depth-first into nested
    sub-jaxprs."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            for sub in _sub_jaxprs(p):
                yield from iter_eqns(sub)


def jaxpr_shapes(jaxpr) -> List[Tuple[int, ...]]:
    """All equation-output shapes in a (closed) jaxpr, recursively — the
    shared replacement for the test-side `_jaxpr_shapes` helpers."""
    return [tuple(v.aval.shape) for eqn in iter_eqns(jaxpr)
            for v in eqn.outvars if hasattr(v.aval, "shape")]


def jaxpr_outvals(jaxpr) -> List[OutVal]:
    """(primitive, shape, dtype) for every equation output, recursively."""
    out: List[OutVal] = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        for v in eqn.outvars:
            aval = v.aval
            if hasattr(aval, "shape"):
                dt = str(getattr(aval, "dtype", ""))
                out.append(OutVal(name, tuple(aval.shape), dt))
    return out


def jaxpr_primitives(jaxpr) -> Set[str]:
    """Set of primitive names appearing anywhere in the trace."""
    return {eqn.primitive.name for eqn in iter_eqns(jaxpr)}
