"""Repo-specific AST lints (stdlib `ast`, no new deps) — DESIGN.md §3.14.

Rules (library code under src/repro only; tests/benchmarks are exempt):

  lock-discipline    serve/: a `self.*_locked(...)` call must happen
                     lexically under `with self._lock:` / `with
                     self._cond:` (or inside another `*_locked` method —
                     the caller-holds-the-lock convention of
                     serve/frontend.py).
  falsy-int-default  `x or <numeric default>` coalescing on an int param
                     treats an explicit 0 as "unset" — the
                     `top_t or self.top_t` bug class PR 7 fixed. Use
                     `if x is None` sentinels.
  np-random-global   `np.random.<fn>()` global-state RNG in library code
                     (only `default_rng`/`Generator`/`SeedSequence` are
                     allowed — reproducibility requires threaded keys).
  pickle-ckpt        ckpt/: pickle-family imports or
                     `allow_pickle=True` — the durability layer's framing
                     is self-describing arrays + JSON, never pickle
                     (§3.11: untrusted snapshots must not execute code).
  validate-routing   serve/: engine-edge entry points (search /
                     search_request / retrieve / retrieve_request /
                     submit) must reach `SearchParams.validate()` —
                     directly or through a self-call chain.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set

from repro.analysis.findings import Finding

ENTRY_POINT_NAMES = {"search", "search_request", "retrieve",
                     "retrieve_request", "submit"}
LOCK_ATTRS = {"_lock", "_cond"}
ALLOWED_NP_RANDOM = {"default_rng", "Generator", "SeedSequence", "PCG64",
                     "Philox", "bit_generator"}
PICKLE_MODULES = {"pickle", "cPickle", "dill", "shelve"}
NUMERIC_CALL_NAMES = {"max", "min", "int", "len", "round", "abs"}


def _seg(src: str, node: ast.AST) -> str:
    return (ast.get_source_segment(src, node) or "").strip()


def _is_self_attr(node: ast.AST, attrs: Set[str]) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self" and node.attr in attrs)


# identifier fragments that mark a name as integer-like — `top_t or
# self.top_t` (the PR 7 bug, verbatim) must trip the rule even though the
# fallback is a bare attribute rather than a literal
INT_NAME_HINTS = ("top_t", "t_route", "head_dim", "n_partitions", "chunk",
                  "budget", "batch", "bq", "pmax", "n_local", "n_spills",
                  "capacity", "n_heads", "seq", "iters", "steps", "size",
                  "count", "width", "depth")


def _int_like_name(name: str) -> bool:
    n = name.lower()
    return n in ("k", "n", "c", "d", "m") or any(h in n
                                                 for h in INT_NAME_HINTS)


def _is_numeric_default(node: ast.AST) -> bool:
    """Does this `or`-fallback look like an integer default? int literals,
    arithmetic, max()/min()/int()/len() calls, unary minus thereof, or an
    int-like-named name/attribute (the `x or self.x` shape)."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, int) and not isinstance(node.value,
                                                              bool)
    if isinstance(node, ast.UnaryOp):
        return _is_numeric_default(node.operand)
    if isinstance(node, ast.BinOp):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        name = f.id if isinstance(f, ast.Name) else (
            f.attr if isinstance(f, ast.Attribute) else "")
        return name in NUMERIC_CALL_NAMES
    if isinstance(node, ast.Attribute):
        return _int_like_name(node.attr)
    if isinstance(node, ast.Name):
        return _int_like_name(node.id)
    return False


class _FunctionStack(ast.NodeVisitor):
    """Base visitor tracking the enclosing function qualname."""

    def __init__(self) -> None:
        self.stack: List[str] = []

    @property
    def context(self) -> str:
        return ".".join(self.stack)

    def _walk_fn(self, node) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_FunctionDef(self, node):          # noqa: N802
        self._walk_fn(node)

    def visit_AsyncFunctionDef(self, node):     # noqa: N802
        self._walk_fn(node)

    def visit_ClassDef(self, node):             # noqa: N802
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()


class _LockDiscipline(_FunctionStack):
    def __init__(self, src: str, relpath: str) -> None:
        super().__init__()
        self.src, self.relpath = src, relpath
        self.lock_depth = 0
        self.findings: List[Finding] = []

    def visit_With(self, node):                 # noqa: N802
        held = any(_is_self_attr(item.context_expr, LOCK_ATTRS)
                   for item in node.items)
        self.lock_depth += held
        self.generic_visit(node)
        self.lock_depth -= held

    def visit_Call(self, node):                 # noqa: N802
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr.endswith("_locked")
                and isinstance(f.value, ast.Name) and f.value.id == "self"
                and self.lock_depth == 0
                and not (self.stack and self.stack[-1].endswith("_locked"))):
            self.findings.append(Finding(
                "lock-discipline", self.relpath, line=node.lineno,
                context=self.context, snippet=_seg(self.src, node),
                message=(f"`self.{f.attr}()` called without holding "
                         f"self._lock/self._cond")))
        self.generic_visit(node)


class _FalsyIntDefault(_FunctionStack):
    def __init__(self, src: str, relpath: str) -> None:
        super().__init__()
        self.src, self.relpath = src, relpath
        self.findings: List[Finding] = []

    def visit_BoolOp(self, node):               # noqa: N802
        if (isinstance(node.op, ast.Or) and len(node.values) == 2
                and isinstance(node.values[0], (ast.Name, ast.Attribute))
                and _is_numeric_default(node.values[1])):
            self.findings.append(Finding(
                "falsy-int-default", self.relpath, line=node.lineno,
                context=self.context, snippet=_seg(self.src, node),
                message=("`or`-coalescing on an integer param treats an "
                         "explicit 0 as unset — use an `is None` "
                         "sentinel")))
        self.generic_visit(node)


class _NpRandomGlobal(_FunctionStack):
    def __init__(self, src: str, relpath: str) -> None:
        super().__init__()
        self.src, self.relpath = src, relpath
        self.findings: List[Finding] = []

    def visit_Attribute(self, node):            # noqa: N802
        # np.random.X  /  numpy.random.X
        v = node.value
        if (isinstance(v, ast.Attribute) and v.attr == "random"
                and isinstance(v.value, ast.Name)
                and v.value.id in ("np", "numpy")
                and node.attr not in ALLOWED_NP_RANDOM):
            self.findings.append(Finding(
                "np-random-global", self.relpath, line=node.lineno,
                context=self.context, snippet=_seg(self.src, node),
                message=(f"global-state RNG `np.random.{node.attr}` in "
                         f"library code — use np.random.default_rng / "
                         f"jax PRNG keys")))
        self.generic_visit(node)


class _PickleInCkpt(_FunctionStack):
    def __init__(self, src: str, relpath: str) -> None:
        super().__init__()
        self.src, self.relpath = src, relpath
        self.findings: List[Finding] = []

    def _flag(self, node, what: str) -> None:
        self.findings.append(Finding(
            "pickle-ckpt", self.relpath, line=node.lineno,
            context=self.context, snippet=_seg(self.src, node),
            message=(f"{what} in the durability layer — snapshots/WAL "
                     f"must stay self-describing arrays + JSON "
                     f"(§3.11), never executable payloads")))

    def visit_Import(self, node):               # noqa: N802
        for a in node.names:
            if a.name.split(".")[0] in PICKLE_MODULES:
                self._flag(node, f"`import {a.name}`")

    def visit_ImportFrom(self, node):           # noqa: N802
        if node.module and node.module.split(".")[0] in PICKLE_MODULES:
            self._flag(node, f"`from {node.module} import ...`")

    def visit_Call(self, node):                 # noqa: N802
        for kw in node.keywords:
            if (kw.arg == "allow_pickle"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                self._flag(node, "`allow_pickle=True`")
        self.generic_visit(node)


def _method_calls_and_validate(fn_node) -> tuple:
    """(self-method names called, does the body call `.validate(...)`)."""
    calls: Set[str] = set()
    validates = False
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute):
            if node.func.attr == "validate":
                validates = True
            if (isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"):
                calls.add(node.func.attr)
    return calls, validates


def _check_validate_routing(tree, src: str, relpath: str) -> List[Finding]:
    findings: List[Finding] = []
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        methods = {m.name: m for m in cls.body
                   if isinstance(m, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))}
        entries = [m for name, m in methods.items()
                   if name in ENTRY_POINT_NAMES]
        if not entries:
            continue
        graph: Dict[str, Set[str]] = {}
        validates: Dict[str, bool] = {}
        for name, m in methods.items():
            graph[name], validates[name] = _method_calls_and_validate(m)
        for m in entries:
            seen, todo = set(), [m.name]
            ok = False
            while todo:
                cur = todo.pop()
                if cur in seen or cur not in methods:
                    continue
                seen.add(cur)
                if validates[cur]:
                    ok = True
                    break
                todo.extend(graph[cur])
            if not ok:
                findings.append(Finding(
                    "validate-routing", relpath, line=m.lineno,
                    context=f"{cls.name}.{m.name}",
                    snippet=f"def {m.name}",
                    message=(f"engine-edge entry point `{cls.name}."
                             f"{m.name}` never reaches SearchParams."
                             f"validate() — the single hardened "
                             f"validation path (§3.12)")))
    return findings


def lint_source(src: str, relpath: str) -> List[Finding]:
    """Lint one module's source. `relpath` (repo-relative, '/'-separated)
    selects which rules apply."""
    relpath = relpath.replace(os.sep, "/")
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("syntax-error", relpath,
                        line=(0 if e.lineno is None else e.lineno),
                        message=str(e))]
    findings: List[Finding] = []
    in_src = relpath.startswith("src/repro/")
    if in_src:
        for visitor_cls in (_FalsyIntDefault, _NpRandomGlobal):
            v = visitor_cls(src, relpath)
            v.visit(tree)
            findings.extend(v.findings)
    if relpath.startswith("src/repro/serve/"):
        v = _LockDiscipline(src, relpath)
        v.visit(tree)
        findings.extend(v.findings)
        findings.extend(_check_validate_routing(tree, src, relpath))
    if relpath.startswith("src/repro/ckpt/"):
        v = _PickleInCkpt(src, relpath)
        v.visit(tree)
        findings.extend(v.findings)
    return findings


def lint_paths(root: str, paths: Optional[List[str]] = None
               ) -> List[Finding]:
    """Lint every library module under `root` (or just `paths`,
    repo-relative)."""
    findings: List[Finding] = []
    if paths is None:
        paths = []
        src_root = os.path.join(root, "src", "repro")
        for dirpath, _, files in os.walk(src_root):
            for f in sorted(files):
                if f.endswith(".py"):
                    paths.append(os.path.relpath(os.path.join(dirpath, f),
                                                 root))
    for rel in sorted(paths):
        with open(os.path.join(root, rel)) as fh:
            findings.extend(lint_source(fh.read(), rel))
    return findings
