"""Recompile sentinel: a registry of jit caches + a canonical mixed-traffic
serving workload that must add ZERO cache entries (DESIGN.md §3.14).

The repo's serving invariant since PR 5/8: trace-shape bucketing
(`pad_queries`) and batch-key coalescing mean that once the buckets a
deployment serves are warm, NO arrival pattern — varied nq, tenants,
inline filters, escalation, mutation cadence — compiles anything new.
Individual tests pin slices of this (`_cache_size()` before/after); the
sentinel is the exhaustive version: snapshot every registered jit cache,
drive the canonical workload through a real ServingFrontend, and report
any growth as findings.

`CacheWatch` is the reusable context-manager form the per-test pins
migrate onto:

    with CacheWatch(search_jit_batched):
        ... arbitrary serving traffic ...
    # raises AssertionError on exit if the cache grew
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.analysis.findings import Finding

# name → "module:attr" for every jit entry point that owns a cache worth
# watching. Resolved lazily so importing the sentinel costs nothing.
JIT_ENTRY_POINTS: Dict[str, str] = {
    "search_jit": "repro.core.search:search_jit",
    "search_jit_batched": "repro.core.search:search_jit_batched",
    "lloyd_sweep": "repro.kernels.lloyd:lloyd_sweep",
    "lloyd_sweep_batched": "repro.kernels.lloyd:lloyd_sweep_batched",
    "assign_fused_gemm": "repro.kernels.soar_assign:_fused_assign_gemm",
    "tree_route_ref": "repro.kernels.tree_route:tree_route_ref",
    "pq_encode": "repro.quant.pq:pq_encode",
    "pq_lut": "repro.quant.pq:pq_lut",
}


def resolve_entry_points(names=None) -> Dict[str, Callable]:
    import importlib
    out: Dict[str, Callable] = {}
    for name, ref in JIT_ENTRY_POINTS.items():
        if names and name not in names:
            continue
        mod, attr = ref.split(":")
        fn = getattr(importlib.import_module(mod), attr)
        if hasattr(fn, "_cache_size"):
            out[name] = fn
    return out


def cache_size(fn) -> int:
    return int(fn._cache_size())


def snapshot_caches(fns: Optional[Dict[str, Callable]] = None
                    ) -> Dict[str, int]:
    fns = fns if fns is not None else resolve_entry_points()
    return {name: cache_size(fn) for name, fn in fns.items()}


def cache_growth(before: Dict[str, int],
                 after: Dict[str, int]) -> Dict[str, tuple]:
    return {name: (before[name], after[name])
            for name in before if after.get(name, 0) > before[name]}


class CacheWatch:
    """Assert zero jit-cache growth across a block.

    `CacheWatch(fn, ...)` watches the given jit wrappers (anything with
    `_cache_size()`); with no args it watches the full registry. On exit
    (without a pending exception) it raises AssertionError naming every
    grown cache — the shared replacement for the per-test
    before/after `_cache_size()` pins."""

    def __init__(self, *fns, allowed_growth: int = 0):
        if fns:
            self.fns = {getattr(f, "__name__", f"fn{i}"): f
                        for i, f in enumerate(fns)}
        else:
            self.fns = resolve_entry_points()
        self.allowed_growth = allowed_growth
        self.before: Dict[str, int] = {}

    def __enter__(self) -> "CacheWatch":
        self.before = snapshot_caches(self.fns)
        return self

    def growth(self) -> Dict[str, tuple]:
        return cache_growth(self.before, snapshot_caches(self.fns))

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            return False
        grown = {name: (b, a) for name, (b, a) in self.growth().items()
                 if a - b > self.allowed_growth}
        if grown:
            raise AssertionError(
                "jit cache grew during watched block: " + ", ".join(
                    f"{name} {b}->{a}" for name, (b, a) in grown.items()))
        return False


# --------------------------------------------------- canonical workload

def run_serving_workload(verbose: bool = False) -> List[Finding]:
    """Drive the canonical mixed-traffic serving workload and return a
    cache-growth finding per jit entry point that recompiled.

    Phases:
      1. build a small engine + front-end, register two tenants;
      2. warm every trace class a deployment serves — both power-of-two
         buckets, the pure-unfiltered trace, tenant/standing/inline
         filtered traces with escalation on AND off, and the mutation
         cadence (an overflow-sized add forces one capacity growth so
         later small adds stay inside the grown headroom, exactly the
         delta-pack contract of DESIGN.md §3.8);
      3. snapshot every registered jit cache;
      4. the measured phase: concurrent clients with varied nq, rotating
         tenants, inline bitmaps, escalation toggles, and interleaved
         add/soft-remove barriers;
      5. any cache growth is a finding.
    """
    import threading

    import jax
    import numpy as np

    from repro.serve.api import SearchParams
    from repro.serve.engine import AnnEngine
    from repro.serve.frontend import ServingFrontend

    rng = np.random.default_rng(0)
    n, d = 2_000, 16
    X = rng.standard_normal((n, d)).astype(np.float32)
    Q = rng.standard_normal((32, d)).astype(np.float32)
    engine = AnnEngine.build(jax.random.PRNGKey(1), X, 16,
                             spill_mode="soar", train_iters=4)

    findings: List[Finding] = []
    with ServingFrontend(engine, policy="local", max_batch=16,
                         default_deadline_ms=10_000.0) as fe:
        fe.register_tenant("t0", ids=np.arange(0, n, 2))
        fe.register_tenant("t1", ids=np.arange(1, n, 2))

        # -- warmup: every trace class the measured phase will touch
        for nq in (1, 9):                       # buckets 8 and 16
            fe.search(Q[:nq], SearchParams(k=5))        # pure unfiltered
        # mutation cadence: force the one legitimate capacity growth now
        ids = fe.add(rng.standard_normal((400, d)).astype(np.float32))
        fe.remove(ids[:8], hard=False)          # standing tombstone filter
        # the incremental-assign path right-sizes its chunk to the add
        # batch (§3.8), so each distinct mutation batch size traces once:
        # warm the cadence size the measured phase uses
        fe.add(rng.standard_normal((2, d)).astype(np.float32))
        for nq in (1, 9):
            fe.search(Q[:nq], SearchParams(k=5))        # standing-filter
            for tenant in ("t0", "t1"):
                fe.search(Q[:nq], SearchParams(k=5, tenant=tenant))
                fe.search(Q[:nq], SearchParams(k=5, tenant=tenant,
                                               escalate=False))
        mask = (rng.random(engine.index.n_total) < 0.5).astype(np.uint8)
        fe.search(Q[:3], SearchParams(k=5, filter_mask=mask))   # inline
        fe.search(Q[:3], SearchParams(k=5, filter_mask=mask,
                                      escalate=False))
        fe.flush()

        # -- snapshot, then the measured mixed-traffic phase
        fns = resolve_entry_points()
        before = snapshot_caches(fns)
        tenants = (None, "t0", "t1", None, "t1", "t0")

        def client(i: int) -> None:
            nq = 1 + (i % 13)                   # both buckets, all sizes
            p = SearchParams(k=5, tenant=tenants[i % len(tenants)],
                             escalate=(i % 3 != 0))
            fe.search(Q[i % 16:i % 16 + nq], p)

        for wave in range(3):
            threads = [threading.Thread(target=client, args=(wave * 12 + j,))
                       for j in range(12)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            # mutation barriers between waves: small adds stay inside the
            # grown capacity headroom; soft removes never move data
            new = fe.add(rng.standard_normal((2, d)).astype(np.float32))
            fe.remove(new[:1], hard=False)
        m2 = (rng.random(engine.index.n_total) < 0.4).astype(np.uint8)
        fe.search(Q[:5], SearchParams(k=5, filter_mask=m2))
        fe.flush()
        after = snapshot_caches(fns)
        stats = dict(fe.stats)

    for name, (b, a) in cache_growth(before, after).items():
        findings.append(Finding(
            "cache-growth", "sentinel:serving-workload", context=name,
            snippet=f"{name}", line=0,
            message=(f"canonical serving workload grew {name}'s jit cache "
                     f"{b}->{a} — a trace class escaped the warmup "
                     f"buckets (recompile storm risk in serving)")))
    if verbose:
        print(f"[sentinel] requests={stats.get('requests')} "
              f"dispatches={stats.get('dispatches')} "
              f"coalesced={stats.get('coalesced')} caches={after}")
    return findings
