"""Durable state: train-loop checkpoints (checkpoint.py) and the index
lifecycle substrate (index_store.py snapshots + wal.py mutation log +
faults.py crash injection) — DESIGN.md §3.11."""
from repro.ckpt.index_store import (CorruptSnapshotError, load_shards,
                                    load_snapshot, save_shards,
                                    save_snapshot)
from repro.ckpt.wal import MutationWAL

__all__ = ["CorruptSnapshotError", "MutationWAL", "load_shards",
           "load_snapshot", "save_shards", "save_snapshot"]
