"""Atomic, retention-managed checkpointing with elastic resharding.

Format: one .npz with flattened leaves keyed by pytree path + meta.json
(step, leaf names). Saves go to a tmp dir then os.rename (atomic on POSIX) —
a preempted save never corrupts the latest checkpoint.

Elastic resharding: restore() takes target shardings (or a template) and
device_puts each leaf — a checkpoint written on one mesh restores onto any
other mesh shape (tested in tests/test_checkpoint.py with different host
device counts).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

from repro.ckpt.index_store import atomic_replace_dir, resolve_snapshot_dir


def _leaf_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        name = jax.tree_util.keystr(path)
        names.append(re.sub(r"[^A-Za-z0-9_.\-]", "_", name))
    assert len(set(names)) == len(names), "non-unique leaf names"
    return names


def save(path: str, tree: Any, step: int = 0, extra: Optional[dict] = None):
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = _leaf_names(tree)
    arrays = {}
    dtypes = {}
    for n, (_, leaf) in zip(names, flat):
        a = np.asarray(leaf)
        dtypes[n] = str(a.dtype)
        if a.dtype.name == "bfloat16":   # numpy can't serialize ml_dtypes
            a = a.view(np.uint16)
        arrays[n] = a
    np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "names": names, "dtypes": dtypes,
                   "extra": extra or {}}, f)
        f.flush()
        os.fsync(f.fileno())
    # the old rmtree(path)-then-rename left a window with NO copy on disk
    # (crash after the rmtree loses the only checkpoint); the rename-aside
    # swap keeps a committed copy at every crash point, and restore()
    # finishes an interrupted swap from <path>.old
    atomic_replace_dir(tmp, path)


def restore(path: str, template: Any, shardings: Any = None):
    """Rebuild `template`'s pytree from disk; optionally device_put with new
    shardings (elastic re-mesh)."""
    path = resolve_snapshot_dir(path)
    if not os.path.isdir(path):
        raise FileNotFoundError(f"no checkpoint directory at {path}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    names = _leaf_names(template)
    assert names == meta["names"], "checkpoint/template structure mismatch"
    import ml_dtypes
    leaves = []
    for n in names:
        a = data[n]
        if meta.get("dtypes", {}).get(n) == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        leaves.append(a)
    _, treedef = jax.tree_util.tree_flatten(template)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta["step"], meta["extra"]


class CheckpointManager:
    """step-numbered checkpoints under a directory, keeping the newest N."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        # retention must never delete the checkpoint that was just
        # written — keep < 1 would do exactly that
        self.keep = max(1, int(keep))
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}")

    def steps(self):
        """Committed steps, sorted. Stray entries (foo/, ckpt_abc,
        ckpt_N.tmp) are ignored; a checkpoint surviving only as
        ckpt_N.old (crash mid-swap) counts — restore() finishes the
        swap."""
        out = set()
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"ckpt_(\d+)(\.old)?", name)
            if m:
                out.add(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree, extra=None):
        save(self._path(step), tree, step=step, extra=extra)
        for old in self.steps()[:-self.keep]:
            if old == step:      # an out-of-order save of an old step is
                continue         # still the newest write — never drop it
            for p in (self._path(old), self._path(old) + ".old"):
                if os.path.isdir(p):
                    shutil.rmtree(p)

    def restore(self, template, step: Optional[int] = None, shardings=None):
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoints under {self.dir}")
        elif step not in self.steps():
            have = self.steps()
            raise FileNotFoundError(
                f"no checkpoint for step {step} under {self.dir} "
                f"(have steps {have})" if have else
                f"no checkpoint for step {step} under {self.dir} "
                f"(directory is empty)")
        return restore(self._path(step), template, shardings)

    # -------- train-state convenience (params + optimizer + data cursor)
    def save_train_state(self, step: int, params, opt_state):
        self.save(step, {"params": params, "opt": opt_state},
                  extra={"data_step": step})

    def restore_train_state(self, cfg, shardings=None):
        from repro.models import transformer as T
        from repro.train import optimizer as opt
        step = self.latest_step()
        params_t = T.abstract_params(cfg)
        # template with concrete leaves not needed: np arrays replace structs
        tmpl = {"params": params_t, "opt": None}
        # build an optimizer-state template lazily from the params template
        m = jax.tree.map(lambda s: s, params_t)
        tmpl["opt"] = opt.AdamWState(jax.ShapeDtypeStruct((), np.int32),
                                     m, jax.tree.map(lambda s: s, params_t))
        tree, step, extra = self.restore(tmpl, step, shardings)
        return tree["params"], tree["opt"], extra.get("data_step", step)
