"""Deterministic fault injection for the durable-index write paths
(DESIGN.md §3.11).

A durability layer is only as trustworthy as its crash matrix: every claim
of the form "a crash during X leaves a recoverable state" needs a test
that actually dies at X. This module is the single injection seam the
snapshot writer (index_store.py), the WAL appender (wal.py), and the
checkpoint commit (checkpoint.py) thread their writes and commit steps
through, so the recovery test suite (tests/test_durability.py) can
deterministically kill the process — or raise, for the fast in-process
matrix — at any byte offset of any file or at any named protocol step.

Two kinds of injection point:

- **byte-budget streams** — ``write(f, data, stream=NAME)``: when the
  installed plan targets ``NAME`` with a byte budget, exactly that many
  bytes of the stream are written (flushed + fsynced, so the on-disk
  prefix is what a real crash at that point would leave) and then the
  process dies. Stream names used by the writers:
  ``snapshot:arrays``, ``snapshot:manifest``, ``wal:append``.
- **named crash points** — ``crash_point(NAME)``: dies at the Nth hit of
  a protocol step. Points used: ``commit:between_renames``,
  ``commit:before_cleanup``, ``wal:record`` (after a full record is
  durable, before control returns).

Plan grammar (``install(spec)`` or env ``REPRO_FAULT`` for subprocesses):

    "snapshot:arrays+4096"        die after 4096 bytes of that stream
    "wal:append+100"              die after 100 bytes of a WAL append
    "commit:between_renames"      die at the 1st hit of that point
    "wal:record@3"                die at the 3rd hit

``REPRO_FAULT_MODE`` / ``mode=``: ``"raise"`` (default — raise
``InjectedCrash``, a BaseException so library ``except Exception``
blocks cannot swallow it) or ``"exit"`` (``os._exit``, a true crash: no
atexit handlers, no buffered-file flushes beyond what the writer already
forced).

Also home to the **corruption injectors** (``flip_byte``,
``truncate_tail``) the load-path tests use to assert that a damaged
snapshot or WAL surfaces ``CorruptSnapshotError`` instead of garbage
results.

Zero overhead when no plan is installed: the hot-path checks are a single
``is None`` test.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional


class InjectedCrash(BaseException):
    """Raised (mode="raise") at an injected crash point. BaseException on
    purpose: recovery code under test must never be able to catch this as
    an ordinary error and "handle" the crash away."""


@dataclass
class FaultPlan:
    point: str                      # stream or crash-point name
    after_bytes: int = -1           # >=0: byte budget for a stream target
    hits: int = 1                   # Nth hit of a named point
    mode: str = "raise"             # "raise" | "exit"
    _written: int = field(default=0, repr=False)
    _hit_count: int = field(default=0, repr=False)

    @classmethod
    def parse(cls, spec: str, mode: str = "raise") -> "FaultPlan":
        """Parse the plan grammar (module docstring)."""
        spec = spec.strip()
        if "+" in spec:
            name, _, nb = spec.rpartition("+")
            return cls(point=name, after_bytes=int(nb), mode=mode)
        if "@" in spec:
            name, _, n = spec.rpartition("@")
            return cls(point=name, hits=int(n), mode=mode)
        return cls(point=spec, mode=mode)


_PLAN: Optional[FaultPlan] = None


def install(spec: Optional[str] = None, mode: Optional[str] = None):
    """Install a fault plan. With no args, reads ``REPRO_FAULT`` /
    ``REPRO_FAULT_MODE`` from the environment (the subprocess tests'
    channel); no-op if neither is given."""
    global _PLAN
    if spec is None:
        spec = os.environ.get("REPRO_FAULT")
    if mode is None:
        mode = os.environ.get("REPRO_FAULT_MODE", "raise")
    if not spec:
        return None
    _PLAN = FaultPlan.parse(spec, mode=mode)
    return _PLAN


def uninstall():
    global _PLAN
    _PLAN = None


def active() -> Optional[FaultPlan]:
    return _PLAN


def _die(plan: FaultPlan):
    if plan.mode == "exit":
        os._exit(42)                 # a real crash: no cleanup of any kind
    raise InjectedCrash(plan.point)


def crash_point(name: str):
    """Named protocol step: dies when the installed plan targets `name`
    (point-style, not byte-budget) and this is the plan's Nth hit."""
    plan = _PLAN
    if plan is None or plan.after_bytes >= 0 or plan.point != name:
        return
    plan._hit_count += 1
    if plan._hit_count >= plan.hits:
        _die(plan)


def write(f, data: bytes, stream: str):
    """Byte-counted write through the injection seam. When the installed
    plan targets `stream` with a byte budget, writes exactly the budget's
    remaining bytes, forces them to disk (flush + fsync — the on-disk
    state must be the crash state, not "whatever the FILE* buffer held"),
    and dies."""
    plan = _PLAN
    if plan is None or plan.after_bytes < 0 or plan.point != stream:
        f.write(data)
        return
    remaining = plan.after_bytes - plan._written
    if len(data) < remaining or remaining < 0:
        f.write(data)
        plan._written += len(data)
        return
    f.write(data[:max(remaining, 0)])
    f.flush()
    os.fsync(f.fileno())
    _die(plan)


# ------------------------------------------------------------ corruption
def flip_byte(path: str, offset: int):
    """XOR one byte at `offset` (negative: from EOF) — the bit-rot
    injector for the load-path CRC tests."""
    with open(path, "r+b") as f:
        size = os.fstat(f.fileno()).st_size
        off = offset if offset >= 0 else size + offset
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))


def truncate_tail(path: str, nbytes: int):
    """Drop the last `nbytes` bytes — the torn-write injector."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(0, size - nbytes))
