"""Compat shim (DESIGN.md §3.13): the fault-injection seam started life
here as the durable-storage crash injector (PR 7); ISSUE 9 generalized
it with serving-side points (engine-raise, latency spikes, shard/replica
failures) and promoted it to ``repro.faults`` so the serving tier can
depend on it without reaching into ``ckpt``. All state lives in
``repro.faults`` — importing through this path shares the same installed
plans."""
from repro.faults import (FaultPlan, InjectedCrash, InjectedFault,
                          InjectedTransientFault, active, crash_point,
                          flip_byte, inject, install, serve_point,
                          truncate_tail, uninstall, write)

__all__ = ["FaultPlan", "InjectedCrash", "InjectedFault",
           "InjectedTransientFault", "active", "crash_point", "flip_byte",
           "inject", "install", "serve_point", "truncate_tail",
           "uninstall", "write"]
