"""Durable index snapshots: atomic, versioned, checksummed save/load for
every index object the serving stack holds in RAM (DESIGN.md §3.11).

Everything PRs 2-6 built — PackedIVF serving snapshots, MutableIVF
mutation state (tombstones, soft-delete bitmap, capacity-padded partition
arrays), trained TreeRouters, KNNMemory segment metadata — lived only in
process memory; a restart lost the build and every mutation since. This
module is the durability substrate: an index round-trips to disk and back
with **bitwise-identical search results** on both engines, and a damaged
snapshot (truncated file, flipped byte, torn manifest) is DETECTED at
load with a clear ``CorruptSnapshotError`` instead of silently serving
garbage neighbor ids.

Snapshot layout (format v1), one directory per snapshot::

    <path>/
      manifest.json   {"crc": <hex of the manifest body>, "manifest":
                       {format_version, kind, checksum_algo, meta,
                        arrays: [{name, dtype, shape, offset, nbytes,
                                  crc}, ...]}}
      arrays.bin      raw little-endian array bytes, 64-byte-aligned
                      offsets (mmap-friendly: the out-of-core tier maps
                      posting lists straight from this file)

Integrity: every array carries a CRC over its raw bytes, and the manifest
body carries its own CRC — a flipped byte anywhere fails loudly. The
checksum algorithm is recorded in the manifest: ``crc32c`` (Castagnoli)
when the optional ``crc32c`` wheel is present, else zlib's ``crc32``
(this container has no crc32c wheel; both are C-speed, and the manifest
records which one wrote the snapshot so a reader never verifies with the
wrong polynomial).

Atomicity: writes go to ``<path>.tmp-<pid>`` and commit via the
rename-aside protocol (``atomic_replace_dir``): fsync the tmp contents,
rename any existing snapshot to ``<path>.old``, rename tmp in, delete
old. A crash at ANY point leaves either the previous committed snapshot
(possibly under ``.old`` — ``resolve_snapshot_dir`` finishes the
interrupted swap at load time) or the new one, never a hybrid; the
crash-point matrix in tests/test_durability.py drives the writer through
``ckpt/faults.py`` to prove it.

Serialized kinds: ``IVFIndex``, ``MutableIVF`` (full mutation state at
capacity width, so the reopened index delta-packs exactly like the one
that was saved), ``PackedIVF``, ``KNNMemory`` (values + segment labels
alongside the index), plus a multi-shard envelope for the distributed
layer (``save_shards``/``load_shards`` re-exported through
core/distributed.py). Routers (Flat/Tree) ride every kind.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Optional

import numpy as np

from repro.ckpt import faults

FORMAT_VERSION = 1
_ALIGN = 64

try:                                   # optional hardware CRC32C wheel
    import crc32c as _crc32c_mod

    def _crc32c(data: bytes) -> int:
        return _crc32c_mod.crc32c(data)
    _HAVE_CRC32C = True
except ImportError:
    _crc32c_mod = None
    _HAVE_CRC32C = False

_ALGOS = {"crc32": zlib.crc32}
if _HAVE_CRC32C:
    _ALGOS["crc32c"] = _crc32c
_DEFAULT_ALGO = "crc32c" if _HAVE_CRC32C else "crc32"


class CorruptSnapshotError(Exception):
    """A snapshot or WAL failed an integrity check (missing/truncated
    file, CRC mismatch, bad magic/version, shape-byte mismatch). The
    load path raises this instead of ever serving a torn index."""


def _checksum(algo: str, data) -> int:
    fn = _ALGOS.get(algo)
    if fn is None:
        raise CorruptSnapshotError(
            f"snapshot written with checksum algo {algo!r}, which is not "
            f"available here (have: {sorted(_ALGOS)})")
    return fn(bytes(data)) & 0xFFFFFFFF


# ------------------------------------------------------------------ fsync
def _fsync_file(path: str):
    with open(path, "rb") as f:
        os.fsync(f.fileno())


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_replace_dir(tmp: str, dst: str):
    """Crash-safe directory swap: rename the live snapshot aside, rename
    the (already fsynced) tmp in, then delete the old copy. The previous
    ``rmtree(dst)``-then-``rename`` idiom had a window where a crash
    left NO copy at all; here every crash point leaves at least one fully
    committed directory (possibly under ``.old`` — see
    ``resolve_snapshot_dir``). Crash points are injectable via
    ckpt/faults.py."""
    old = dst + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)           # leftover from an earlier crash
    if os.path.exists(dst):
        os.rename(dst, old)
    faults.crash_point("commit:between_renames")
    os.rename(tmp, dst)
    _fsync_dir(os.path.dirname(os.path.abspath(dst)) or ".")
    faults.crash_point("commit:before_cleanup")
    if os.path.exists(old):
        shutil.rmtree(old)


def resolve_snapshot_dir(path: str) -> str:
    """Finish an interrupted ``atomic_replace_dir`` at load time: if the
    snapshot is missing but ``<path>.old`` exists, the crash hit between
    the two renames — the old directory IS the last committed state, so
    rename it back and serve it."""
    if os.path.isdir(path):
        return path
    old = path + ".old"
    if os.path.isdir(old):
        os.rename(old, path)
        return path
    return path                        # let the caller raise "missing"


# --------------------------------------------------------------- manifest
def _write_manifest(f, manifest: dict, algo: str):
    body = json.dumps(manifest, sort_keys=True)
    payload = json.dumps(
        {"crc": f"{_checksum(algo, body.encode()):08x}",
         "manifest": manifest}, sort_keys=True).encode()
    faults.write(f, payload, stream="snapshot:manifest")


def read_manifest(path: str) -> dict:
    """Load + integrity-check a snapshot manifest (arrays not touched)."""
    path = resolve_snapshot_dir(path)
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        raise CorruptSnapshotError(f"no snapshot at {path} (manifest.json "
                                   f"missing)")
    try:
        with open(mpath, "rb") as f:
            outer = json.load(f)
        manifest = outer["manifest"]
        crc = outer["crc"]
    except (json.JSONDecodeError, KeyError, UnicodeDecodeError) as e:
        raise CorruptSnapshotError(
            f"unreadable snapshot manifest at {mpath}: {e}") from e
    algo = manifest.get("checksum_algo", "crc32")
    body = json.dumps(manifest, sort_keys=True)
    if f"{_checksum(algo, body.encode()):08x}" != crc:
        raise CorruptSnapshotError(f"manifest checksum mismatch at {mpath}")
    ver = manifest.get("format_version")
    if ver != FORMAT_VERSION:
        raise CorruptSnapshotError(
            f"snapshot format version {ver!r} at {path}; this build reads "
            f"version {FORMAT_VERSION}")
    return manifest


# ----------------------------------------------------------- array (de)ser
def _np_host(a) -> np.ndarray:
    """Pytree leaf → contiguous host array (jax arrays devolve to numpy)."""
    a = np.asarray(a)
    if a.dtype.name == "bfloat16":     # numpy can't serialize ml_dtypes
        a = a.view(np.uint16)
    return np.ascontiguousarray(a)


def _write_state(path: str, kind: str, meta: dict, arrays: dict,
                 algo: Optional[str] = None):
    """Write one snapshot directory atomically (manifest + arrays.bin)."""
    algo = algo or _DEFAULT_ALGO
    tmp = f"{path}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    entries = []
    off = 0
    with open(os.path.join(tmp, "arrays.bin"), "wb") as f:
        for name, arr in arrays.items():
            if arr is None:
                continue
            a = _np_host(arr)
            pad = (-off) % _ALIGN
            if pad:
                faults.write(f, b"\x00" * pad, stream="snapshot:arrays")
                off += pad
            raw = a.tobytes()
            entries.append({"name": name, "dtype": str(a.dtype),
                            "shape": list(a.shape), "offset": off,
                            "nbytes": len(raw),
                            "crc": f"{_checksum(algo, raw):08x}"})
            faults.write(f, raw, stream="snapshot:arrays")
            off += len(raw)
        f.flush()
        os.fsync(f.fileno())
    manifest = {"format_version": FORMAT_VERSION, "kind": kind,
                "checksum_algo": algo, "meta": meta, "arrays": entries}
    with open(os.path.join(tmp, "manifest.json"), "wb") as f:
        _write_manifest(f, manifest, algo)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    atomic_replace_dir(tmp, path)


def _read_arrays(path: str, manifest: dict,
                 only_prefix: Optional[str] = None) -> dict:
    algo = manifest["checksum_algo"]
    apath = os.path.join(path, "arrays.bin")
    if not os.path.exists(apath):
        raise CorruptSnapshotError(f"{apath} missing")
    size = os.path.getsize(apath)
    out = {}
    with open(apath, "rb") as f:
        for e in manifest["arrays"]:
            if only_prefix is not None \
                    and not e["name"].startswith(only_prefix):
                continue
            if e["offset"] + e["nbytes"] > size:
                raise CorruptSnapshotError(
                    f"{apath} truncated: array {e['name']!r} needs bytes "
                    f"[{e['offset']}, {e['offset'] + e['nbytes']}) but the "
                    f"file has {size}")
            dt = np.dtype(e["dtype"])
            want = int(np.prod(e["shape"], dtype=np.int64)) * dt.itemsize
            if want != e["nbytes"]:
                raise CorruptSnapshotError(
                    f"array {e['name']!r}: manifest shape {e['shape']} "
                    f"({want} bytes) disagrees with nbytes {e['nbytes']}")
            f.seek(e["offset"])
            raw = f.read(e["nbytes"])
            if len(raw) != e["nbytes"]:
                raise CorruptSnapshotError(
                    f"short read on array {e['name']!r}")
            if f"{_checksum(algo, raw):08x}" != e["crc"]:
                raise CorruptSnapshotError(
                    f"checksum mismatch on array {e['name']!r} — the "
                    f"snapshot at {path} is corrupt")
            out[e["name"]] = np.frombuffer(raw, dtype=dt).reshape(
                e["shape"]).copy()
    return out


# ------------------------------------------------------------ router codec
def _router_state(router):
    """Router → (meta | None, name-prefixed arrays). The frozen trained
    tables are what persist; derived serving views (pruning) recompute."""
    if router is None:
        return None, {}
    from repro.core.router import FlatRouter, TreeRouter
    if isinstance(router, FlatRouter):
        return ({"type": "flat"},
                {"router.centroids": router.centroids})
    if isinstance(router, TreeRouter):
        return ({"type": "tree", "t_route": router.t_route,
                 "n_partitions": router.n_partitions},
                {"router.super_centroids": router.super_centroids,
                 "router.children": router.children,
                 "router.child_centroids": router.child_centroids})
    raise TypeError(f"cannot snapshot router type {type(router).__name__}")


def _router_from_state(meta, arrays):
    if meta is None:
        return None
    from repro.core.router import FlatRouter, TreeRouter
    if meta["type"] == "flat":
        return FlatRouter(arrays["router.centroids"])
    if meta["type"] == "tree":
        return TreeRouter(arrays["router.super_centroids"],
                          arrays["router.children"],
                          arrays["router.child_centroids"],
                          t_route=meta["t_route"],
                          n_partitions=meta["n_partitions"])
    raise CorruptSnapshotError(f"unknown router type {meta['type']!r} in "
                               f"snapshot manifest")


def _pq_state(pq):
    return {} if pq is None else {"pq.centers": pq.centers}


def _pq_from_state(arrays):
    if "pq.centers" not in arrays:
        return None
    from repro.quant.pq import PQCodebook
    import jax.numpy as jnp
    return PQCodebook(jnp.asarray(arrays["pq.centers"]))


# ------------------------------------------------------------ object codecs
def _state_of(obj, extra: Optional[dict]):
    """Dispatch an index object → (kind, meta, arrays)."""
    from repro.core.ivf import IVFIndex
    from repro.core.mutable import MutableIVF
    from repro.core.search import PackedIVF
    from repro.serve.knn_memory import KNNMemory
    if isinstance(obj, MutableIVF):
        kind, meta, arrays = _mutable_state(obj)
    elif isinstance(obj, IVFIndex):
        kind, meta, arrays = _ivf_state(obj)
    elif isinstance(obj, PackedIVF):
        kind, meta, arrays = _packed_state(obj)
    elif isinstance(obj, KNNMemory):
        kind, meta, arrays = _knn_state(obj)
    else:
        raise TypeError(f"cannot snapshot object of type "
                        f"{type(obj).__name__}")
    meta["extra"] = extra or {}
    return kind, meta, arrays


def _ivf_state(idx):
    rmeta, rarr = _router_state(idx.router)
    arrays = {"centroids": idx.centroids, "starts": idx.starts,
              "point_ids": idx.point_ids, "assignments": idx.assignments}
    if idx.codes is not None:
        arrays["codes"] = idx.codes
    if idx.rerank_f32 is not None:
        arrays["rerank_f32"] = idx.rerank_f32
    if idx.rerank_int8 is not None:
        arrays["rerank_int8.q"] = idx.rerank_int8.q
        arrays["rerank_int8.scale"] = idx.rerank_int8.scale
    arrays.update(_pq_state(idx.pq))
    arrays.update(rarr)
    meta = {"n_points": int(idx.n_points), "spill_mode": idx.spill_mode,
            "lam": float(idx.lam), "router": rmeta}
    return "IVFIndex", meta, arrays


def _ivf_from(meta, arrays):
    from repro.core.ivf import IVFIndex
    from repro.quant.int8 import Int8Data
    import jax.numpy as jnp
    ri = None
    if "rerank_int8.q" in arrays:
        ri = Int8Data(jnp.asarray(arrays["rerank_int8.q"]),
                      jnp.asarray(arrays["rerank_int8.scale"]))
    return IVFIndex(
        centroids=arrays["centroids"], starts=arrays["starts"],
        point_ids=arrays["point_ids"], codes=arrays.get("codes"),
        pq=_pq_from_state(arrays), rerank_int8=ri,
        rerank_f32=arrays.get("rerank_f32"),
        assignments=arrays["assignments"], n_points=meta["n_points"],
        spill_mode=meta["spill_mode"], lam=meta["lam"],
        router=_router_from_state(meta["router"], arrays))


def _mutable_state(mut):
    rmeta, rarr = _router_state(mut.router)
    arrays = {"centroids": mut.centroids, "part_ids": mut.part_ids,
              "sizes": mut.sizes, "rerank": mut.rerank,
              "assignments": mut.assignments,
              "alive": mut.alive.astype(np.uint8)}
    if mut.part_codes is not None:
        arrays["part_codes"] = mut.part_codes
    arrays.update(_pq_state(mut.pq))
    arrays.update(rarr)
    meta = {"spill_mode": mut.spill_mode, "lam": float(mut.lam),
            "n_spills": int(mut.n_spills), "n_total": int(mut.n_total),
            "n_dead_slots": int(mut.n_dead_slots),
            "n_soft_deleted": int(mut.n_soft_deleted),
            "compact_threshold": float(mut.compact_threshold),
            "wal_seq": int(mut.wal_seq), "router": rmeta}
    return "MutableIVF", meta, arrays


def _mutable_from(meta, arrays):
    from repro.core.mutable import MutableIVF
    return MutableIVF(
        centroids=arrays["centroids"], pq=_pq_from_state(arrays),
        spill_mode=meta["spill_mode"], lam=meta["lam"],
        n_spills=meta["n_spills"], part_ids=arrays["part_ids"],
        part_codes=arrays.get("part_codes"), sizes=arrays["sizes"],
        rerank=arrays["rerank"], assignments=arrays["assignments"],
        alive=arrays["alive"].astype(bool), n_total=meta["n_total"],
        n_dead_slots=meta["n_dead_slots"],
        n_soft_deleted=meta["n_soft_deleted"],
        compact_threshold=meta["compact_threshold"],
        router=_router_from_state(meta["router"], arrays),
        wal_seq=meta.get("wal_seq", 0))


def _packed_state(p):
    rmeta, rarr = _router_state(p.router)
    arrays = {"centroids": p.centroids, "part_ids": p.part_ids,
              "sizes": p.sizes, "rerank": p.rerank}
    if p.part_codes is not None:
        arrays["part_codes"] = p.part_codes
    if p.part_codes2 is not None:
        arrays["part_codes2"] = p.part_codes2
    arrays.update(_pq_state(p.pq))
    arrays.update(rarr)
    return "PackedIVF", {"router": rmeta}, arrays


def _packed_from(meta, arrays):
    from repro.core.search import PackedIVF
    import jax.numpy as jnp
    rt = _router_from_state(meta["router"], arrays)
    j = jnp.asarray
    return PackedIVF(
        j(arrays["centroids"]), j(arrays["part_ids"]),
        j(arrays["part_codes"]) if "part_codes" in arrays else None,
        j(arrays["part_codes2"]) if "part_codes2" in arrays else None,
        j(arrays["sizes"]), _pq_from_state(arrays), j(arrays["rerank"]),
        rt.device() if rt is not None else None)


def _knn_state(mem):
    _, imeta, iarrays = _mutable_state(mem.index)
    arrays = {f"index.{k}": v for k, v in iarrays.items()}
    arrays["values"] = mem.values
    if mem.segments is not None:
        arrays["segments"] = mem.segments
    return "KNNMemory", {"engine": mem.engine, "top_t": mem.top_t,
                         "index": imeta}, arrays


def _knn_from(meta, arrays):
    from repro.serve.api import DEFAULT_TOP_T
    from repro.serve.knn_memory import KNNMemory
    iarrays = {k[len("index."):]: v for k, v in arrays.items()
               if k.startswith("index.")}
    return KNNMemory(index=_mutable_from(meta["index"], iarrays),
                     values=arrays["values"], engine=meta["engine"],
                     segments=arrays.get("segments"),
                     top_t=int(meta.get("top_t", DEFAULT_TOP_T)))


_LOADERS = {"IVFIndex": _ivf_from, "MutableIVF": _mutable_from,
            "PackedIVF": _packed_from, "KNNMemory": _knn_from}


# ---------------------------------------------------------------- main API
EXTRA_PREFIX = "extra."


def save_snapshot(path: str, obj, *, extra: Optional[dict] = None,
                  extra_arrays: Optional[dict] = None,
                  algo: Optional[str] = None):
    """Atomically snapshot an index object (IVFIndex / MutableIVF /
    PackedIVF / KNNMemory) to `path`. `extra` is a JSON-able dict stored
    in the manifest (e.g. engine serving params); `extra_arrays` is a
    name → ndarray dict of caller-owned arrays that ride the snapshot
    under an ``extra.`` name prefix with the same CRC/atomicity
    guarantees (the serving front-end stores per-tenant filter bitmaps
    this way, §3.12) and load back via `load_extra_arrays`; `algo`
    overrides the checksum algorithm (default: crc32c when available,
    else crc32)."""
    kind, meta, arrays = _state_of(obj, extra)
    for name, arr in (extra_arrays or {}).items():
        key = EXTRA_PREFIX + name
        if key in arrays:
            raise ValueError(f"duplicate extra array name {name!r}")
        arrays[key] = arr
    _write_state(path, kind, meta, arrays, algo=algo)


def load_extra_arrays(path: str) -> dict:
    """Read back the `extra_arrays` stored alongside a snapshot (CRC-
    verified, ``extra.`` prefix stripped); {} when none were saved. The
    object codecs ignore these entries, so layers above the index can
    version their own state without touching the kind formats."""
    path = resolve_snapshot_dir(path)
    manifest = read_manifest(path)
    raw = _read_arrays(path, manifest, only_prefix=EXTRA_PREFIX)
    return {k[len(EXTRA_PREFIX):]: v for k, v in raw.items()}


def load_snapshot(path: str, *, expect_kind: Optional[str] = None):
    """Load a snapshot → (object, extra). Integrity is verified before
    anything is deserialized (manifest CRC, per-array CRCs, shape/byte
    agreement, truncation) and any failure raises CorruptSnapshotError —
    a torn snapshot can never reach the search path. An interrupted
    atomic swap is finished first (resolve_snapshot_dir)."""
    path = resolve_snapshot_dir(path)
    manifest = read_manifest(path)
    kind = manifest["kind"]
    if kind not in _LOADERS:
        raise CorruptSnapshotError(f"unknown snapshot kind {kind!r}")
    if expect_kind is not None and kind != expect_kind:
        raise CorruptSnapshotError(
            f"snapshot at {path} holds a {kind}, expected {expect_kind}")
    arrays = _read_arrays(path, manifest)
    meta = manifest["meta"]
    return _LOADERS[kind](meta, arrays), meta.get("extra", {})


# ------------------------------------------------------------ shard envelope
def save_shards(path: str, indexes, *, extra: Optional[dict] = None):
    """Snapshot a list of per-shard indexes (the distributed layer's
    building blocks) as one atomic envelope: each shard is a full
    snapshot under ``shard_<i>/``, plus an envelope manifest. The whole
    envelope commits with the same rename-aside protocol, so a crash
    mid-save never yields a half-written shard set."""
    indexes = list(indexes)
    tmp = f"{path}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for i, idx in enumerate(indexes):
        kind, meta, arrays = _state_of(idx, None)
        _write_state(os.path.join(tmp, f"shard_{i:04d}"), kind, meta,
                     arrays)
    manifest = {"format_version": FORMAT_VERSION, "kind": "ShardEnvelope",
                "checksum_algo": _DEFAULT_ALGO,
                "meta": {"n_shards": len(indexes), "extra": extra or {}},
                "arrays": []}
    with open(os.path.join(tmp, "manifest.json"), "wb") as f:
        _write_manifest(f, manifest, _DEFAULT_ALGO)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    atomic_replace_dir(tmp, path)


def load_shards(path: str):
    """Load a shard envelope → (list of per-shard indexes, extra). Feed
    the list to distributed.sharded_from_indexes(_pq) to re-stack the
    serving envelope."""
    path = resolve_snapshot_dir(path)
    manifest = read_manifest(path)
    if manifest["kind"] != "ShardEnvelope":
        raise CorruptSnapshotError(
            f"snapshot at {path} is a {manifest['kind']!r}, not a shard "
            f"envelope")
    n = manifest["meta"]["n_shards"]
    out = []
    for i in range(n):
        sp = os.path.join(path, f"shard_{i:04d}")
        if not os.path.isdir(sp):
            raise CorruptSnapshotError(
                f"shard envelope at {path} claims {n} shards but "
                f"shard_{i:04d} is missing")
        obj, _ = load_snapshot(sp)
        out.append(obj)
    return out, manifest["meta"].get("extra", {})
