"""Mutation write-ahead log for MutableIVF (DESIGN.md §3.11).

Snapshots alone make durability O(index) per mutation batch — unusable at
serving cadence. The WAL closes the gap: every mutation (``add`` /
``remove`` / ``harden_soft_deletes`` / ``compact``) appends one
CRC-framed record BEFORE it is applied, so

    recovery = load latest valid snapshot + replay records with
               seq > snapshot.wal_seq

reproduces the live index **bitwise** (every mutation path is
deterministic given the same starting state: fused assignment against
the frozen codebook, stable counting sorts, stable compaction argsort —
the same property the mutate-≡-rebuild contract of §3.7 already pins).

Record framing (little-endian)::

    [u32 magic "WAL1"] [u32 seq] [u32 type] [u32 payload_len]
    [u32 payload_crc]  [u32 header_crc]     [payload ...]

- ``header_crc`` covers the first 20 header bytes, so a flipped bit in a
  length field cannot send the reader off the rails;
- a **torn final record** (crash mid-append: the remaining bytes are a
  strict prefix of the record) is tolerated and dropped — the mutation
  never committed, the state before it is the recovery point. The opener
  truncates the torn bytes so subsequent appends re-use the tail;
- an invalid record that IS fully present (bad magic / failed CRC with
  enough bytes on disk) is corruption, not tearing → raises
  ``CorruptSnapshotError``: committed mutations must never be silently
  skipped.

``fsync`` policy: ``"always"`` fsyncs after every record (a record
returned to the caller survives power loss), ``"never"`` leaves flushing
to the OS (crash-consistent — a prefix of records survives — but the
tail may be lost; the right trade for bulk loads). Appends thread
through ckpt/faults.py (stream ``"wal:append"``, point ``"wal:record"``)
for the crash matrix.

Payloads carry JSON meta + raw numpy arrays in an inline framed form
(dtype/shape header per array) — no pickle anywhere in the recovery
path.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.ckpt import faults
from repro.ckpt.index_store import CorruptSnapshotError

_MAGIC = 0x314C4157                    # b"WAL1" little-endian
_HDR = struct.Struct("<IIIII")         # magic, seq, type, plen, pcrc
_HCRC = struct.Struct("<I")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")

# record types — applied by repro.core.mutable.MutableIVF.replay_record
REC_ADD = 1
REC_REMOVE = 2
REC_HARDEN = 3
REC_COMPACT = 4


def _crc(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def encode_payload(meta: Optional[dict], arrays: Optional[dict]) -> bytes:
    mj = json.dumps(meta or {}).encode()
    parts = [_U32.pack(len(mj)), mj,
             _U32.pack(len(arrays) if arrays else 0)]
    for name, arr in (arrays or {}).items():
        a = np.ascontiguousarray(np.asarray(arr))
        hdr = json.dumps({"name": name, "dtype": str(a.dtype),
                          "shape": list(a.shape)}).encode()
        parts += [_U32.pack(len(hdr)), hdr, _U64.pack(a.nbytes),
                  a.tobytes()]
    return b"".join(parts)


def decode_payload(buf: bytes) -> Tuple[dict, dict]:
    try:
        off = _U32.size
        (mlen,) = _U32.unpack_from(buf, 0)
        meta = json.loads(buf[off:off + mlen].decode())
        off += mlen
        (n,) = _U32.unpack_from(buf, off)
        off += _U32.size
        arrays = {}
        for _ in range(n):
            (hlen,) = _U32.unpack_from(buf, off)
            off += _U32.size
            hdr = json.loads(buf[off:off + hlen].decode())
            off += hlen
            (nbytes,) = _U64.unpack_from(buf, off)
            off += _U64.size
            dt = np.dtype(hdr["dtype"])
            arrays[hdr["name"]] = np.frombuffer(
                buf, dtype=dt, count=nbytes // dt.itemsize,
                offset=off).reshape(hdr["shape"]).copy()
            off += nbytes
        return meta, arrays
    except (struct.error, json.JSONDecodeError, UnicodeDecodeError,
            ValueError, KeyError) as e:
        # CRC passed but the payload doesn't parse — still corruption
        raise CorruptSnapshotError(f"undecodable WAL payload: {e}") from e


def scan(path: str):
    """Walk the log: yields (seq, rtype, payload_bytes, end_offset) for
    every valid record; returns at a torn tail (recording where the valid
    prefix ends); raises CorruptSnapshotError on a fully-present invalid
    record (mid-file corruption). Use via `read_records` / `MutationWAL`.
    """
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        off = 0
        while off < size:
            remaining = size - off
            full_hdr = _HDR.size + _HCRC.size
            if remaining < full_hdr:
                return off             # torn header → drop
            hdr = f.read(_HDR.size)
            (hcrc,) = _HCRC.unpack(f.read(_HCRC.size))
            magic, seq, rtype, plen, pcrc = _HDR.unpack(hdr)
            if _crc(hdr) != hcrc or magic != _MAGIC:
                # the full header is on disk yet invalid: appends write
                # strict prefixes, so this cannot be a torn write
                raise CorruptSnapshotError(
                    f"corrupt WAL record header at byte {off} of {path}")
            if remaining < full_hdr + plen:
                return off             # torn payload → drop the record
            payload = f.read(plen)
            if _crc(payload) != pcrc:
                raise CorruptSnapshotError(
                    f"corrupt WAL payload (seq {seq}) at byte {off} of "
                    f"{path}")
            off += full_hdr + plen
            yield seq, rtype, payload, off
    return off


def read_records(path: str) -> Iterator[Tuple[int, int, dict, dict]]:
    """Yield (seq, rtype, meta, arrays) for every committed record,
    dropping a torn tail, raising CorruptSnapshotError on corruption."""
    for seq, rtype, payload, _ in scan(path):
        meta, arrays = decode_payload(payload)
        yield seq, rtype, meta, arrays


class MutationWAL:
    """Append-side handle. Opening scans the existing log (validating
    every record), TRUNCATES a torn tail, and positions the next append
    after the last committed record with a monotonically increasing
    sequence number. `start_seq` floors the sequence — pass the
    snapshot's wal_seq when the log was rotated at save time, so sequence
    numbers never move backwards across a rotation."""

    def __init__(self, path: str, fsync: str = "always",
                 start_seq: int = 0):
        if fsync not in ("always", "never"):
            raise ValueError(f"fsync policy {fsync!r} not in "
                             f"('always', 'never')")
        self.path = path
        self.fsync = fsync
        last_seq = int(start_seq)
        valid_end = 0
        if os.path.exists(path):
            for seq, _, _, end in scan(path):
                last_seq = max(last_seq, seq)
                valid_end = end
            if os.path.getsize(path) > valid_end:
                with open(path, "r+b") as f:
                    f.truncate(valid_end)    # drop the torn tail
        self._seq = last_seq
        self._f = open(path, "ab")
        d = os.path.dirname(os.path.abspath(path)) or "."
        fd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(fd)               # the log file itself is durable
        finally:
            os.close(fd)

    @property
    def last_seq(self) -> int:
        return self._seq

    def append(self, rtype: int, meta: Optional[dict] = None,
               arrays: Optional[dict] = None) -> int:
        """Frame + append one record; returns its sequence number. The
        record is on disk (fsync="always": durably) before this returns —
        the write-ahead contract callers rely on."""
        seq = self._seq + 1
        payload = encode_payload(meta, arrays)
        hdr = _HDR.pack(_MAGIC, seq, rtype, len(payload), _crc(payload))
        rec = hdr + _HCRC.pack(_crc(hdr)) + payload
        faults.write(self._f, rec, stream="wal:append")
        self._f.flush()
        if self.fsync == "always":
            os.fsync(self._f.fileno())
        faults.crash_point("wal:record")
        self._seq = seq
        return seq

    def rotate(self, upto_seq: int):
        """Drop the log body after a successful snapshot covering
        `upto_seq` (all records are ≤ upto_seq by the append protocol).
        Sequence numbers continue from the snapshot's wal_seq, so a crash
        between snapshot commit and rotation is benign — replay skips
        records ≤ wal_seq either way."""
        if upto_seq < self._seq:
            raise ValueError(f"cannot rotate to seq {upto_seq}: records "
                             f"up to {self._seq} are in the log")
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.truncate(0)
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self):
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
