"""Architecture registry: --arch <id> resolves here.

Each module defines CONFIG (exact assigned config) and optionally RULES
(per-arch logical→physical overrides, see DESIGN.md §6).
"""
from __future__ import annotations

import importlib

_ARCHS = {
    "granite-3-2b": "granite_3_2b",
    "nemotron-4-15b": "nemotron_4_15b",
    "minitron-8b": "minitron_8b",
    "mistral-large-123b": "mistral_large_123b",
    "paligemma-3b": "paligemma_3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "xlstm-350m": "xlstm_350m",
    "hubert-xlarge": "hubert_xlarge",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS = tuple(_ARCHS)


def get_config(arch_id: str):
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch_id]}")
    return mod.CONFIG


def get_rule_overrides(arch_id: str) -> dict:
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch_id]}")
    return getattr(mod, "RULE_OVERRIDES", {})
