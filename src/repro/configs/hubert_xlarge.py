"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16, MHA) d_ff=5120
vocab=504 (masked-unit prediction targets). Encoder-only, bidirectional;
the CNN waveform frontend is a STUB per spec: input_specs() provides
precomputed frame embeddings. No decode step → decode shapes skipped.
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, mlp="gelu",
    causal=False, frontend="audio",
)
