"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2. Mamba:attention 7:1 interleave (one attn
per group of 8, position 3 as in the paper), MoE every other layer.
Mamba state + 1:8 attention → sub-quadratic → runs long_500k with the
attention KV cache seq-sharded. [arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536, mlp="swiglu",
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe_positions=(1, 3, 5, 7), n_experts=16, experts_per_token=2,
    moe_d_ff=14336,
    ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
    subquadratic=True,
)
