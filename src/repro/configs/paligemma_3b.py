"""paligemma-3b [vlm] — 18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384
vocab=257216. SigLIP frontend is a STUB per spec: input_specs() provides 256
precomputed patch embeddings; the gemma decoder uses a prefix-LM mask over
them. [arXiv:2407.07726; hf]

Sharding note (DESIGN.md §6): 8 q-heads don't divide the 16-way model axis;
attention weights stay replicated (they're 2% of params) and the model axis
shards the 16384-wide MLP + the 257k vocab, which dominate.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, mlp="geglu",
    frontend="vision", n_prefix_embeds=256,
)

RULE_OVERRIDES = {"heads": None, "head": None, "kv_heads": None}
