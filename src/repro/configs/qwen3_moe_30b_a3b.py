"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768(expert)
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab_size=151936, mlp="swiglu",
    moe_positions=(0,), n_experts=128, experts_per_token=8, moe_d_ff=768,
)
