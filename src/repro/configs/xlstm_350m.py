"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks in the xLSTM[7:1] ratio (7 mLSTM : 1 sLSTM per group of
8; 24 layers = 3 groups). Attention-free → sub-quadratic → runs long_500k.
[arXiv:2405.04517; unverified]

SOAR applicability (DESIGN.md §Arch-applicability): kNN-attention memory is
inapplicable (no KV); the arch is built without the paper's technique.

Sharding: 4 heads don't divide the 16-way model axis → the 256-wide value
dim ("head") is sharded instead; sLSTM is tiny and stays replicated.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304, mlp="none",
    block_pattern=("mlstm",) * 7 + ("slstm",),
    subquadratic=True,
)

RULE_OVERRIDES = {"heads": None, "head": "model"}
