"""SOAR core: VQ training, orthogonality-amplified spilled assignment,
IVF index construction, search, and KMR metrics — the paper's contribution."""
from repro.core.kmeans import train_kmeans, assign_euclidean, assign_euclidean_topk  # noqa: F401
from repro.core.soar import (soar_assign, soar_assign_multi,  # noqa: F401
                             naive_spill_assign, soar_loss_values)
from repro.core.ivf import IVFIndex, build_ivf, finalize_ivf  # noqa: F401
from repro.core.build import (build_ivf_sharded, train_codebook,  # noqa: F401
                              assign_shards)
from repro.core.mutable import MutableIVF  # noqa: F401
from repro.core.router import (FlatRouter, TreeRouter,  # noqa: F401
                               train_tree_router, as_router, clamp_top_t)
from repro.core.search import search_numpy, search_jit, pack_ivf  # noqa: F401
from repro.core.kmr import (kmr_curve, points_to_recall, true_neighbors,  # noqa: F401
                            rank_statistics, KMRCurve)
