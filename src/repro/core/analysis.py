"""Statistics behind the paper's analysis figures (Figs 1, 2, 4, 7, 9).

All functions operate on (query, true-neighbor) pairs: for each query q and
each of its true top-k neighbors x, the residual r = x - C_pi(x) and the
spilled residual r' = x - C_pi'(x).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PairStats(NamedTuple):
    qr: np.ndarray          # <q, r>        per (query, neighbor) pair
    qr2: np.ndarray         # <q, r'>
    cos1: np.ndarray        # cos(theta)  = <q,r>/(||q|| ||r||)
    cos2: np.ndarray        # cos(theta')
    rnorm: np.ndarray       # ||r||
    r2norm: np.ndarray      # ||r'||
    res_cos: np.ndarray     # <r_hat, r'_hat>  (residual-residual angle)


@jax.jit
def _pair_stats(X, C, a1, a2, Q, true_ids):
    nbr = X[true_ids]                        # (nq, k, d)
    r = nbr - C[a1[true_ids]]
    r2 = nbr - C[a2[true_ids]]
    qn = jnp.linalg.norm(Q, axis=-1, keepdims=True)
    qr = jnp.einsum("qd,qkd->qk", Q, r)
    qr2 = jnp.einsum("qd,qkd->qk", Q, r2)
    rn = jnp.maximum(jnp.linalg.norm(r, axis=-1), 1e-12)
    r2n = jnp.maximum(jnp.linalg.norm(r2, axis=-1), 1e-12)
    cos1 = qr / (rn * qn)
    cos2 = qr2 / (r2n * qn)
    rescos = jnp.einsum("qkd,qkd->qk", r, r2) / (rn * r2n)
    return qr, qr2, cos1, cos2, rn, r2n, rescos


def pair_stats(X, C, assignments, Q, true_ids) -> PairStats:
    """assignments: (n, 2) [primary, spilled]."""
    out = _pair_stats(jnp.asarray(X), jnp.asarray(C),
                      jnp.asarray(assignments[:, 0]), jnp.asarray(assignments[:, 1]),
                      jnp.asarray(Q, jnp.float32), jnp.asarray(true_ids))
    return PairStats(*[np.asarray(o).reshape(-1) for o in out])


def pearson(a: np.ndarray, b: np.ndarray) -> float:
    a = a - a.mean()
    b = b - b.mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / max(denom, 1e-12))


def score_error_correlation(stats: PairStats) -> float:
    """rho(<q,r>, <q,r'>) over observed pairs (Figure 9 y-axis)."""
    return pearson(stats.qr, stats.qr2)


def angle_correlation(stats: PairStats) -> float:
    """rho(cos theta, cos theta') (Figures 4 / 7)."""
    return pearson(stats.cos1, stats.cos2)


def mean_qr_by_rank(X, C, assignments, Q, true_ids, n_bins: int = 20):
    """Figure 1: mean <q,r> bucketed by RANK(q, C_pi(x), C)."""
    from repro.core.kmr import rank_statistics

    class _Idx:  # minimal duck-typed shim for rank_statistics
        pass
    idx = _Idx()
    idx.centroids = np.asarray(C)
    idx.assignments = np.asarray(assignments)
    prim_rank, _ = rank_statistics(idx, Q, true_ids)
    stats = pair_stats(X, C, assignments, Q, true_ids)
    ranks = prim_rank.reshape(-1)
    qr = stats.qr
    # log-spaced rank bins
    edges = np.unique(np.geomspace(1, max(ranks.max(), 2), n_bins).astype(int))
    centers, means = [], []
    for lo, hi in zip(edges[:-1], edges[1:]):
        m = (ranks >= lo - 1) & (ranks < hi)
        if m.sum() > 0:
            centers.append((lo + hi) / 2)
            means.append(float(qr[m].mean()))
    return np.array(centers), np.array(means)
