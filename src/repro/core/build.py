"""Sharded SOAR index build: sample-trained codebook + streamed assignment
(DESIGN.md §3.7).

The monolithic `build_ivf` runs Lloyd iterations over the full dataset and
materializes every per-point intermediate at O(n) on the accelerator; at
SPANN/big-ann scale the *build* — not search — is what dies first. This
driver follows the paper's serving lineage (ScaNN trains partitions on a
subsample; SPANN's contribution is almost entirely build/partition
plumbing):

1. the VQ codebook is trained on a `train_sample` row-subsample — k-means
   quality saturates long before n, and a frozen codebook is what makes
   incremental inserts possible at all (core/mutable.py);
2. primary + SOAR assignments stream over `shard_size` row-tiles of X
   through the fused path in `kernels/soar_assign.py` (Pallas two-MXU-pass
   kernel on TPU, chunked two-GEMM `lax.map` tiles elsewhere — both share
   the reassociated loss form of core/soar.py), so peak accelerator memory
   is O(shard_size·(c + d)) however large n grows;
3. CSR / residual-PQ / rerank assembly goes through the shared
   `finalize_ivf`, which also streams residual encoding.

`codebook=` / `pq=` freeze those stages explicitly — the rebuild-comparator
contract the incremental-mutation equivalence tests pin.

Multi-host: `distributed.make_sharded_assign` wraps the same fused
assignment in shard_map over the data axis (assignment against a replicated
frozen codebook is embarrassingly parallel — no collectives), and
`distributed.build_sharded_ivf*` route their per-shard builds through
`build_ivf_sharded`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import IVFIndex, finalize_ivf
from repro.core.kmeans import train_kmeans
from repro.kernels.soar_assign import assign_fused
from repro.quant.pq import PQCodebook
from repro.quant.anisotropic import anisotropic_kmeans, eta_from_threshold

DEFAULT_TRAIN_SAMPLE = 131_072
DEFAULT_SHARD = 65_536


def spill_plan(spill_mode: str, lam: float, n_spills: int):
    """Canonical (effective lam, effective spill count) per spill mode."""
    if spill_mode == "none":
        return 0.0, 0
    if spill_mode == "naive":
        return 0.0, 1
    if spill_mode == "soar":
        return lam, n_spills
    raise ValueError(spill_mode)


def train_codebook(key, X, n_partitions: int, *,
                   train_sample: Optional[int] = DEFAULT_TRAIN_SAMPLE,
                   train_iters: int = 15, anisotropic_T: float = 0.0,
                   init: str = "pp", batch_size: Optional[int] = None,
                   verbose: bool = False) -> np.ndarray:
    """Train the (to-be-frozen) VQ codebook on a row-subsample of X.

    With anisotropic_T > 0 the codebook is score-aware (quant/anisotropic);
    note the sharded pipeline always assigns primaries by Euclidean argmin,
    so anisotropic *training* shapes the centroids only. `init` /
    `batch_size` select the flagged k-means|| / mini-batch training modes
    (exact full-batch k-means++ path is the default, see core/kmeans.py).
    """
    n, d = X.shape
    if train_sample and n > train_sample:
        sel = np.asarray(jax.random.choice(key, n, (train_sample,),
                                           replace=False))
        Xt = jnp.asarray(X[sel], jnp.float32)
    else:
        Xt = jnp.asarray(X, jnp.float32)
    if anisotropic_T > 0.0:
        eta = eta_from_threshold(anisotropic_T, d)
        C, _ = anisotropic_kmeans(key, Xt, n_partitions, eta,
                                  iters=max(4, train_iters // 3))
    else:
        C = train_kmeans(key, Xt, n_partitions, iters=train_iters,
                         verbose=verbose, init=init, batch_size=batch_size,
                         final_assign=False).centroids
    return np.asarray(C, np.float32)


def assign_shards(X, C, *, spill_mode: str = "soar", lam: float = 1.0,
                  n_spills: int = 1, shard_size: int = DEFAULT_SHARD,
                  chunk: int = 8192, verbose: bool = False) -> np.ndarray:
    """Stream fused primary+spill assignment over row-shards of X.

    The host loop moves one `shard_size` tile at a time to the accelerator;
    inside each shard `assign_fused` tiles further via `lax.map` chunks —
    the loss matrix never exists beyond (chunk, c). Returns the (n, a)
    int32 assignment matrix (host memory, 4·a bytes/point).
    """
    X = np.asarray(X, np.float32)
    eff_lam, eff_spills = spill_plan(spill_mode, lam, n_spills)
    n = X.shape[0]
    out = np.empty((n, 1 + eff_spills), np.int32)
    Cd = jnp.asarray(C, jnp.float32)
    for i0 in range(0, n, shard_size):
        blk = jnp.asarray(X[i0:i0 + shard_size])
        out[i0:i0 + blk.shape[0]] = np.asarray(
            assign_fused(blk, Cd, lam=eff_lam, n_spills=eff_spills,
                         chunk=chunk))
        if verbose:
            print(f"assign shard [{i0}:{i0 + blk.shape[0]}] / {n}")
    return out


def build_ivf_sharded(key, X, n_partitions: int, *, spill_mode: str = "soar",
                      lam: float = 1.0, n_spills: int = 1,
                      pq_subspaces: int = 0, rerank: str = "f32",
                      train_iters: int = 15,
                      train_sample: Optional[int] = DEFAULT_TRAIN_SAMPLE,
                      shard_size: int = DEFAULT_SHARD, chunk: int = 8192,
                      anisotropic_T: float = 0.0,
                      codebook: Optional[np.ndarray] = None,
                      pq: Optional[PQCodebook] = None,
                      init: str = "pp", batch_size: Optional[int] = None,
                      timings: Optional[dict] = None,
                      verbose: bool = False, router=None,
                      router_kw: Optional[dict] = None) -> IVFIndex:
    """Scalable build: sample-trained codebook, streamed assignment shards.

    Drop-in replacement for `build_ivf` whose accelerator peak is
    O(max(train_sample, shard_size)) instead of O(n). With
    `train_sample=None` (codebook trained on all of X) the result is
    bitwise-identical to `build_ivf` — pinned by tests/test_build.py.

    `codebook=` (and optionally `pq=`) skip training and build against the
    given FROZEN stages — the path used for mutation-equivalence rebuilds
    and for re-indexing fresh data into an existing serving configuration.
    Passing a prebuilt Router instance as `router` freezes it the same
    way (rebuilds keep serving through the router the fleet compiled
    against); a spec string trains anew over the (frozen or fresh)
    codebook with a fold_in-derived key, never perturbing the kmeans/PQ
    random streams.
    """
    from repro.core.ivf import _phase
    from repro.core.router import as_router

    X = np.asarray(X, np.float32)
    kkm, kpq = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    with _phase(timings, "kmeans"):
        if codebook is None:
            C = train_codebook(kkm, X, n_partitions,
                               train_sample=train_sample,
                               train_iters=train_iters,
                               anisotropic_T=anisotropic_T, init=init,
                               batch_size=batch_size, verbose=verbose)
        else:
            C = np.asarray(codebook, np.float32)
    with _phase(timings, "spill_assign"):
        assignments = assign_shards(X, C, spill_mode=spill_mode, lam=lam,
                                    n_spills=n_spills, shard_size=shard_size,
                                    chunk=chunk, verbose=verbose)
    with _phase(timings, "router"):
        rt = as_router(router, C, key=jax.random.fold_in(kkm, 0x52F7),
                       **(router_kw or {}))
    return finalize_ivf(kpq, X, C, assignments, pq_subspaces=pq_subspaces,
                        rerank=rerank, spill_mode=spill_mode, lam=lam, pq=pq,
                        timings=timings, router=rt)
