"""Distributed SOAR serving: database sharded over the mesh, queries
replicated, local IVF search per shard, global top-k merge.

This is the cluster-scale layer of the reproduction (big-ann-benchmarks
scale: 1B+ vectors don't fit one host). Design (DESIGN.md §3.5):

- each shard owns n/D vectors and trains its OWN local VQ codebook +
  (optionally spilled) IVF over them — building is embarrassingly parallel
  and shard-local, exactly how ScaNN serving shards;
- a query batch is replicated to all shards (its bytes are tiny vs the DB);
- each shard runs the fixed-budget jit search (search_jit semantics) over
  its local partitions and emits its local top-k with GLOBAL ids;
- one `all_gather` of (D × nq × k) ids/scores + a replicated top-k merge.
  The collective moves O(nq·k·D) bytes — independent of database size, so
  SOAR's bandwidth frugality survives cluster scale.

Implemented with shard_map over the database axes; runs identically on the
8-device test mesh (tests/test_distributed.py) and the 512-chip production
mesh (dry-run cell `ann_serve`, launch/ann_dryrun.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.build import build_ivf_sharded, spill_plan
from repro.core.router import FlatRouter, TreeRouter
from repro.core.search import (_pad_topk, _search_block, dedup_topk_window,
                               pack_ivf, window_pq_scores)
from repro.kernels.soar_assign import assign_fused


class ShardedIVF(NamedTuple):
    """Per-shard IVF arrays, stacked over a leading shard dim D."""
    centroids: jax.Array     # (D, c, d)
    part_ids: jax.Array      # (D, c, pmax) int32 GLOBAL point ids, -1 pad
    sizes: jax.Array         # (D, c) int32
    rerank: jax.Array        # (D, n_local, d) — highest-bitrate local shard
    local_base: jax.Array    # (D,) int32 global id offset of each shard


class ShardedIVFPQ(NamedTuple):
    """PQ-scored variant (§Perf H3 — the paper's actual pipeline): per
    ASSIGNMENT codes in partition order; candidates are scored from uint8
    codes (d/(2s)·2 bytes each at uint8 layout) instead of gathering the
    float32 vectors (4d bytes) — 16× less candidate traffic at m=d/4."""
    centroids: jax.Array     # (D, c, d)
    part_ids: jax.Array      # (D, c, pmax) int32 GLOBAL ids, -1 pad
    part_codes: jax.Array    # (D, c, pmax, m) uint8 PQ codes per assignment
    pq_centers: jax.Array    # (D, m, 16, s) per-shard PQ codebook
    sizes: jax.Array         # (D, c) int32
    rerank: jax.Array        # (D, n_local, d)
    local_base: jax.Array    # (D,) int32


class ShardedTreeRouter(NamedTuple):
    """Per-shard TreeRouter tables, stacked over the leading shard dim D
    (each shard trains its own router over its own local centroids, like
    its own codebook). Shards are padded to the common (S, cmax) envelope:
    pad supers are zero rows whose children are all -1, so selecting one
    contributes only -inf candidates (a wasted route slot, never a wrong
    result)."""
    super_centroids: jax.Array   # (D, S, d)
    children: jax.Array          # (D, S, cmax) int32 local partitions, -1 pad
    child_centroids: jax.Array   # (D, S, cmax, d)


def stack_tree_routers(routers) -> ShardedTreeRouter:
    """Stack per-shard TreeRouters (e.g. `idx.router` of each shard built
    with router="tree") into the sharded envelope for the
    `with_router=True` distributed search paths."""
    S = max(r.n_super for r in routers)
    cmax = max(r.cmax for r in routers)
    d = routers[0].d
    D = len(routers)
    SC = np.zeros((D, S, d), np.float32)
    CH = np.full((D, S, cmax), -1, np.int32)
    CC = np.zeros((D, S, cmax, d), np.float32)
    for i, r in enumerate(routers):
        SC[i, :r.n_super] = np.asarray(r.super_centroids)
        CH[i, :r.n_super, :r.cmax] = np.asarray(r.children)
        CC[i, :r.n_super, :r.cmax] = np.asarray(r.child_centroids)
    return ShardedTreeRouter(jnp.asarray(SC), jnp.asarray(CH),
                             jnp.asarray(CC))


def tree_router_pspecs(axes: Tuple[str, ...]) -> ShardedTreeRouter:
    a = axes if len(axes) > 1 else axes[0]
    return ShardedTreeRouter(P(a), P(a), P(a))


def _resolve_shard(idx):
    """Accept IVFIndex or MutableIVF (post-mutation) per shard."""
    from repro.core.mutable import MutableIVF
    return idx.to_ivf_index() if isinstance(idx, MutableIVF) else idx


def _stack_shards(indexes):
    """Shared stacker for the ShardedIVF(PQ) builders/refreshers: resolve
    mutable shards, pack, pad ids to the common pmax envelope (-1
    sentinel) and rerank to the max local id space (zero rows — padded
    ids never appear in any partition slot, so they are unreachable), and
    accumulate cumulative global-id base offsets. Returns
    (packed, resolved, ids, cents, sizes, reranks, bases)."""
    resolved = list(map(_resolve_shard, indexes))
    packed = [pack_ivf(idx, pair_codes=False) for idx in resolved]
    n_locals = [idx.n_points for idx in resolved]
    pmax = max(pk.part_ids.shape[1] for pk in packed)
    nmax = max(n_locals)
    cents, ids, sizes, reranks, bases = [], [], [], [], []
    base = 0
    for pk, nl in zip(packed, n_locals):
        pad = pmax - pk.part_ids.shape[1]
        ids.append(np.pad(np.asarray(pk.part_ids), ((0, 0), (0, pad)),
                          constant_values=-1))
        cents.append(np.asarray(pk.centroids))
        sizes.append(np.asarray(pk.sizes))
        reranks.append(np.pad(np.asarray(pk.rerank),
                              ((0, nmax - nl), (0, 0))))
        bases.append(base)
        base += nl
    return packed, resolved, ids, cents, sizes, reranks, bases


def sharded_from_indexes(indexes) -> ShardedIVF:
    """Stack per-shard indexes (IVFIndex or MutableIVF) into a ShardedIVF.

    The refresh path after online mutation: each shard's live snapshot is
    packed and padded to the common (pmax, n_local) envelope; local ids
    keep their shard-stable values and globalize via the cumulative base
    offsets.
    """
    _, _, ids, cents, sizes, reranks, bases = _stack_shards(indexes)
    return ShardedIVF(
        jnp.asarray(np.stack(cents)), jnp.asarray(np.stack(ids)),
        jnp.asarray(np.stack(sizes)), jnp.asarray(np.stack(reranks)),
        jnp.asarray(np.array(bases, np.int32)))


def build_sharded_ivf(key, X: np.ndarray, n_shards: int, n_partitions: int,
                      spill_mode: str = "soar", lam: float = 1.0,
                      train_iters: int = 8) -> ShardedIVF:
    """Host-side build: split X row-wise, build one spilled IVF per shard.

    Each per-shard build runs the streamed driver (core/build.py), so peak
    accelerator memory is O(shard tile) rather than O(n/D).
    """
    n = X.shape[0]
    assert n % n_shards == 0
    nl = n // n_shards
    indexes = [
        build_ivf_sharded(jax.random.fold_in(key, s),
                          X[s * nl:(s + 1) * nl], n_partitions,
                          spill_mode=spill_mode, lam=lam,
                          train_iters=train_iters)
        for s in range(n_shards)
    ]
    return sharded_from_indexes(indexes)


def make_sharded_assign(mesh, axes: Tuple[str, ...], *,
                        spill_mode: str = "soar", lam: float = 1.0,
                        n_spills: int = 1, chunk: int = 8192):
    """Build-side shard_map: fn(X rows sharded over `axes`, C replicated)
    → (n, 1 + n_spills) assignments, sharded like X.

    Assignment against a frozen replicated codebook is embarrassingly
    parallel — no collectives — which is exactly why the sharded build
    scales linearly with the mesh (DESIGN.md §3.7). Routes through the
    same `assign_fused` dispatcher as every other entry point (Pallas on
    TPU, chunked GEMM elsewhere; spill_mode semantics via spill_plan).
    Pairs with the serving local-search paths above, which consume the
    resulting per-shard CSR.
    """
    from jax.experimental.shard_map import shard_map

    eff_lam, eff_spills = spill_plan(spill_mode, lam, n_spills)

    def local(Xs, C):
        return assign_fused(Xs, C, lam=eff_lam, n_spills=eff_spills,
                            chunk=chunk)

    a = axes if len(axes) > 1 else axes[0]
    return shard_map(local, mesh=mesh, in_specs=(P(a), P()),
                     out_specs=P(a), check_rep=False)


def abstract_sharded_ivf(n_shards: int, n_local: int, n_partitions: int,
                         pmax: int, d: int) -> ShardedIVF:
    """ShapeDtypeStruct stand-in for the production-scale dry run."""
    f = jax.ShapeDtypeStruct
    return ShardedIVF(
        f((n_shards, n_partitions, d), jnp.float32),
        f((n_shards, n_partitions, pmax), jnp.int32),
        f((n_shards, n_partitions), jnp.int32),
        f((n_shards, n_local, d), jnp.float32),
        f((n_shards,), jnp.int32))


def abstract_sharded_ivf_pq(n_shards: int, n_local: int, n_partitions: int,
                            pmax: int, d: int, m: int) -> ShardedIVFPQ:
    f = jax.ShapeDtypeStruct
    return ShardedIVFPQ(
        f((n_shards, n_partitions, d), jnp.float32),
        f((n_shards, n_partitions, pmax), jnp.int32),
        f((n_shards, n_partitions, pmax, m), jnp.uint8),
        f((n_shards, m, 16, d // m), jnp.float32),
        f((n_shards, n_partitions), jnp.int32),
        f((n_shards, n_local, d), jnp.float32),
        f((n_shards,), jnp.int32))


def sharded_ivf_pspecs(axes: Tuple[str, ...]) -> ShardedIVF:
    a = axes if len(axes) > 1 else axes[0]
    return ShardedIVF(P(a), P(a), P(a), P(a), P(a))


def sharded_ivf_pq_pspecs(axes: Tuple[str, ...]) -> ShardedIVFPQ:
    a = axes if len(axes) > 1 else axes[0]
    return ShardedIVFPQ(P(a), P(a), P(a), P(a), P(a), P(a), P(a))


def stack_filters(masks, n_local_max: Optional[int] = None) -> jax.Array:
    """Per-shard LOCAL-id filter bitmaps → (D, nmax) uint8, zero-padded.

    Padded local ids never appear in any partition slot, and a 0 bit only
    re-masks them, so over-padding is harmless. Feed the result to the
    filtered distributed search paths (sharded like the index arrays).
    """
    masks = [np.asarray(m).astype(np.uint8).ravel() for m in masks]
    nmax = int(max(m.shape[0] for m in masks)
               if n_local_max is None else n_local_max)
    out = np.zeros((len(masks), nmax), np.uint8)
    for i, m in enumerate(masks):
        out[i, :m.shape[0]] = m
    return jnp.asarray(out)


def shard_filters(global_mask, n_locals) -> jax.Array:
    """Split a GLOBAL-id bitmap into the stacked per-shard local layout.

    Global ids are the cumulative-base globalization of shard-local ids
    (ShardedIVF.local_base), so shard s's slice is simply
    global_mask[base_s : base_s + n_local_s].
    """
    gm = np.asarray(global_mask).astype(np.uint8).ravel()
    total = int(sum(n_locals))
    assert gm.shape[0] == total, (
        f"global mask covers {gm.shape[0]} ids but shards hold {total} — "
        f"a short mask would silently zero-fill (exclude) trailing shards")
    out, off = [], 0
    for nl in n_locals:
        out.append(gm[off:off + nl])
        off += nl
    return stack_filters(out)


def _local_router(C, srt, t_route):
    """Per-shard probe router inside shard_map: the shard's stacked tree
    tables when given (squeezing the size-1 lead dim), else the flat probe
    over the local centroids — op-for-op the historical inline GEMM."""
    if srt is None:
        return FlatRouter(C)
    S = srt.super_centroids.shape[1]
    return TreeRouter(srt.super_centroids[0], srt.children[0],
                      srt.child_centroids[0],
                      t_route=(max(1, -(-S // 8)) if t_route is None
                               else t_route),
                      n_partitions=C.shape[0])


def _shard_map_variants(local_search, mesh, spec, axes, with_filter,
                        with_router, with_health=False):
    """shard_map wiring shared by both distributed search makers: the
    optional filter bitmap, router-table, and health-mask args extend
    in_specs in a fixed order (ivf, Q[, filt][, router][, health])."""
    from jax.experimental.shard_map import shard_map

    a = axes if len(axes) > 1 else axes[0]
    specs = [spec, P()]
    if with_filter:
        specs.append(P(a))
    if with_router:
        specs.append(tree_router_pspecs(axes))
    if with_health:
        specs.append(P(a))

    def fn(ivf, Q, *rest):
        it = iter(rest)
        filt = next(it) if with_filter else None
        srt = next(it) if with_router else None
        health = next(it) if with_health else None
        return local_search(ivf, Q, filt, srt, health)

    return shard_map(fn, mesh=mesh, in_specs=tuple(specs),
                     out_specs=(P(), P()), check_rep=False)


def _mask_unhealthy(ids, vals, health):
    """Degraded fan-out (DESIGN.md §3.13): zero out a DOWN shard's local
    contribution before the global merge — its candidate rows become the
    (-1, -inf) padding sentinel, so the merged top-k comes entirely from
    the healthy shards (partial results, never a hang and never a stale
    answer attributed to a dead target). `health` is the (D,) uint8
    bitmap (HealthTracker.mask), sharded like the index, so each shard
    sees its own (1,) slice. With every bit set the select copies
    ids/vals through unchanged — healthy-path results stay
    bitwise-identical to the non-health trace (pinned in
    tests/test_resilience.py)."""
    if health is None:
        return ids, vals
    ok = health[0] > 0
    return (jnp.where(ok, ids, -1).astype(jnp.int32),
            jnp.where(ok, vals, -jnp.inf))


def make_replicated_search(mesh, axes: Tuple[str, ...], *, top_t: int,
                           final_k: int, rerank_budget: int = 256,
                           multiplicity: int = 2, with_filter: bool = False,
                           escalate: bool = True, params=None):
    """DATA-PARALLEL replica fan-out (DESIGN.md §3.12): the full packed
    index is REPLICATED on every device and the QUERY batch is sharded
    over `axes` — the dual of make_distributed_search, which shards the
    database and replicates queries. Returns a jit-able
    fn(PackedIVF, Q[, filter]) → (ids, scores), Q row count divisible by
    the mesh axis size (serve callers get this from
    pad_queries(multiple=R)).

    Each replica runs the SAME single-host candidate-local pipeline
    (`_search_block`, filtered escalation included) on its query slice
    with NO collectives — per-query results are bitwise identical to the
    single-device path, so a serve-time policy can flip between replica
    and shard-parallel execution without changing any answer. Replica
    fan-out is the right policy while the index fits one device and
    throughput is query-bound (the front-end's default when devices > 1);
    the shard-parallel path takes over when n outgrows device memory.

    `params`: optional serve/api.SearchParams overriding k/top_t/
    rerank_budget/escalate — the unified request API's route into the
    distributed layer (make_distributed_search takes it too).

    with_filter=True: the fn takes a trailing (n,) uint8 GLOBAL-id bitmap
    (replicated — every replica holds all ids), e.g. a tenant bitmap from
    the front-end's TenantFilterBank.

    Degraded mode (§3.13) is intentionally NOT a mask here, unlike the
    shard-parallel makers: replicas hold disjoint QUERY slices of one
    batch, so masking a dead replica would lose its queries' answers
    rather than narrow their coverage. The degraded path for replica
    fan-out lives at the front-end: a failed replica dispatch trips the
    per-target circuit breaker and the batch re-dispatches on the local
    single-device path (same data, full coverage), flagged
    `SearchResult.degraded`.
    """
    from jax.experimental.shard_map import shard_map

    if params is not None:
        p = params.validate(default_top_t=top_t,
                            default_rerank=rerank_budget)
        top_t, final_k = p.top_t, p.k
        rerank_budget, escalate = p.rerank_budget, p.escalate

    a = axes if len(axes) > 1 else axes[0]

    def local(packed, Q, filt=None):
        return _search_block(packed, Q, top_t, final_k, rerank_budget,
                             multiplicity, filt, escalate)

    fn = (local if with_filter
          else (lambda packed, Q: local(packed, Q)))
    specs = [P(), P(a)] + ([P()] if with_filter else [])
    return shard_map(fn, mesh=mesh, in_specs=tuple(specs),
                     out_specs=(P(a), P(a)), check_rep=False)


def _apply_params(params, top_t, final_k):
    """Resolve a serve/api.SearchParams against a distributed maker's
    kwargs — the unified request API's seam into this layer."""
    if params is None:
        return top_t, final_k, True
    p = params.validate(default_top_t=top_t)
    return p.top_t, p.k, p.escalate


def make_distributed_search(mesh, axes: Tuple[str, ...], *, top_t: int,
                            final_k: int = 10, multiplicity: int = 2,
                            with_filter: bool = False,
                            with_router: bool = False,
                            t_route: Optional[int] = None,
                            with_health: bool = False,
                            params=None):
    """Returns jit-able fn(ShardedIVF, Q (nq, d)) → (ids, scores) global.

    Pass multiplicity ≥ 1 + n_spills when serving multi-spill shards
    (dedup_topk_window's correctness bound); default 2 covers the
    single-spill "naive"/"soar" builds.

    with_filter=True: the returned fn takes an extra argument — a (D, n_local)
    uint8 LOCAL-id bitmap (stack_filters / shard_filters), sharded like the
    index — and masks candidates per gathered window before dedup, exactly
    the §3.9 subset semantics of the single-host engines.

    with_router=True: the fn takes a trailing ShardedTreeRouter argument
    (stack_tree_routers over the shards' build-time routers) and probes
    through each shard's two-level router at the given `t_route` (default
    ceil(S/8)) instead of the flat local GEMM — the per-shard O(c)→O(√c)
    probe reduction, shard-local like everything else.

    with_health=True: the fn takes a FINAL (D,) uint8 health bitmap
    (HealthTracker.mask, sharded like the index) and serves top-k from
    the HEALTHY shards only — a down shard's candidates become (-1,
    -inf) padding before the global merge (partial results, DESIGN.md
    §3.13). An all-ones mask is bitwise-identical to the
    with_health=False results.

    params: optional serve/api.SearchParams whose k/top_t override the
    kwargs (the unified request API, DESIGN.md §3.12).
    """
    top_t, final_k, _ = _apply_params(params, top_t, final_k)

    def local_search(ivf: ShardedIVF, Q, filt=None, srt=None, health=None):
        # leading shard dim is size 1 inside shard_map — squeeze it
        C = ivf.centroids[0]
        part_ids = ivf.part_ids[0]
        rerank = ivf.rerank[0]
        base = ivf.local_base[0]

        # batched: one router probe, then candidate-local dedup — no
        # intermediate scales with the shard size (DESIGN.md §3.6)
        router = _local_router(C, srt, t_route)
        _, parts = router.route(Q, router.clamp(top_t))
        ids = part_ids[parts].reshape(Q.shape[0], -1)      # (nq, t·pmax) local
        valid = ids >= 0
        if filt is not None:
            valid = valid & (filt[0][jnp.maximum(ids, 0)] > 0)
            ids = jnp.where(valid, ids, -1)    # filtered ≡ padding for dedup
        scores = jnp.einsum("qwd,qd->qw", rerank[jnp.maximum(ids, 0)], Q)
        scores = jnp.where(valid, scores, -jnp.inf)
        ids, vals = dedup_topk_window(ids, scores, final_k, multiplicity)
        # a tombstone-heavy mutable shard (sharded_from_indexes) can have a
        # window narrower than final_k — pad to keep the merge shapes fixed
        ids, vals = _pad_topk(ids, vals, final_k)
        # globalize local ids, preserving the -1 padding sentinel (an
        # under-filled window must not alias into the previous shard)
        ids = jnp.where(ids >= 0, ids + base, -1).astype(jnp.int32)
        ids, vals = _mask_unhealthy(ids, vals, health)
        # global merge: gather every shard's candidates, re-top-k
        ax = axes[0] if len(axes) == 1 else axes
        all_ids = jax.lax.all_gather(ids, ax, tiled=False)   # (D, nq, k)
        all_vals = jax.lax.all_gather(vals, ax, tiled=False)
        if len(axes) > 1:   # gathered over multiple axes → extra lead dims
            all_ids = all_ids.reshape((-1,) + ids.shape)
            all_vals = all_vals.reshape((-1,) + vals.shape)
        D = all_ids.shape[0]
        flat_v = jnp.moveaxis(all_vals, 0, 1).reshape(Q.shape[0], D * final_k)
        flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(Q.shape[0], D * final_k)
        v, pos = jax.lax.top_k(flat_v, final_k)
        return jnp.take_along_axis(flat_i, pos, axis=1), v

    return _shard_map_variants(local_search, mesh, sharded_ivf_pspecs(axes),
                               axes, with_filter, with_router, with_health)


def make_distributed_search_pq(mesh, axes: Tuple[str, ...], *, top_t: int,
                               final_k: int = 10, rerank_k: int = 256,
                               q_chunk: int = 128, multiplicity: int = 2,
                               with_filter: bool = False,
                               with_router: bool = False,
                               t_route: Optional[int] = None,
                               with_health: bool = False,
                               params=None):
    """PQ-scored distributed search (§Perf H3 — the paper's own pipeline).

    Per shard per q_chunk tile: batched centroid top-t → PQ-score the
    gathered t·pmax candidate windows from their uint8 codes (Pallas one-hot
    MXU kernel on TPU, + the router's coarse score) → candidate-local
    dedup-by-max + top rerank_k over the window → exact rerank of only
    those from the float data → local top-k → global all_gather merge.
    Tiles stream through lax.map to bound the live candidate buffers
    (baseline peaked at 16 GiB gathering f32 candidates).

    with_filter as in make_distributed_search: fn gains a (D, n_local)
    uint8 local-id bitmap argument masking candidates pre-dedup.
    with_router/t_route as in make_distributed_search: a trailing
    ShardedTreeRouter argument replaces the flat local probe.
    with_health as in make_distributed_search: a final (D,) uint8 health
    bitmap masks down shards out of the merge (§3.13 partial results).
    params: optional serve/api.SearchParams overriding k/top_t (§3.12).
    """
    top_t, final_k, _ = _apply_params(params, top_t, final_k)

    def local_search(ivf: ShardedIVFPQ, Q, filt=None, srt=None,
                     health=None):
        C = ivf.centroids[0]
        part_ids = ivf.part_ids[0]
        part_codes = ivf.part_codes[0]
        pqc = ivf.pq_centers[0]                   # (m, 16, s)
        rerank = ivf.rerank[0]
        base = ivf.local_base[0]
        fbits = None if filt is None else filt[0]
        m = pqc.shape[0]
        s = pqc.shape[2]
        pmax = part_ids.shape[1]
        router = _local_router(C, srt, t_route)
        tt = router.clamp(top_t)

        def tile(Qb):                                      # (bq, d)
            psc, parts = router.route(Qb, tt)
            bq = Qb.shape[0]
            tw = parts.shape[-1]         # router may return fewer than tt
            ids = part_ids[parts].reshape(bq, -1)          # (bq, t·pmax)
            valid = ids >= 0
            if fbits is not None:
                valid = valid & (fbits[jnp.maximum(ids, 0)] > 0)
                ids = jnp.where(valid, ids, -1)
            codes = part_codes[parts].reshape(bq, tw * pmax, m)
            luts = jnp.einsum("qms,mks->qmk", Qb.reshape(bq, m, s), pqc)
            approx = window_pq_scores(luts, codes)
            approx = approx + jnp.repeat(psc, pmax, axis=-1)
            approx = jnp.where(valid, approx, -jnp.inf)
            bi, bv = dedup_topk_window(ids, approx, rerank_k, multiplicity)
            exact = jnp.einsum("qbd,qd->qb", rerank[jnp.maximum(bi, 0)], Qb)
            exact = jnp.where(jnp.isfinite(bv), exact, -jnp.inf)
            v, pos = jax.lax.top_k(exact, min(final_k, exact.shape[-1]))
            gi, v = _pad_topk(jnp.take_along_axis(bi, pos, axis=-1), v,
                              final_k)
            # keep the -1 sentinel out of the global id space: an
            # under-filled tombstone-heavy shard must not alias elsewhere
            return jnp.where(gi >= 0, gi + base, -1).astype(jnp.int32), v

        nq = Q.shape[0]
        Qc = Q.reshape(nq // q_chunk, q_chunk, -1)
        ids, vals = jax.lax.map(tile, Qc)
        ids = ids.reshape(nq, final_k)
        vals = vals.reshape(nq, final_k)
        ids, vals = _mask_unhealthy(ids, vals, health)
        ax = axes[0] if len(axes) == 1 else axes
        all_ids = jax.lax.all_gather(ids, ax, tiled=False)
        all_vals = jax.lax.all_gather(vals, ax, tiled=False)
        if len(axes) > 1:
            all_ids = all_ids.reshape((-1,) + ids.shape)
            all_vals = all_vals.reshape((-1,) + vals.shape)
        D = all_ids.shape[0]
        flat_v = jnp.moveaxis(all_vals, 0, 1).reshape(nq, D * final_k)
        flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(nq, D * final_k)
        v, pos = jax.lax.top_k(flat_v, final_k)
        return jnp.take_along_axis(flat_i, pos, axis=1), v

    return _shard_map_variants(local_search, mesh,
                               sharded_ivf_pq_pspecs(axes), axes,
                               with_filter, with_router, with_health)


def sharded_from_indexes_pq(indexes) -> ShardedIVFPQ:
    """Stack per-shard PQ indexes (IVFIndex or MutableIVF) — the refresh
    path that re-serves per-shard indexes after online mutation."""
    packed, resolved, ids, cents, sizes, reranks, bases = (
        _stack_shards(indexes))
    pmax = ids[0].shape[1]
    codes, pqcs = [], []
    for pk, idx in zip(packed, resolved):
        pad = pmax - pk.part_ids.shape[1]
        codes.append(np.pad(np.asarray(pk.part_codes),
                            ((0, 0), (0, pad), (0, 0))))
        pqcs.append(np.asarray(idx.pq.centers))
    return ShardedIVFPQ(
        jnp.asarray(np.stack(cents)), jnp.asarray(np.stack(ids)),
        jnp.asarray(np.stack(codes)), jnp.asarray(np.stack(pqcs)),
        jnp.asarray(np.stack(sizes)), jnp.asarray(np.stack(reranks)),
        jnp.asarray(np.array(bases, np.int32)))


# ------------------------------------------------------------- durability
def save_sharded(path: str, indexes, *, extra=None):
    """Per-shard snapshot envelope (DESIGN.md §3.11): one integrity-
    checked snapshot subdir per shard (IVFIndex or MutableIVF — full
    mutation state survives) plus an envelope manifest, committed with a
    single atomic directory swap. Keep the PER-SHARD indexes around for
    saving rather than the stacked device arrays: the envelope restores
    them, and `sharded_from_indexes(_pq)` restacks bitwise."""
    from repro.ckpt.index_store import save_shards
    save_shards(path, indexes, extra=extra)


def load_sharded(path: str):
    """→ (per-shard index objects, extra). Restack with
    `sharded_from_indexes` / `sharded_from_indexes_pq`; any torn or
    bit-flipped shard raises CorruptSnapshotError at load."""
    from repro.ckpt.index_store import load_shards
    return load_shards(path)


def build_sharded_ivf_pq(key, X: np.ndarray, n_shards: int, n_partitions: int,
                         pq_subspaces: int, spill_mode: str = "soar",
                         lam: float = 1.0, train_iters: int = 8
                         ) -> ShardedIVFPQ:
    """Host-side build of the PQ-scored sharded index (streamed per shard)."""
    n = X.shape[0]
    assert n % n_shards == 0
    nl = n // n_shards
    indexes = [
        build_ivf_sharded(jax.random.fold_in(key, sh),
                          X[sh * nl:(sh + 1) * nl], n_partitions,
                          spill_mode=spill_mode, lam=lam,
                          pq_subspaces=pq_subspaces, train_iters=train_iters)
        for sh in range(n_shards)
    ]
    return sharded_from_indexes_pq(indexes)
