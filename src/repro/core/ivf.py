"""Inverted-file (IVF) index with spilled assignments — build + layout.

Layout follows the paper's memory model (§3.5, Figure 5):
  - centroids stored once;
  - per ASSIGNMENT (so duplicated under spilling): point id (4B) + PQ code of
    the residual w.r.t. that assignment's centroid (d/2s bytes at 16 centers);
  - per POINT (stored once): highest-bitrate rerank representation
    (int8: d bytes, or float32: 4d bytes).

Partitions are CSR-contiguous (starts/point_ids) — the linearizable,
sequential-access layout the paper contrasts with graph indices; on TPU this
is also the layout that streams HBM→VMEM efficiently.
"""
from __future__ import annotations

import functools
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import train_kmeans
from repro.kernels.soar_assign import assign_fused
from repro.core.soar import soar_assign
from repro.quant.pq import (PQCodebook, PQ_TRAIN_SAMPLE, train_pq, pq_encode,
                            _encode_block)
from repro.quant.int8 import Int8Data, int8_quantize
from repro.quant.anisotropic import anisotropic_kmeans, eta_from_threshold


@dataclass
class IVFIndex:
    centroids: np.ndarray          # (c, d) f32
    starts: np.ndarray             # (c+1,) i64 CSR partition offsets
    point_ids: np.ndarray          # (n_assign,) i32
    codes: Optional[np.ndarray]    # (n_assign, m) uint8 PQ codes (per assignment)
    pq: Optional[PQCodebook]       # shared residual codebook
    rerank_int8: Optional[Int8Data]
    rerank_f32: Optional[np.ndarray]
    assignments: np.ndarray        # (n, a) i32 — column 0 primary
    n_points: int
    spill_mode: str                # "none" | "naive" | "soar"
    lam: float
    # optional probe-stage Router (core/router.py) trained at build time
    # and serialized with the index; None → flat probe (historical)
    router: Optional[object] = None

    @property
    def n_assignments(self) -> int:
        return int(self.point_ids.shape[0])

    @property
    def n_partitions(self) -> int:
        return int(self.centroids.shape[0])

    def partition_sizes(self) -> np.ndarray:
        return np.diff(self.starts)

    def memory_bytes(self, rerank: str = "int8") -> dict:
        """Index memory accounting per the paper's model (§3.5)."""
        c, d = self.centroids.shape
        m = self.codes.shape[1] if self.codes is not None else 0
        per_assign = 4 + m * 0.5          # id + 4-bit codes (paper accounting)
        rerank_bytes = {"int8": d + 4, "f32": 4 * d}[rerank] * self.n_points
        return dict(
            centroids=4 * c * d,
            assignments=per_assign * self.n_assignments,
            rerank=rerank_bytes,
            total=4 * c * d + per_assign * self.n_assignments + rerank_bytes,
        )


@contextmanager
def _phase(timings: Optional[dict], name: str):
    """Accumulate the block's wall seconds into timings[name] (no-op when
    timings is None) — the single instrumentation point for the per-phase
    benchmark rows, so a phase can never be attributed to the wrong row."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        if timings is not None:
            timings[name] = (timings.get(name, 0.0)
                             + time.perf_counter() - t0)


def _stable_counting_sort(flat_part: np.ndarray, c: int) -> np.ndarray:
    """O(N) stable counting-sort permutation of small-int keys.

    scipy's coo→csr conversion IS a counting sort (bincount + cumsum
    offsets + one linear scatter in C) and preserves input order within
    each row; with `data = arange(N)` its CSR data array is exactly the
    stable sort permutation. Falls back to numpy's stable argsort (radix
    for ints) when scipy is unavailable — bitwise-identical either way
    (pinned in tests/test_build_perf.py).
    """
    N = flat_part.shape[0]
    if N == 0:
        return np.empty((0,), np.int64)
    try:
        import scipy.sparse as sp
    except ImportError:
        return np.argsort(flat_part, kind="stable")
    coo = sp.coo_matrix(
        (np.arange(N, dtype=np.int64),
         (flat_part, np.arange(N, dtype=np.int64))), shape=(c, N))
    return coo.tocsr().data


def _csr_from_assignments(assignments: np.ndarray, c: int):
    """(n, a) assignment matrix → CSR (starts, point_ids, assign_col)."""
    n, a = assignments.shape
    flat_part = assignments.reshape(-1)                      # (n*a,)
    order = _stable_counting_sort(flat_part, c)
    point_ids = (order // a).astype(np.int32)                # flat id = i*a+j
    counts = np.bincount(flat_part, minlength=c)
    starts = np.zeros(c + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    return starts, point_ids, order


# PQ_TRAIN_SAMPLE (re-exported from quant/pq.py): finalize_ivf mirrors
# train_pq's own subsample cap so the streamed path selects the same rows
# the materialize-everything path would


@functools.partial(jax.jit, static_argnames=("chunk",))
def _encode_residuals_fused(centers, Xd, Cd, pids, parts, chunk: int):
    """One-pass streamed residual encode: CSR gather + subtract + PQ encode
    fused in a single scan — no per-chunk host round-trips, nothing
    materialized beyond one (chunk, d) tile."""
    na = pids.shape[0]
    m, k, s = centers.shape
    pad = (-na) % chunk
    pid_t = jnp.pad(pids, (0, pad)).reshape(-1, chunk)
    part_t = jnp.pad(parts, (0, pad)).reshape(-1, chunk)

    def body(_, inp):
        pid, prt = inp
        res = Xd[pid] - Cd[prt]                       # (chunk, d) on device
        return None, _encode_block(centers, res.reshape(chunk, m, s))

    _, codes = jax.lax.scan(body, None, (pid_t, part_t))
    return codes.reshape(-1, m)[:na]


@jax.jit
def _gather_residuals(Xd, Cd, pids, parts):
    return Xd[pids] - Cd[parts]


def finalize_ivf(kpq, X, C, assignments: np.ndarray, *, pq_subspaces: int = 0,
                 rerank: str = "f32", spill_mode: str = "soar",
                 lam: float = 1.0, pq: Optional[PQCodebook] = None,
                 encode_chunk: int = 16_384,
                 fused_encode: Optional[bool] = None,
                 timings: Optional[dict] = None,
                 router=None) -> IVFIndex:
    """CSR + residual-PQ + rerank assembly shared by every build path
    (monolithic `build_ivf`, sharded `core/build.py`, mutation compaction).

    Residual encoding has two routes, bitwise-identical (pinned in
    tests/test_build_perf.py):

    - `fused_encode=True`: ONE jit'd scan fuses the CSR gather + residual
      subtract + PQ encode with no per-chunk host round-trips. It keeps X
      and the id arrays DEVICE-resident, so device peak is O(n·d) — free
      on CPU (host == device), a real constraint on accelerators;
    - `fused_encode=False`: the chunked host-loop reference — per-tile
      host gather + `pq_encode` call; device peak O(encode_chunk·d)
      however large the index.

    The default (None) picks the fused route on CPU or when X is small
    enough to sit on-device comfortably, the streamed route otherwise —
    preserving `build_ivf_sharded`'s O(shard) accelerator-memory story.
    When `pq` is passed the codebook is FROZEN (the incremental-insert
    contract, DESIGN.md §3.7): only encoding runs.

    `timings`, when given, collects per-phase wall seconds (csr, pq_train,
    encode, rerank) for the benchmark's phase rows.
    """
    Xh = np.asarray(X, np.float32)
    if fused_encode is None:
        fused_encode = (jax.default_backend() == "cpu"
                        or Xh.size <= (1 << 26))      # ≤256MB f32 on-device
    with _phase(timings, "csr"):
        Ch = np.asarray(C, np.float32)
        assignments = np.asarray(assignments, np.int32)
        n = Xh.shape[0]
        starts, point_ids, order = _csr_from_assignments(assignments,
                                                         Ch.shape[0])
    codes = None
    if pq is not None or pq_subspaces > 0:
        # residuals w.r.t. the centroid of EACH assignment, in CSR order
        flat_part = assignments.reshape(-1)[order]
        if fused_encode:    # device-resident gather sources (CPU: no copy)
            Xd = jnp.asarray(Xh)
            Cd = jnp.asarray(Ch)
            pid_d = jnp.asarray(point_ids)
            part_d = jnp.asarray(flat_part)
        if pq is None:
            with _phase(timings, "pq_train"):
                na = point_ids.shape[0]
                if na > PQ_TRAIN_SAMPLE:   # mirror train_pq's own sampling
                    sel = jax.random.choice(kpq, na, (PQ_TRAIN_SAMPLE,),
                                            replace=False)
                    if fused_encode:
                        res = _gather_residuals(Xd, Cd, pid_d[sel],
                                                part_d[sel])
                    else:
                        sel = np.asarray(sel)
                        res = jnp.asarray(Xh[point_ids[sel]]
                                          - Ch[flat_part[sel]])
                elif fused_encode:
                    res = _gather_residuals(Xd, Cd, pid_d, part_d)
                else:
                    res = jnp.asarray(Xh[point_ids] - Ch[flat_part])
                pq = train_pq(kpq, res, pq_subspaces)
        with _phase(timings, "encode"):
            m = pq.centers.shape[0]
            if point_ids.shape[0] == 0:
                codes = np.zeros((0, m), np.uint8)
            elif fused_encode:
                codes = np.asarray(_encode_residuals_fused(
                    pq.centers, Xd, Cd, pid_d, part_d, encode_chunk))
            else:
                # reference: per-chunk host gather + pq_encode round-trips
                parts_out = []
                for i in range(0, point_ids.shape[0], encode_chunk):
                    res = (Xh[point_ids[i:i + encode_chunk]]
                           - Ch[flat_part[i:i + encode_chunk]])
                    parts_out.append(
                        np.asarray(pq_encode(pq, jnp.asarray(res))))
                codes = np.concatenate(parts_out)

    with _phase(timings, "rerank"):
        rerank_int8 = (int8_quantize(jnp.asarray(Xh))
                       if rerank == "int8" else None)
        rerank_f32 = Xh if rerank == "f32" else None

    return IVFIndex(
        centroids=Ch, starts=starts, point_ids=point_ids,
        codes=codes, pq=pq, rerank_int8=rerank_int8, rerank_f32=rerank_f32,
        assignments=assignments, n_points=n, spill_mode=spill_mode, lam=lam,
        router=router)


def build_ivf(key, X, n_partitions: int, spill_mode: str = "soar",
              lam: float = 1.0, n_spills: int = 1, pq_subspaces: int = 0,
              rerank: str = "f32", train_iters: int = 15,
              anisotropic_T: float = 0.0, verbose: bool = False,
              init: str = "pp", batch_size: Optional[int] = None,
              timings: Optional[dict] = None, router=None,
              router_kw: Optional[dict] = None) -> IVFIndex:
    """Train VQ + (optionally) spilled assignments + PQ, build the index.

    spill_mode: "none" (plain IVF), "naive" (2nd-closest centroid),
    "soar" (the paper's loss). PQ codes encode the residual w.r.t. the
    assignment's own centroid (duplicated per assignment, per Figure 5).

    This is the monolithic single-host path (Lloyd sweeps over the full
    dataset). For O(shard) peak memory and sample-trained codebooks, see
    `core/build.py::build_ivf_sharded`. Primary + spill assignments run
    through the SAME fused kernel as the sharded path
    (`kernels/soar_assign.py::assign_fused`) — one shared X·Cᵀ GEMM, no
    separate train-then-spill passes. `init`/`batch_size` expose the
    flagged k-means|| / mini-batch training modes (exact path default).

    router: probe-stage router spec — None (flat inline, nothing stored),
    "tree" (train a TreeRouter over the centroids; `router_kw` forwards
    n_super/t_route/iters), "flat", or a prebuilt Router instance (the
    frozen-router rebuild contract). Trained AFTER VQ with a key derived
    via fold_in, so passing router never perturbs the kmeans/PQ streams
    (build outputs stay bitwise-identical).
    """
    from repro.core.build import spill_plan
    from repro.core.router import as_router

    X = jnp.asarray(X, jnp.float32)
    n, d = X.shape
    kkm, kpq = jax.random.split(jax.random.PRNGKey(0) if key is None else key)

    with _phase(timings, "kmeans"):
        if anisotropic_T > 0.0:
            eta = eta_from_threshold(anisotropic_T, d)
            C, primary = anisotropic_kmeans(kkm, X, n_partitions, eta,
                                            iters=max(4, train_iters // 3))
        else:
            km = train_kmeans(kkm, X, n_partitions, iters=train_iters,
                              verbose=verbose, init=init,
                              batch_size=batch_size, final_assign=False)
            C, primary = km.centroids, None

    with _phase(timings, "spill_assign"):
        if primary is not None:
            # anisotropic primaries are score-aware (not the Euclidean
            # argmin), so spills must build on the given primary column
            if spill_mode == "none":
                assignments = np.asarray(primary)[:, None]
            else:
                eff_lam, _ = spill_plan(spill_mode, lam, n_spills)
                if spill_mode != "soar" or n_spills == 1:
                    sec = soar_assign(X, C, primary, lam=eff_lam)
                    assignments = np.stack(
                        [np.asarray(primary), np.asarray(sec)], axis=1)
                else:
                    from repro.core.soar import soar_assign_multi
                    assignments = np.asarray(soar_assign_multi(
                        X, C, primary, lam=lam, n_spills=n_spills))
        else:
            eff_lam, eff_spills = spill_plan(spill_mode, lam, n_spills)
            assignments = np.asarray(assign_fused(X, C, lam=eff_lam,
                                                  n_spills=eff_spills))

    with _phase(timings, "router"):
        rt = as_router(router, np.asarray(C),
                       key=jax.random.fold_in(kkm, 0x52F7),
                       **(router_kw or {}))
    return finalize_ivf(kpq, X, C, assignments, pq_subspaces=pq_subspaces,
                        rerank=rerank, spill_mode=spill_mode, lam=lam,
                        timings=timings, router=rt)
