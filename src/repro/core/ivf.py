"""Inverted-file (IVF) index with spilled assignments — build + layout.

Layout follows the paper's memory model (§3.5, Figure 5):
  - centroids stored once;
  - per ASSIGNMENT (so duplicated under spilling): point id (4B) + PQ code of
    the residual w.r.t. that assignment's centroid (d/2s bytes at 16 centers);
  - per POINT (stored once): highest-bitrate rerank representation
    (int8: d bytes, or float32: 4d bytes).

Partitions are CSR-contiguous (starts/point_ids) — the linearizable,
sequential-access layout the paper contrasts with graph indices; on TPU this
is also the layout that streams HBM→VMEM efficiently.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import train_kmeans, assign_euclidean_topk
from repro.core.soar import soar_assign, soar_assign_multi, naive_spill_assign
from repro.quant.pq import PQCodebook, train_pq, pq_encode
from repro.quant.int8 import Int8Data, int8_quantize
from repro.quant.anisotropic import anisotropic_kmeans, eta_from_threshold


@dataclass
class IVFIndex:
    centroids: np.ndarray          # (c, d) f32
    starts: np.ndarray             # (c+1,) i64 CSR partition offsets
    point_ids: np.ndarray          # (n_assign,) i32
    codes: Optional[np.ndarray]    # (n_assign, m) uint8 PQ codes (per assignment)
    pq: Optional[PQCodebook]       # shared residual codebook
    rerank_int8: Optional[Int8Data]
    rerank_f32: Optional[np.ndarray]
    assignments: np.ndarray        # (n, a) i32 — column 0 primary
    n_points: int
    spill_mode: str                # "none" | "naive" | "soar"
    lam: float

    @property
    def n_assignments(self) -> int:
        return int(self.point_ids.shape[0])

    @property
    def n_partitions(self) -> int:
        return int(self.centroids.shape[0])

    def partition_sizes(self) -> np.ndarray:
        return np.diff(self.starts)

    def memory_bytes(self, rerank: str = "int8") -> dict:
        """Index memory accounting per the paper's model (§3.5)."""
        c, d = self.centroids.shape
        m = self.codes.shape[1] if self.codes is not None else 0
        per_assign = 4 + m * 0.5          # id + 4-bit codes (paper accounting)
        rerank_bytes = {"int8": d + 4, "f32": 4 * d}[rerank] * self.n_points
        return dict(
            centroids=4 * c * d,
            assignments=per_assign * self.n_assignments,
            rerank=rerank_bytes,
            total=4 * c * d + per_assign * self.n_assignments + rerank_bytes,
        )


def _csr_from_assignments(assignments: np.ndarray, c: int):
    """(n, a) assignment matrix → CSR (starts, point_ids, assign_col)."""
    n, a = assignments.shape
    flat_part = assignments.reshape(-1)                      # (n*a,)
    flat_pid = np.repeat(np.arange(n, dtype=np.int32), a)
    order = np.argsort(flat_part, kind="stable")
    sorted_part = flat_part[order]
    point_ids = flat_pid[order]
    counts = np.bincount(sorted_part, minlength=c)
    starts = np.zeros(c + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    return starts, point_ids, order


def build_ivf(key, X, n_partitions: int, spill_mode: str = "soar",
              lam: float = 1.0, n_spills: int = 1, pq_subspaces: int = 0,
              rerank: str = "f32", train_iters: int = 15,
              anisotropic_T: float = 0.0, verbose: bool = False) -> IVFIndex:
    """Train VQ + (optionally) spilled assignments + PQ, build the index.

    spill_mode: "none" (plain IVF), "naive" (2nd-closest centroid),
    "soar" (the paper's loss). PQ codes encode the residual w.r.t. the
    assignment's own centroid (duplicated per assignment, per Figure 5).
    """
    X = jnp.asarray(X, jnp.float32)
    n, d = X.shape
    kkm, kpq = jax.random.split(jax.random.PRNGKey(0) if key is None else key)

    if anisotropic_T > 0.0:
        eta = eta_from_threshold(anisotropic_T, d)
        C, primary = anisotropic_kmeans(kkm, X, n_partitions, eta,
                                        iters=max(4, train_iters // 3))
    else:
        km = train_kmeans(kkm, X, n_partitions, iters=train_iters, verbose=verbose)
        C, primary = km.centroids, km.assignments

    if spill_mode == "none":
        assignments = np.asarray(primary)[:, None]
    elif spill_mode == "naive":
        sec = naive_spill_assign(X, C, primary)
        assignments = np.stack([np.asarray(primary), np.asarray(sec)], axis=1)
    elif spill_mode == "soar":
        if n_spills == 1:
            sec = soar_assign(X, C, primary, lam=lam)
            assignments = np.stack([np.asarray(primary), np.asarray(sec)], axis=1)
        else:
            assignments = np.asarray(
                soar_assign_multi(X, C, primary, lam=lam, n_spills=n_spills))
    else:
        raise ValueError(spill_mode)

    starts, point_ids, order = _csr_from_assignments(assignments, n_partitions)

    codes = None
    pq = None
    if pq_subspaces > 0:
        # residuals w.r.t. the centroid of EACH assignment, in CSR order
        flat_part = assignments.reshape(-1)[order]
        flat_pid = point_ids
        residuals = np.asarray(X)[flat_pid] - np.asarray(C)[flat_part]
        pq = train_pq(kpq, jnp.asarray(residuals), pq_subspaces)
        codes = np.asarray(pq_encode(pq, jnp.asarray(residuals)))

    rerank_int8 = int8_quantize(X) if rerank == "int8" else None
    rerank_f32 = np.asarray(X) if rerank == "f32" else None

    return IVFIndex(
        centroids=np.asarray(C), starts=starts, point_ids=point_ids,
        codes=codes, pq=pq, rerank_int8=rerank_int8, rerank_f32=rerank_f32,
        assignments=assignments, n_points=n, spill_mode=spill_mode, lam=lam)
