"""Inverted-file (IVF) index with spilled assignments — build + layout.

Layout follows the paper's memory model (§3.5, Figure 5):
  - centroids stored once;
  - per ASSIGNMENT (so duplicated under spilling): point id (4B) + PQ code of
    the residual w.r.t. that assignment's centroid (d/2s bytes at 16 centers);
  - per POINT (stored once): highest-bitrate rerank representation
    (int8: d bytes, or float32: 4d bytes).

Partitions are CSR-contiguous (starts/point_ids) — the linearizable,
sequential-access layout the paper contrasts with graph indices; on TPU this
is also the layout that streams HBM→VMEM efficiently.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import train_kmeans, assign_euclidean_topk
from repro.core.soar import soar_assign, soar_assign_multi, naive_spill_assign
from repro.quant.pq import PQCodebook, train_pq, pq_encode
from repro.quant.int8 import Int8Data, int8_quantize
from repro.quant.anisotropic import anisotropic_kmeans, eta_from_threshold


@dataclass
class IVFIndex:
    centroids: np.ndarray          # (c, d) f32
    starts: np.ndarray             # (c+1,) i64 CSR partition offsets
    point_ids: np.ndarray          # (n_assign,) i32
    codes: Optional[np.ndarray]    # (n_assign, m) uint8 PQ codes (per assignment)
    pq: Optional[PQCodebook]       # shared residual codebook
    rerank_int8: Optional[Int8Data]
    rerank_f32: Optional[np.ndarray]
    assignments: np.ndarray        # (n, a) i32 — column 0 primary
    n_points: int
    spill_mode: str                # "none" | "naive" | "soar"
    lam: float

    @property
    def n_assignments(self) -> int:
        return int(self.point_ids.shape[0])

    @property
    def n_partitions(self) -> int:
        return int(self.centroids.shape[0])

    def partition_sizes(self) -> np.ndarray:
        return np.diff(self.starts)

    def memory_bytes(self, rerank: str = "int8") -> dict:
        """Index memory accounting per the paper's model (§3.5)."""
        c, d = self.centroids.shape
        m = self.codes.shape[1] if self.codes is not None else 0
        per_assign = 4 + m * 0.5          # id + 4-bit codes (paper accounting)
        rerank_bytes = {"int8": d + 4, "f32": 4 * d}[rerank] * self.n_points
        return dict(
            centroids=4 * c * d,
            assignments=per_assign * self.n_assignments,
            rerank=rerank_bytes,
            total=4 * c * d + per_assign * self.n_assignments + rerank_bytes,
        )


def _csr_from_assignments(assignments: np.ndarray, c: int):
    """(n, a) assignment matrix → CSR (starts, point_ids, assign_col)."""
    n, a = assignments.shape
    flat_part = assignments.reshape(-1)                      # (n*a,)
    flat_pid = np.repeat(np.arange(n, dtype=np.int32), a)
    order = np.argsort(flat_part, kind="stable")
    sorted_part = flat_part[order]
    point_ids = flat_pid[order]
    counts = np.bincount(sorted_part, minlength=c)
    starts = np.zeros(c + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    return starts, point_ids, order


# train_pq's own subsample cap — finalize_ivf replicates its selection so the
# streamed path is bitwise-identical to the old materialize-everything path
PQ_TRAIN_SAMPLE = 100_000


def finalize_ivf(kpq, X, C, assignments: np.ndarray, *, pq_subspaces: int = 0,
                 rerank: str = "f32", spill_mode: str = "soar",
                 lam: float = 1.0, pq: Optional[PQCodebook] = None,
                 encode_chunk: int = 65_536) -> IVFIndex:
    """CSR + residual-PQ + rerank assembly shared by every build path
    (monolithic `build_ivf`, sharded `core/build.py`, mutation compaction).

    All per-assignment float work (residual gather + PQ encode) streams in
    `encode_chunk` tiles, so accelerator peak stays O(encode_chunk·d) no
    matter how large the index; only integer CSR arrays and the host-side
    dataset are O(n). When `pq` is passed the codebook is FROZEN (the
    incremental-insert contract, DESIGN.md §3.7): only encoding runs.
    """
    Xh = np.asarray(X, np.float32)
    Ch = np.asarray(C, np.float32)
    assignments = np.asarray(assignments, np.int32)
    n = Xh.shape[0]
    starts, point_ids, order = _csr_from_assignments(assignments,
                                                     Ch.shape[0])
    codes = None
    if pq is not None or pq_subspaces > 0:
        # residuals w.r.t. the centroid of EACH assignment, in CSR order
        flat_part = assignments.reshape(-1)[order]
        if pq is None:
            na = point_ids.shape[0]
            if na > PQ_TRAIN_SAMPLE:   # mirror train_pq's internal sampling
                sel = np.asarray(jax.random.choice(
                    kpq, na, (PQ_TRAIN_SAMPLE,), replace=False))
            else:
                sel = slice(None)
            res = Xh[point_ids[sel]] - Ch[flat_part[sel]]
            pq = train_pq(kpq, jnp.asarray(res), pq_subspaces)
        parts_out = []
        for i in range(0, point_ids.shape[0], encode_chunk):
            res = (Xh[point_ids[i:i + encode_chunk]]
                   - Ch[flat_part[i:i + encode_chunk]])
            parts_out.append(np.asarray(pq_encode(pq, jnp.asarray(res))))
        m = pq.centers.shape[0]
        codes = (np.concatenate(parts_out) if parts_out
                 else np.zeros((0, m), np.uint8))

    rerank_int8 = int8_quantize(jnp.asarray(Xh)) if rerank == "int8" else None
    rerank_f32 = Xh if rerank == "f32" else None

    return IVFIndex(
        centroids=Ch, starts=starts, point_ids=point_ids,
        codes=codes, pq=pq, rerank_int8=rerank_int8, rerank_f32=rerank_f32,
        assignments=assignments, n_points=n, spill_mode=spill_mode, lam=lam)


def build_ivf(key, X, n_partitions: int, spill_mode: str = "soar",
              lam: float = 1.0, n_spills: int = 1, pq_subspaces: int = 0,
              rerank: str = "f32", train_iters: int = 15,
              anisotropic_T: float = 0.0, verbose: bool = False) -> IVFIndex:
    """Train VQ + (optionally) spilled assignments + PQ, build the index.

    spill_mode: "none" (plain IVF), "naive" (2nd-closest centroid),
    "soar" (the paper's loss). PQ codes encode the residual w.r.t. the
    assignment's own centroid (duplicated per assignment, per Figure 5).

    This is the monolithic single-host path (Lloyd iterations over the full
    dataset). For O(shard) peak memory and sample-trained codebooks, see
    `core/build.py::build_ivf_sharded`.
    """
    X = jnp.asarray(X, jnp.float32)
    n, d = X.shape
    kkm, kpq = jax.random.split(jax.random.PRNGKey(0) if key is None else key)

    if anisotropic_T > 0.0:
        eta = eta_from_threshold(anisotropic_T, d)
        C, primary = anisotropic_kmeans(kkm, X, n_partitions, eta,
                                        iters=max(4, train_iters // 3))
    else:
        km = train_kmeans(kkm, X, n_partitions, iters=train_iters, verbose=verbose)
        C, primary = km.centroids, km.assignments

    if spill_mode == "none":
        assignments = np.asarray(primary)[:, None]
    elif spill_mode == "naive":
        sec = naive_spill_assign(X, C, primary)
        assignments = np.stack([np.asarray(primary), np.asarray(sec)], axis=1)
    elif spill_mode == "soar":
        if n_spills == 1:
            sec = soar_assign(X, C, primary, lam=lam)
            assignments = np.stack([np.asarray(primary), np.asarray(sec)], axis=1)
        else:
            assignments = np.asarray(
                soar_assign_multi(X, C, primary, lam=lam, n_spills=n_spills))
    else:
        raise ValueError(spill_mode)

    return finalize_ivf(kpq, X, C, assignments, pq_subspaces=pq_subspaces,
                        rerank=rerank, spill_mode=spill_mode, lam=lam)
