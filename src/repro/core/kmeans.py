"""Vector-quantization training: k-means++ / k-means|| init + fused Lloyd.

The training loop is built from single-pass fused sweeps
(`kernels/lloyd.py`): each iteration streams X once, computing chunk
assignments AND per-centroid sums/counts in the scan carry — no (n,)
assignment vector, no second pass over X (the two-pass `lloyd_step` is
kept below as the unfused reference the bitwise tests pin against).

Seeding:
- `kmeans_pp_init` (default, exact D^2 sampling): the c sequential picks
  are unavoidable for k-means++, but each step is one GEMV
  (||x||^2 - 2<x, c_new> + ||c_new||^2) plus an inverse-CDF draw
  (cumsum + searchsorted, ONE uniform per pick) instead of a broadcast
  (n, d) residual and an n-wide Gumbel draw — ~6x faster at 50k x 100.
- `kmeans_parallel_init` (init="parallel"): k-means||-style over-sampling
  (Bahmani et al.) — a handful of rounds each drawing `oversample*c`
  candidates at once (Gumbel top-k, D^2-proportional without
  replacement), then a weighted k-means++ / Lloyd finish on the candidate
  set. Kills the c-step sequential loop; quality is recall-equivalent
  (tests/test_build_perf.py) but the realization differs from k-means++,
  so it is opt-in.

Mini-batch mode (`batch_size=`): web-scale k-means (Sculley) — each
iteration sweeps a random batch and folds it into the centroids with
per-centroid running-count learning rates. Opt-in; the default full-batch
path is the exact Lloyd recursion.

Supports spherical mode (centroids renormalized, for angular/MIPS data);
anisotropic (score-aware) training lives in repro.quant.anisotropic.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lloyd import _xct, lloyd_sweep, lloyd_sweep_auto
from repro.utils import chunked_map, pairwise_neg_sqdist_argmin


def _xv(X, v):
    """X (n, d) @ v (d,) — small-d unrolled like kernels/lloyd._xct."""
    return _xct(X, v[:, None])[..., 0]


class KMeansResult(NamedTuple):
    centroids: jax.Array                # (c, d)
    assignments: Optional[jax.Array]    # (n,) int32 primary (None if skipped)
    distortion: jax.Array               # scalar mean ||x - c||^2
    history: np.ndarray                 # per-iteration distortion


@functools.partial(jax.jit, static_argnames=("c",))
def kmeans_pp_init(key, X, c: int):
    """k-means++ seeding, fully compiled (fori_loop over c picks).

    Exact D^2 sampling via inverse-CDF (cumsum + one uniform per pick);
    distances update through the reassociated GEMV form, so each pick is
    one streaming pass over X with no (n, d) broadcast intermediate.
    """
    n, d = X.shape
    xn = jnp.sum(X * X, axis=-1)
    k0, kloop = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    init_c = jnp.zeros((c, d), X.dtype).at[0].set(X[first])
    init_d = jnp.maximum(xn - 2.0 * _xv(X, X[first]) + jnp.sum(X[first] ** 2),
                         0.0)

    def body(i, state):
        cents, min_d, key = state
        key, kp = jax.random.split(key)
        # sample next center proportional to squared distance (inverse CDF)
        cdf = jnp.cumsum(min_d)
        u = jax.random.uniform(kp, ()) * cdf[-1]
        idx = jnp.minimum(jnp.searchsorted(cdf, u), n - 1)
        nxt = X[idx]
        cents = cents.at[i].set(nxt)
        dn = jnp.maximum(xn - 2.0 * _xv(X, nxt) + jnp.sum(nxt * nxt), 0.0)
        return cents, jnp.minimum(min_d, dn), key

    cents, _, _ = jax.lax.fori_loop(1, c, body, (init_c, init_d, kloop))
    return cents


@functools.partial(jax.jit, static_argnames=("c", "l", "rounds",
                                             "finish_iters", "chunk"))
def kmeans_parallel_init(key, X, c: int, l: int, rounds: int = 4,
                         finish_iters: int = 6, chunk: int = 8192):
    """k-means||-style over-sampling init (flagged; see module docstring).

    rounds x l candidates drawn D^2-proportionally (Gumbel top-l, without
    replacement), weighted by their Voronoi counts over X, then reduced to
    c seeds with weighted k-means++ + `finish_iters` weighted Lloyd steps
    on the candidate set only.
    """
    n, d = X.shape
    xn = jnp.sum(X * X, axis=-1)
    k0, kw = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    min_d = jnp.maximum(xn - 2.0 * (X @ X[first]) + jnp.sum(X[first] ** 2),
                        0.0)
    ncand = 1 + rounds * l
    cands = jnp.zeros((ncand, d), X.dtype).at[0].set(X[first])
    for r in range(rounds):                         # static unroll, r small
        kr = jax.random.fold_in(kw, r)
        g = jax.random.gumbel(kr, (n,))
        _, pick = jax.lax.top_k(jnp.log(jnp.maximum(min_d, 1e-30)) + g, l)
        newc = X[pick]                              # (l, d)
        cands = jax.lax.dynamic_update_slice_in_dim(cands, newc, 1 + r * l, 0)
        nn = jnp.sum(newc * newc, axis=-1)

        def upd(blk, nc=newc, ncn=nn):
            xb, mdb = blk[:, :d], blk[:, d]
            dnew = jnp.min(ncn[None, :] - 2.0 * (xb @ nc.T), axis=-1)
            dnew = jnp.maximum(dnew + jnp.sum(xb * xb, axis=-1), 0.0)
            return jnp.minimum(mdb, dnew)

        min_d = chunked_map(upd, jnp.concatenate([X, min_d[:, None]], -1),
                            chunk)

    # weight candidates by how much data they attract
    cn_cand = jnp.sum(cands * cands, axis=-1)

    def vor(xb):
        return jnp.argmin(cn_cand[None, :] - 2.0 * (xb @ cands.T),
                          axis=-1).astype(jnp.int32)

    owner = chunked_map(vor, X, chunk)
    w = jax.ops.segment_sum(jnp.ones((n,), X.dtype), owner,
                            num_segments=ncand)

    # weighted k-means++ over the (small) candidate set
    kpp, klloyd = jax.random.split(jax.random.fold_in(key, rounds))
    cfirst = jnp.argmax(w)                          # heaviest candidate
    seeds = jnp.zeros((c, d), X.dtype).at[0].set(cands[cfirst])
    cd = jnp.sum((cands - cands[cfirst]) ** 2, axis=-1)

    def pp_body(i, state):
        sds, dmin, kk = state
        kk, kp = jax.random.split(kk)
        cdf = jnp.cumsum(jnp.maximum(dmin, 0.0) * w)
        u = jax.random.uniform(kp, ()) * cdf[-1]
        idx = jnp.minimum(jnp.searchsorted(cdf, u), ncand - 1)
        nxt = cands[idx]
        sds = sds.at[i].set(nxt)
        return sds, jnp.minimum(dmin, jnp.sum((cands - nxt) ** 2, -1)), kk

    seeds, _, _ = jax.lax.fori_loop(1, c, pp_body, (seeds, cd, kpp))

    def lloyd_body(_, sds):
        sn = jnp.sum(sds * sds, axis=-1)
        a = jnp.argmin(sn[None, :] - 2.0 * (cands @ sds.T), axis=-1)
        sums = jax.ops.segment_sum(cands * w[:, None], a, num_segments=c)
        cw = jax.ops.segment_sum(w, a, num_segments=c)
        return jnp.where(cw[:, None] > 0, sums / jnp.maximum(cw[:, None], 1.0),
                         sds)

    return jax.lax.fori_loop(0, finish_iters, lloyd_body, seeds)


@functools.partial(jax.jit, static_argnames=("c", "chunk"))
def lloyd_step(X, C, c: int, chunk: int = 16384):
    """One UNFUSED Lloyd iteration: assign + mean update (two passes over X,
    materializes the (n,) assignment). Kept as the reference implementation
    the fused `lloyd_sweep` is bitwise-pinned against at matched reduction
    order (tests/test_build_perf.py); the training loop itself uses the
    sweep. Empty clusters keep their old center."""
    assign, min_d = pairwise_neg_sqdist_argmin(X, C, chunk=chunk)
    sums = jax.ops.segment_sum(X, assign, num_segments=c)
    counts = jax.ops.segment_sum(jnp.ones((X.shape[0],), X.dtype), assign,
                                 num_segments=c)
    new_C = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), C)
    return new_C, assign, jnp.mean(min_d)


@functools.partial(jax.jit, static_argnames=("c", "batch_size", "chunk"))
def _minibatch_step(X, C, v, key, c: int, batch_size: int, chunk: int):
    """One mini-batch sweep + running-count centroid blend (Sculley)."""
    sel = jax.random.randint(key, (batch_size,), 0, X.shape[0])
    bc, counts, dist = lloyd_sweep(X[sel], C, c,
                                   chunk=min(chunk, batch_size))
    v = v + counts
    eta = counts / jnp.maximum(v, 1.0)
    C = jnp.where(counts[:, None] > 0,
                  C * (1.0 - eta[:, None]) + bc * eta[:, None], C)
    return C, v, dist


def _stopped(prev: float, d: float, tol: float) -> bool:
    return prev - d < tol * max(abs(prev), 1e-12)


def train_kmeans(key, X, c: int, iters: int = 15, chunk: int = 8192,
                 spherical: bool = False, init_sample: int = 32_768,
                 tol: float = 1e-5, verbose: bool = False,
                 init: str = "pp", init_rounds: int = 4,
                 init_oversample: float = 2.0,
                 batch_size: Optional[int] = None,
                 final_assign: bool = True) -> KMeansResult:
    """Full VQ training. Host loop over jit'd fused sweeps (early stop and
    logging stay host-side; the per-iteration device program is ONE scan).

    init: "pp" (exact k-means++, default) or "parallel" (k-means||
    over-sampling — kills the c sequential picks; recall-equivalent but a
    different random realization, so opt-in).
    batch_size: None (exact full-batch Lloyd, default) or a mini-batch
    size for Sculley-style web-scale updates (opt-in approximation).
    final_assign: skip the trailing full re-assignment pass when the
    caller computes assignments itself (e.g. build_ivf routes them
    through the fused primary+spill kernel); assignments is then None and
    distortion reports the last sweep's value.
    """
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    kinit, _ = jax.random.split(key)
    if n > init_sample:
        # without replacement: duplicates shrink the effective sample
        # (~16% at 32k-of-90k) and measurably cost codebook quality; this
        # runs ONCE per training so the O(n) permutation is cheap here
        sel = jax.random.choice(kinit, n, (init_sample,), replace=False)
        Xi = X[sel]
    else:
        Xi = X
    if init == "pp":
        C = kmeans_pp_init(kinit, Xi, c)
    elif init == "parallel":
        # the per-round oversample can never exceed the candidate pool
        # (Gumbel top-l is without replacement over the init sample) —
        # c within 1/init_oversample of the sample size crashed top_k
        C = kmeans_parallel_init(kinit, Xi, c,
                                 l=min(int(init_oversample * c),
                                       int(Xi.shape[0])),
                                 rounds=init_rounds)
    else:
        raise ValueError(f"unknown init {init!r}")

    hist = []
    prev = np.inf
    dist = jnp.array(np.inf)
    if batch_size is not None:
        v = jnp.zeros((c,), jnp.float32)
        for it in range(iters):
            kb = jax.random.fold_in(key, 1000 + it)
            C, v, dist = _minibatch_step(X, C, v, kb, c, batch_size, chunk)
            if spherical:
                C = C / jnp.maximum(
                    jnp.linalg.norm(C, axis=-1, keepdims=True), 1e-12)
            hist.append(float(dist))       # batch distortion: no early stop
            if verbose:
                print(f"kmeans mb-iter {it}: batch distortion {hist[-1]:.6f}")
    else:
        for it in range(iters):
            C, _, dist = lloyd_sweep_auto(X, C, c, chunk=chunk)
            if spherical:
                C = C / jnp.maximum(
                    jnp.linalg.norm(C, axis=-1, keepdims=True), 1e-12)
            d = float(dist)
            hist.append(d)
            if verbose:
                print(f"kmeans iter {it}: distortion {d:.6f}")
            if _stopped(prev, d, tol):
                break
            prev = d
    if not final_assign:
        return KMeansResult(C, None, dist, np.asarray(hist))
    # final re-assignment against the final centroids
    assign, min_d = pairwise_neg_sqdist_argmin(X, C, chunk=chunk)
    return KMeansResult(C, assign, jnp.mean(min_d), np.asarray(hist))


@functools.partial(jax.jit, static_argnames=("chunk",))
def assign_euclidean(X, C, chunk: int = 16384):
    """Primary VQ assignment: nearest centroid by squared L2."""
    assign, _ = pairwise_neg_sqdist_argmin(X, C, chunk=chunk)
    return assign


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def assign_euclidean_topk(X, C, k: int, chunk: int = 16384):
    """Top-k nearest centroids per point (for naive spilling baselines)."""
    Cn = jnp.sum(C * C, axis=-1)

    def f(xb):
        d = Cn[None, :] - 2.0 * (xb @ C.T)
        _, idx = jax.lax.top_k(-d, k)
        return idx.astype(jnp.int32)

    return chunked_map(f, X, chunk)
