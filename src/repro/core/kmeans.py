"""Vector-quantization training: k-means++ init + Lloyd's iterations.

All heavy math is jit-compiled and chunked so memory stays bounded at
n·chunk rather than n·c. Supports spherical mode (centroids renormalized,
for angular/MIPS data) and anisotropic (score-aware) assignment/update via
repro.quant.anisotropic.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import chunked_map, pairwise_neg_sqdist_argmin


class KMeansResult(NamedTuple):
    centroids: jax.Array      # (c, d)
    assignments: jax.Array    # (n,) int32 primary assignment
    distortion: jax.Array     # scalar mean ||x - c||^2
    history: np.ndarray       # per-iteration distortion


@functools.partial(jax.jit, static_argnames=("c",))
def kmeans_pp_init(key, X, c: int):
    """k-means++ seeding, fully compiled (fori_loop over c picks)."""
    n, d = X.shape
    k0, kloop = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    init_c = jnp.zeros((c, d), X.dtype).at[0].set(X[first])
    init_d = jnp.sum((X - X[first]) ** 2, axis=-1)

    def body(i, state):
        cents, min_d, key = state
        key, kp = jax.random.split(key)
        # sample next center proportional to squared distance
        idx = jax.random.categorical(kp, jnp.log(jnp.maximum(min_d, 1e-30)))
        nxt = X[idx]
        cents = cents.at[i].set(nxt)
        min_d = jnp.minimum(min_d, jnp.sum((X - nxt) ** 2, axis=-1))
        return cents, min_d, key

    cents, _, _ = jax.lax.fori_loop(1, c, body, (init_c, init_d, kloop))
    return cents


@functools.partial(jax.jit, static_argnames=("c", "chunk"))
def lloyd_step(X, C, c: int, chunk: int = 16384):
    """One Lloyd iteration: assign + mean update. Empty clusters keep old center."""
    assign, min_d = pairwise_neg_sqdist_argmin(X, C, chunk=chunk)
    sums = jax.ops.segment_sum(X, assign, num_segments=c)
    counts = jax.ops.segment_sum(jnp.ones((X.shape[0],), X.dtype), assign, num_segments=c)
    new_C = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), C)
    return new_C, assign, jnp.mean(min_d)


def train_kmeans(key, X, c: int, iters: int = 15, chunk: int = 16384,
                 spherical: bool = False, init_sample: int = 50_000,
                 tol: float = 1e-5, verbose: bool = False) -> KMeansResult:
    """Full VQ training. Host loop over jit'd steps (allows early stop/logging)."""
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    kinit, _ = jax.random.split(key)
    if n > init_sample:
        sel = jax.random.choice(kinit, n, (init_sample,), replace=False)
        C = kmeans_pp_init(kinit, X[sel], c)
    else:
        C = kmeans_pp_init(kinit, X, c)
    hist = []
    prev = np.inf
    assign = None
    dist = jnp.array(np.inf)
    for it in range(iters):
        C, assign, dist = lloyd_step(X, C, c, chunk=chunk)
        if spherical:
            C = C / jnp.maximum(jnp.linalg.norm(C, axis=-1, keepdims=True), 1e-12)
        d = float(dist)
        hist.append(d)
        if verbose:
            print(f"kmeans iter {it}: distortion {d:.6f}")
        if prev - d < tol * max(abs(prev), 1e-12):
            break
        prev = d
    # final re-assignment against the final centroids
    assign, min_d = pairwise_neg_sqdist_argmin(X, C, chunk=chunk)
    return KMeansResult(C, assign, jnp.mean(min_d), np.asarray(hist))


@functools.partial(jax.jit, static_argnames=("chunk",))
def assign_euclidean(X, C, chunk: int = 16384):
    """Primary VQ assignment: nearest centroid by squared L2."""
    assign, _ = pairwise_neg_sqdist_argmin(X, C, chunk=chunk)
    return assign


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def assign_euclidean_topk(X, C, k: int, chunk: int = 16384):
    """Top-k nearest centroids per point (for naive spilling baselines)."""
    Cn = jnp.sum(C * C, axis=-1)

    def f(xb):
        d = Cn[None, :] - 2.0 * (xb @ C.T)
        _, idx = jax.lax.top_k(-d, k)
        return idx.astype(jnp.int32)

    return chunked_map(f, X, chunk)
