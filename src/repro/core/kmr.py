"""K-means recall (KMR) curves — Eq. (1) of the paper, partition-size weighted.

KMR_k(t) = mean fraction of true top-k neighbors whose (best) assigned
partition ranks within the query's top-t partitions. Following §5.1, curves
are reported against the cumulative NUMBER OF DATAPOINTS in the top-t
partitions (spilled indices have larger partitions, so equal-t comparisons
would flatter spilling).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import IVFIndex
from repro.utils import topk_inner_product


class KMRCurve(NamedTuple):
    recall_at_t: np.ndarray        # (c,) mean recall when searching top-t parts
    points_at_t: np.ndarray        # (c,) mean cumulative datapoints read
    name: str


def true_neighbors(X, Q, k: int = 100, chunk: int = 8192) -> np.ndarray:
    _, ids = topk_inner_product(jnp.asarray(Q), jnp.asarray(X), k, chunk=chunk)
    return np.asarray(ids)


@functools.partial(jax.jit, static_argnames=("k",))
def _kmr_core(C, sizes, assigns, Q, true_ids, k: int):
    """assigns: (n, a) int32; true_ids: (nq, k).

    Returns (recall_hist (nq, c), cum_points (nq, c)) where recall_hist[q, t-1]
    is the count of neighbors found within top-t, cum_points the datapoints read.
    """
    c = C.shape[0]
    scores = Q @ C.T                                    # (nq, c)
    order = jnp.argsort(-scores, axis=1)                # rank → partition
    # rankpos[q, part] = rank of partition for this query
    rankpos = jnp.zeros_like(order).at[
        jnp.arange(Q.shape[0])[:, None], order].set(
        jnp.broadcast_to(jnp.arange(c), order.shape))
    cum_points = jnp.cumsum(sizes[order], axis=1)       # (nq, c)
    nbr_assign = assigns[true_ids]                      # (nq, k, a)
    rp = jnp.take_along_axis(
        rankpos[:, None, :], nbr_assign.astype(jnp.int32), axis=2)  # (nq,k,a)
    best = jnp.min(rp, axis=2)                          # (nq, k) best rank (0-based)
    # histogram over ranks → cumulative = neighbors found within top-t
    onehot = jax.nn.one_hot(best, c, dtype=jnp.float32).sum(axis=1)  # (nq, c)
    found = jnp.cumsum(onehot, axis=1)
    return found / k, cum_points


def kmr_curve(index: IVFIndex, Q, true_ids, k: int = 100, name: str = "") -> KMRCurve:
    sizes = jnp.asarray(index.partition_sizes().astype(np.float32))
    recall, pts = _kmr_core(
        jnp.asarray(index.centroids), sizes, jnp.asarray(index.assignments),
        jnp.asarray(Q, jnp.float32), jnp.asarray(true_ids), k)
    return KMRCurve(np.asarray(recall.mean(0)), np.asarray(pts.mean(0)),
                    name or index.spill_mode)


def points_to_recall(curve: KMRCurve, target: float) -> float:
    """Mean datapoints that must be read to reach `target` mean recall
    (linear interpolation between adjacent t; inf if unreachable)."""
    r, p = curve.recall_at_t, curve.points_at_t
    idx = np.searchsorted(r, target)
    if idx >= len(r):
        return float("inf")
    if idx == 0 or r[idx] == target:
        return float(p[idx])
    r0, r1, p0, p1 = r[idx - 1], r[idx], p[idx - 1], p[idx]
    if r1 <= r0:
        return float(p[idx])
    w = (target - r0) / (r1 - r0)
    return float(p0 + w * (p1 - p0))


def rank_statistics(index: IVFIndex, Q, true_ids):
    """Per (query, neighbor): primary-centroid rank and spilled-centroid rank
    (Figure 8 data). Requires a spilled index (a >= 2)."""
    C = jnp.asarray(index.centroids)
    Qj = jnp.asarray(Q, jnp.float32)
    scores = Qj @ C.T
    order = jnp.argsort(-scores, axis=1)
    c = C.shape[0]
    rankpos = jnp.zeros_like(order).at[
        jnp.arange(Qj.shape[0])[:, None], order].set(
        jnp.broadcast_to(jnp.arange(c), order.shape))
    nbr_assign = jnp.asarray(index.assignments)[jnp.asarray(true_ids)]  # (nq,k,a)
    rp = jnp.take_along_axis(rankpos[:, None, :],
                             nbr_assign.astype(jnp.int32), axis=2)
    return np.asarray(rp[..., 0]), np.asarray(rp[..., 1])  # primary, spilled
