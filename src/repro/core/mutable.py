"""Mutable packed SOAR index: online insert/delete over a frozen codebook
(DESIGN.md §3.7).

A serving index cannot rebuild-the-world per mutation. Following the
SPANN/ScaNN playbook, the VQ codebook and PQ codebook are FROZEN at build
time, which makes mutations local:

- **insert**: the new vectors' primary + SOAR spill assignments are one
  fused-assign call against the fixed centroids (`kernels/soar_assign.py`)
  plus PQ encoding of their residuals — O(batch · c), nothing global moves;
- **delete**: a tombstone — the point's partition slots are blanked to -1
  (exactly the padding sentinel the search pipeline already masks to -inf),
  so deletion needs no data movement at all;
- **compaction**: tombstones waste probed-window slots, so when more than
  `compact_threshold` of occupied slots are dead, one vectorized pass
  shifts live slots left per partition and shrinks `sizes`.

Partition arrays are padded to a capacity that grows geometrically, so
appends are amortized O(batch). Point ids are STABLE across every mutation
(external handles never dangle); id space is append-only and dead rerank
rows are reclaimed only by `compact(reclaim=True)`.

Search serves from snapshots: `pack()` → PackedIVF for the candidate-local
jit pipeline, `to_ivf_index()` → CSR IVFIndex for the numpy engine. The
packed snapshot is maintained INCREMENTALLY (delta pack): the device
arrays are cached at the padded capacity width, mutations record which
partitions (and which appended rerank rows) they touched, and the next
`pack()` scatters only those rows into the cached arrays — skipping the
host-side O(index) re-pack and the full host→device re-upload (the
device-side buffer copies remain; see `_apply_pack_delta`). Because the
width is capacity-stable, the serving jit pipeline also stops
recompiling when pmax drifts across mutations.
Slot growth or compaction fall back to a full repack. The CSR snapshot
stays invalidate-on-mutation (the numpy engine re-reads it wholesale).
The equivalence contract — an index mutated into a state equals a
from-scratch build of that state against the same frozen stages — is
pinned by tests/test_mutable.py; delta-pack vs full-pack identity by
tests/test_build_perf.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.wal import REC_ADD, REC_COMPACT, REC_HARDEN, REC_REMOVE
from repro.core.build import build_ivf_sharded, spill_plan
from repro.core.ivf import IVFIndex
from repro.core.search import PackedIVF, _paired_codes
from repro.kernels.soar_assign import assign_fused
from repro.quant.pq import PQCodebook, pq_encode


def _grow_rows(arr: np.ndarray, n_new: int, fill) -> np.ndarray:
    """Geometric row growth to at least n_new rows."""
    if arr.shape[0] >= n_new:
        return arr
    cap = max(n_new, 2 * arr.shape[0], 64)
    out = np.full((cap,) + arr.shape[1:], fill, arr.dtype)
    out[:arr.shape[0]] = arr
    return out


class EpochLRU:
    """Epoch-keyed LRU of derived values (device filter bitmaps).

    Generalizes the PR 5 standing-filter cache OUT of MutableIVF: an
    entry is (epoch, value) under a caller key; `get` returns the cached
    value only while the epoch matches, else rebuilds via the callback
    and refreshes the entry. Capacity-1 instances back the index's own
    standing tombstone bitmap; the serving front-end's TenantFilterBank
    (serve/frontend.py, DESIGN.md §3.12) holds a capacity-N instance
    keyed by tenant, so steady-state tenant serving pays zero per-search
    host composition or upload, and a mutation (epoch bump) invalidates
    every tenant's bitmap at once without touching device memory until a
    tenant is next served."""

    def __init__(self, capacity: int = 1):
        from collections import OrderedDict
        self.capacity = max(1, int(capacity))
        self._d = OrderedDict()
        self.fills = 0              # cache-miss rebuilds (tests/telemetry)

    def get(self, key, epoch, build):
        hit = self._d.get(key)
        if hit is not None and hit[0] == epoch:
            self._d.move_to_end(key)
            return hit[1]
        val = build()
        self.fills += 1
        self._d[key] = (epoch, val)
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
        return val

    def drop(self, key):
        self._d.pop(key, None)

    def __contains__(self, key):
        return key in self._d

    def __len__(self):
        return len(self._d)


@dataclass
class MutableIVF:
    """Mutable padded-partition SOAR index over frozen VQ/PQ codebooks."""
    centroids: np.ndarray               # (c, d) f32, FROZEN
    pq: Optional[PQCodebook]            # FROZEN (None → no PQ stage)
    spill_mode: str
    lam: float
    n_spills: int                       # spills per point (0 for "none")
    part_ids: np.ndarray                # (c, cap) int32; -1 = empty/tombstone
    part_codes: Optional[np.ndarray]    # (c, cap, m) uint8
    sizes: np.ndarray                   # (c,) int32 slots in use (incl. dead)
    rerank: np.ndarray                  # (cap_n, d) f32 by point id
    assignments: np.ndarray             # (cap_n, a) int32; -1 rows dead/unused
    alive: np.ndarray                   # (cap_n,) bool
    n_total: int                        # high-water point id (append-only)
    n_dead_slots: int = 0
    n_soft_deleted: int = 0             # alive=False but slots NOT blanked
    compact_threshold: float = 0.25
    # probe-stage Router (core/router.py), FROZEN like the codebooks:
    # online `add` routes through the build-time tables untouched; snapshots
    # serve a derived view with emptied partitions pruned (_serving_router)
    router: Optional[object] = None
    _packed: Optional[PackedIVF] = field(default=None, repr=False)
    _packed_pair: Optional[bool] = field(default=None, repr=False)
    _csr: Optional[IVFIndex] = field(default=None, repr=False)
    # delta-pack state: partitions / appended-id range touched since the
    # cached _packed was last synced; None marks "needs full repack"
    _dirty_parts: Optional[np.ndarray] = field(default=None, repr=False)
    _dirty_ids: int = field(default=0, repr=False)      # rerank rows synced
    # standing-filter cache: device uint8 alive bitmap, keyed by an epoch
    # bumped whenever `alive` mutates (add/remove) — a capacity-1 EpochLRU
    # (the front-end's per-tenant bank is the capacity-N generalization)
    _alive_epoch: int = field(default=0, repr=False)
    _filter_cache: EpochLRU = field(default_factory=EpochLRU, repr=False)
    # serving-router cache, keyed by the live-partition mask (see
    # _serving_router)
    _router_dev: Optional[object] = field(default=None, repr=False)
    _router_key: Optional[bytes] = field(default=None, repr=False)
    # durability (DESIGN.md §3.11): sequence number of the last mutation
    # covered by this state — a snapshot stores it, and WAL replay skips
    # records at or below it. _wal, when attached, gets one CRC-framed
    # record per mutation BEFORE the mutation applies (write-ahead).
    wal_seq: int = 0
    _wal: Optional[object] = field(default=None, repr=False)
    _replaying: bool = field(default=False, repr=False)

    # ------------------------------------------------------------ builders
    @classmethod
    def from_index(cls, idx: IVFIndex, compact_threshold: float = 0.25,
                   capacity_slack: float = 1.25) -> "MutableIVF":
        """Wrap a built IVFIndex (any builder) into the mutable layout."""
        c = idx.n_partitions
        sizes = idx.partition_sizes().astype(np.int32)
        cap = max(8, int(np.ceil(sizes.max() * capacity_slack))
                  if sizes.size else 8)
        part_ids = np.full((c, cap), -1, np.int32)
        m = idx.codes.shape[1] if idx.codes is not None else 0
        part_codes = np.zeros((c, cap, m), np.uint8) if m else None
        part = np.repeat(np.arange(c), sizes)
        pos = (np.arange(idx.n_assignments)
               - np.repeat(idx.starts[:-1], sizes)).astype(np.int64)
        part_ids[part, pos] = idx.point_ids
        if m:
            part_codes[part, pos] = idx.codes
        data = idx.rerank_f32
        if data is None:
            from repro.quant.int8 import int8_dequantize
            data = np.asarray(int8_dequantize(idx.rerank_int8))
        a = idx.assignments.shape[1]
        _, n_spills = spill_plan(idx.spill_mode, idx.lam, a - 1)
        return cls(
            centroids=np.asarray(idx.centroids, np.float32), pq=idx.pq,
            spill_mode=idx.spill_mode, lam=idx.lam, n_spills=n_spills,
            part_ids=part_ids, part_codes=part_codes, sizes=sizes,
            rerank=np.ascontiguousarray(data, dtype=np.float32),
            assignments=np.asarray(idx.assignments, np.int32).copy(),
            alive=np.ones(idx.n_points, bool), n_total=idx.n_points,
            compact_threshold=compact_threshold, router=idx.router)

    @classmethod
    def build(cls, key, X, n_partitions: int, **kw) -> "MutableIVF":
        """Sharded build (core/build.py) → mutable wrap."""
        compact_threshold = kw.pop("compact_threshold", 0.25)
        idx = build_ivf_sharded(key, X, n_partitions, **kw)
        return cls.from_index(idx, compact_threshold=compact_threshold)

    # ------------------------------------------------------------ accessors
    @property
    def n_alive(self) -> int:
        return int(self.alive[:self.n_total].sum())

    @property
    def n_slots(self) -> int:
        return int(self.sizes.sum())

    @property
    def dead_fraction(self) -> float:
        s = self.n_slots
        return self.n_dead_slots / s if s else 0.0

    def _invalidate(self):
        """Full snapshot invalidation (capacity growth / compaction)."""
        self._packed = None
        self._csr = None
        self._dirty_parts = None

    def invalidate_snapshots(self):
        """Public full invalidation: the next `pack()`/`to_ivf_index()`
        rebuilds from scratch instead of delta-updating. Exists for
        benchmarking the delta path against a forced full re-pack and for
        callers that externally mutate the numpy mirrors."""
        self._invalidate()

    def _mark_dirty(self, parts: np.ndarray):
        """Record a local mutation: only `parts` rows changed. The CSR
        snapshot is rebuilt wholesale (numpy engine), the packed snapshot
        delta-updates those rows on the next pack()."""
        self._csr = None
        if self._packed is None or self._dirty_parts is None:
            self._packed = None
            self._dirty_parts = None
            return
        self._dirty_parts[parts] = True

    # ---------------------------------------------------------- durability
    def attach_wal(self, wal, replay: bool = True) -> int:
        """Attach a MutationWAL (ckpt/wal.py): every subsequent mutation
        appends one record before applying. With `replay` (default), any
        committed records in the log with seq > this state's `wal_seq`
        are applied first — the open-after-crash path (snapshot + WAL →
        the exact live state). Returns how many records were replayed."""
        import os

        from repro.ckpt.wal import read_records
        n = 0
        if replay and os.path.exists(wal.path):
            for seq, rtype, meta, arrays in read_records(wal.path):
                if self.replay_record(seq, rtype, meta, arrays):
                    n += 1
        self._wal = wal
        return n

    def replay_record(self, seq: int, rtype: int, meta: dict,
                      arrays: dict) -> bool:
        """Apply one WAL record if it postdates this state (seq >
        wal_seq). Mutations replay through the SAME code paths that
        logged them — determinism of those paths (frozen-codebook fused
        assignment, stable sorts) is what makes recovery bitwise."""
        from repro.ckpt.index_store import CorruptSnapshotError
        if seq <= self.wal_seq:
            return False               # already folded into the snapshot
        self._replaying = True
        try:
            if rtype == REC_ADD:
                self.add(arrays["x"])
            elif rtype == REC_REMOVE:
                self.remove(arrays["ids"], hard=bool(meta["hard"]))
            elif rtype == REC_HARDEN:
                self.harden_soft_deletes()
            elif rtype == REC_COMPACT:
                self._compact_impl()
            else:
                raise CorruptSnapshotError(
                    f"unknown WAL record type {rtype} (seq {seq})")
        finally:
            self._replaying = False
        self.wal_seq = seq
        return True

    def _log(self, rtype: int, meta: Optional[dict] = None,
             arrays: Optional[dict] = None):
        """Write-ahead: append the record (durably, per the WAL's fsync
        policy) BEFORE the mutation applies. A crash after the append
        recovers to the post-mutation state via replay; a crash during it
        leaves a torn record that recovery drops — either way a committed
        state, never a hybrid."""
        if self._wal is not None and not self._replaying:
            self.wal_seq = self._wal.append(rtype, meta, arrays)

    # ------------------------------------------------------------ mutation
    def add(self, X_new) -> np.ndarray:
        """Insert a batch of vectors; returns their (stable) point ids.

        Assignments are computed incrementally against the frozen codebook
        via the fused batched path; PQ codes encode the residual w.r.t.
        each assignment's centroid, exactly as at build time.
        """
        X_new = np.atleast_2d(np.asarray(X_new, np.float32))
        b = X_new.shape[0]
        if b == 0:
            return np.empty((0,), np.int32)
        self._log(REC_ADD, arrays={"x": X_new})
        eff_lam, eff_spills = spill_plan(self.spill_mode, self.lam,
                                         self.n_spills)
        # right-size the streamed tile: a 64-row online insert must not pay
        # for an 8192-row padded tile (compile cache is per chunk size, and
        # online batch sizes are few and repeated)
        chunk = min(8192, max(256, 1 << (b - 1).bit_length()))
        A = np.asarray(assign_fused(jnp.asarray(X_new),
                                    jnp.asarray(self.centroids),
                                    lam=eff_lam, n_spills=eff_spills,
                                    chunk=chunk))
        a = A.shape[1]
        ids = np.arange(self.n_total, self.n_total + b, dtype=np.int32)
        cap_parts0 = self.part_ids.shape[1]
        cap_rerank0 = self.rerank.shape[0]

        # per-point state (geometric growth keeps appends amortized O(b))
        need = self.n_total + b
        self.rerank = _grow_rows(self.rerank, need, 0.0)
        self.assignments = _grow_rows(self.assignments, need, -1)
        self.alive = _grow_rows(self.alive, need, False)
        self.rerank[self.n_total:need] = X_new
        self.assignments[self.n_total:need] = A
        self.alive[self.n_total:need] = True
        self._alive_epoch += 1

        # partition inserts: group the (b·a) flat entries by partition and
        # append each group at its partition's current fill offset
        # (same O(N) stable counting sort as the CSR builder)
        from repro.core.ivf import _stable_counting_sort
        flat_part = A.reshape(-1)
        flat_pid = np.repeat(ids, a)
        order = _stable_counting_sort(flat_part, self.centroids.shape[0])
        sp = flat_part[order]
        counts = np.bincount(sp, minlength=self.centroids.shape[0])
        new_sizes = self.sizes + counts.astype(np.int32)
        cap = self.part_ids.shape[1]
        if new_sizes.max() > cap:
            new_cap = max(int(new_sizes.max()), 2 * cap)
            grown = np.full((self.part_ids.shape[0], new_cap), -1, np.int32)
            grown[:, :cap] = self.part_ids
            self.part_ids = grown
            if self.part_codes is not None:
                m = self.part_codes.shape[2]
                gc = np.zeros((self.part_codes.shape[0], new_cap, m),
                              np.uint8)
                gc[:, :cap] = self.part_codes
                self.part_codes = gc
        rank = np.arange(sp.shape[0]) - np.searchsorted(sp, sp)
        pos = self.sizes[sp] + rank
        self.part_ids[sp, pos] = flat_pid[order]
        if self.pq is not None and self.part_codes is not None:
            res = np.repeat(X_new, a, axis=0) - self.centroids[flat_part]
            ec = min(16384, max(256, 1 << (res.shape[0] - 1).bit_length()))
            codes = np.asarray(pq_encode(self.pq, jnp.asarray(res),
                                         chunk=ec))
            self.part_codes[sp, pos] = codes[order]
        self.sizes = new_sizes
        self.n_total = need
        if (self.part_ids.shape[1] != cap_parts0
                or self.rerank.shape[0] != cap_rerank0):
            self._invalidate()       # capacity grew → cached shapes stale
        else:
            self._mark_dirty(np.unique(sp))
        return ids

    def remove(self, ids: Sequence[int], hard: bool = True) -> int:
        """Tombstone a batch of point ids; returns how many were removed.

        hard=True (default): slots blank to -1 (the search pipelines'
        existing padding sentinel) — no data moves. Compaction runs
        automatically once the dead-slot fraction crosses
        `compact_threshold`.

        hard=False: the point is only marked dead in the `alive` bitmap —
        nothing else is touched, NO snapshot invalidation, no device
        traffic. Soft tombstones are served through the standing filter
        bitmap (`filter_bitmap()`; DESIGN.md §3.9 unifies them with user
        subset filters), which every filter-aware search path ANDs in.
        They are hardened lazily: any later hard `remove`/`compact` leaves
        them in place, and `harden_soft_deletes()` converts them in one
        batch when their slot waste starts to matter.
        """
        ids = np.unique(np.asarray(ids, np.int64))
        ids = ids[(ids >= 0) & (ids < self.n_total)]
        ids = ids[self.alive[ids]]
        if ids.size == 0:
            return 0
        self._log(REC_REMOVE, {"hard": bool(hard)}, {"ids": ids})
        self._alive_epoch += 1
        if not hard:
            self.alive[ids] = False
            self.n_soft_deleted += int(ids.size)
            return int(ids.size)
        self.alive[ids] = False
        self._blank_slots(ids)
        return int(ids.size)

    def _blank_slots(self, ids: np.ndarray):
        """Hard-tombstone bookkeeping shared by remove(hard=True) and
        harden_soft_deletes: blank the ids' partition slots to -1, retire
        their assignment rows, mark dirty, maybe compact."""
        rows = np.unique(self.assignments[ids].reshape(-1))
        rows = rows[rows >= 0]
        sub = self.part_ids[rows]
        dead = np.isin(sub, ids)
        self.part_ids[rows] = np.where(dead, -1, sub)
        self.n_dead_slots += int(dead.sum())
        self.assignments[ids] = -1
        self._mark_dirty(rows)
        if self.dead_fraction > self.compact_threshold:
            # implied by the remove/harden record already logged — logging
            # it again would double-compact on replay
            self._compact_impl()

    def compact(self):
        """Shift live slots left within each partition, dropping tombstones.

        One vectorized stable argsort per row; slot order (hence search
        tie-breaking) of survivors is preserved. Point ids do not change.
        """
        self._log(REC_COMPACT)
        self._compact_impl()

    def _compact_impl(self):
        hole = self.part_ids < 0
        order = np.argsort(hole, axis=1, kind="stable")   # live slots first
        self.part_ids = np.take_along_axis(self.part_ids, order, axis=1)
        if self.part_codes is not None:
            self.part_codes = np.take_along_axis(
                self.part_codes, order[:, :, None], axis=1)
        self.sizes = (self.part_ids >= 0).sum(axis=1).astype(np.int32)
        self.n_dead_slots = 0
        self._invalidate()

    def harden_soft_deletes(self) -> int:
        """Convert soft tombstones (alive=False, slots intact) into hard
        ones (slots blanked to -1) in one batch — reclaims their probed-
        window slots once filter masking alone wastes too many. Returns
        how many were hardened; may trigger compaction."""
        self._log(REC_HARDEN)
        dead = np.flatnonzero(~self.alive[:self.n_total]
                              & (self.assignments[:self.n_total, 0] >= 0))
        self.n_soft_deleted = 0
        if dead.size == 0:
            return 0
        self._blank_slots(dead)
        return int(dead.size)

    # ------------------------------------------------------------ filtering
    @property
    def standing_filter_thin(self) -> bool:
        """True when the standing soft-tombstone filter is selective enough
        (majority of ids dead) that probe escalation can plausibly help;
        serving paths skip the fixed second escalation pass otherwise."""
        return 2 * self.n_soft_deleted > self.n_total

    def serving_filter(self, mask: Optional[np.ndarray] = None,
                       ids: Optional[Sequence[int]] = None,
                       escalate: bool = True):
        """(device filter | None, escalate) plan for the jit serving
        paths — the single source of truth for the standing-vs-user rule,
        routed through by AnnEngine.search and KNNMemory.retrieve:

        - no user subset → the CACHED standing bitmap (only if soft
          tombstones exist), with escalation additionally gated on
          `standing_filter_thin` (a fat tombstone filter can never trigger
          escalation usefully, so don't pay its fixed second probe pass);
        - user subset → a freshly composed + uploaded `filter_bitmap`,
          escalation left to the caller's choice."""
        if mask is None and ids is None:
            if not self.n_soft_deleted:
                return None, escalate
            return (self.standing_filter(),
                    escalate and self.standing_filter_thin)
        return jnp.asarray(self.filter_bitmap(mask=mask, ids=ids)), escalate

    def standing_filter(self) -> jax.Array:
        """Cached DEVICE uint8 alive bitmap at capacity width — the
        no-user-subset standing filter (soft tombstones). Rebuilt and
        re-uploaded only when `alive` has mutated since the last call
        (EpochLRU keyed on the alive epoch + capacity width), so
        steady-state serving with a standing filter pays zero per-search
        host work or transfer."""
        return self._filter_cache.get(
            None, (self._alive_epoch, self.alive.shape[0]),
            lambda: jnp.asarray(self.alive.astype(np.uint8)))

    def filter_bitmap(self, mask: Optional[np.ndarray] = None,
                      ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Standing serving filter (DESIGN.md §3.9): the alive bitmap —
        which already carries every soft tombstone — AND'd with an optional
        user subset given as a bitmap over point ids and/or an explicit id
        allowlist. Returned as uint8 at the rerank CAPACITY width, so the
        jit engines' per-window filter gather keeps a mutation-stable shape
        (no recompiles as n_total drifts); capacity rows beyond n_total are
        0 and unreachable anyway."""
        out = self.alive.astype(np.uint8).copy()
        if ids is not None:
            sel = np.zeros_like(out)
            ii = np.asarray(ids, np.int64).ravel()
            ii = ii[(ii >= 0) & (ii < out.shape[0])]
            sel[ii] = 1
            out &= sel
        if mask is not None:
            m = np.zeros(out.shape[0], np.uint8)
            mm = np.asarray(mask).astype(bool).ravel()[:out.shape[0]]
            m[:mm.shape[0]] = mm
            out &= m
        return out

    # ------------------------------------------------------------ snapshots
    def _serving_router(self):
        """Router view served by snapshots — the frozen-router analogue of
        the frozen codebook: the build-time tables never retrain, but a
        TreeRouter is REFRESHED against the current live-partition mask
        (children of partitions whose every slot is tombstoned prune to
        -1, so probe slots are not wasted reaching empty partitions after
        heavy deletion/compaction churn). Cached by the mask, so steady-
        state packs pay a c-bit compare, and an `add` that repopulates an
        emptied partition un-prunes it on the next snapshot."""
        if self.router is None:
            return None
        live = (self.part_ids >= 0).any(axis=1)
        key = live.tobytes()
        if self._router_dev is None or self._router_key != key:
            rt = self.router
            if hasattr(rt, "pruned"):
                rt = rt.pruned(live)
            self._router_dev = rt.device()
            self._router_key = key
        return self._router_dev

    def _apply_pack_delta(self, p: PackedIVF) -> PackedIVF:
        """Scatter only the dirty partition rows / appended rerank rows
        into the cached device snapshot.

        What this saves vs a full re-pack: the host-side O(index) re-pack
        work (paired-code recompute, live-size scan) and the host→device
        upload of every array — only the touched rows cross the host
        boundary. The eager `.at[].set` updates still COPY the device
        buffers (device-side memcpy is O(index) bytes; true O(touched)
        would need buffer donation), but device memcpy is far cheaper
        than the host path: ~1.8x per add+pack+search step at n=100k.
        At toy scale the fixed dispatch overhead dominates and a full
        re-pack wins — see the bench's smoke cadence rows."""
        dirty = np.flatnonzero(self._dirty_parts)
        part_ids, part_codes = p.part_ids, p.part_codes
        part_codes2, sizes = p.part_codes2, p.sizes
        if dirty.size:
            di = jnp.asarray(dirty)
            rows = self.part_ids[dirty]
            part_ids = part_ids.at[di].set(jnp.asarray(rows))
            sizes = sizes.at[di].set(
                jnp.asarray((rows >= 0).sum(axis=1).astype(np.int32)))
            if part_codes is not None:
                crows = self.part_codes[dirty]
                part_codes = part_codes.at[di].set(jnp.asarray(crows))
                if part_codes2 is not None:
                    part_codes2 = part_codes2.at[di].set(
                        jnp.asarray(_paired_codes(crows)))
        rerank = p.rerank
        if self._dirty_ids < self.n_total:
            new_rows = jnp.asarray(self.rerank[self._dirty_ids:self.n_total])
            rerank = jax.lax.dynamic_update_slice_in_dim(
                rerank, new_rows, self._dirty_ids, 0)
        self._dirty_parts[:] = False
        self._dirty_ids = self.n_total
        return PackedIVF(p.centroids, part_ids, part_codes, part_codes2,
                         sizes, self.pq, rerank, self._serving_router())

    def pack(self, pair_codes: Optional[bool] = None) -> PackedIVF:
        """Padded snapshot for the candidate-local jit pipeline (cached;
        the pair_codes choice is part of the cache identity).

        The snapshot is built at the CAPACITY width of the padded
        partition arrays (not the tight pmax): shapes then stay stable
        across mutations, so (1) the serving jit pipeline never recompiles
        mid-stream and (2) subsequent pack() calls after add/remove only
        scatter the touched rows (delta pack) instead of re-packing and
        re-uploading the whole index. Extra padded slots carry the -1
        sentinel the search window already masks — results are identical
        to a tight pack."""
        if pair_codes is None:
            pair_codes = jax.default_backend() != "tpu"
        if (self._packed is not None and self._packed_pair == pair_codes
                and self._dirty_parts is not None):
            if self._dirty_parts.any() or self._dirty_ids < self.n_total:
                self._packed = self._apply_pack_delta(self._packed)
            return self._packed
        ids = self.part_ids
        codes = self.part_codes
        live_sizes = (ids >= 0).sum(axis=1).astype(np.int32)
        self._packed = PackedIVF(
            jnp.asarray(self.centroids), jnp.asarray(ids),
            jnp.asarray(codes) if codes is not None else None,
            (jnp.asarray(_paired_codes(codes))
             if codes is not None and pair_codes else None),
            jnp.asarray(live_sizes), self.pq,
            jnp.asarray(self.rerank), self._serving_router())
        self._packed_pair = pair_codes
        self._dirty_parts = np.zeros(ids.shape[0], bool)
        self._dirty_ids = self.n_total
        return self._packed

    def to_ivf_index(self) -> IVFIndex:
        """CSR snapshot of the live assignments (numpy engine; cached).

        Point ids keep their stable values; dead rerank rows remain in the
        array (they are never referenced by any partition slot).
        """
        if self._csr is not None:
            return self._csr
        c, cap = self.part_ids.shape
        mask = self.part_ids >= 0
        counts = mask.sum(axis=1)
        starts = np.zeros(c + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        point_ids = self.part_ids[mask].astype(np.int32)
        codes = self.part_codes[mask] if self.part_codes is not None else None
        self._csr = IVFIndex(
            centroids=self.centroids, starts=starts, point_ids=point_ids,
            codes=codes, pq=self.pq, rerank_int8=None,
            rerank_f32=self.rerank[:self.n_total],
            assignments=self.assignments[:self.n_total],
            n_points=self.n_total, spill_mode=self.spill_mode, lam=self.lam,
            router=self._serving_router())
        return self._csr

    def rebuild_reference(self, key=None) -> IVFIndex:
        """From-scratch build of the CURRENT live state against the same
        frozen codebook/PQ (the mutation-equivalence comparator)."""
        live = np.flatnonzero(self.alive[:self.n_total])
        return build_ivf_sharded(
            key, self.rerank[live], self.centroids.shape[0],
            spill_mode=self.spill_mode, lam=self.lam,
            n_spills=max(self.n_spills, 1), codebook=self.centroids,
            pq=self.pq, router=self.router)
