"""Pluggable partition-probe routing (DESIGN.md §3.10).

Every search path used to hardcode the probe stage as a flat
``Q @ centroids.T`` GEMM plus top-t — duplicated (with three different
clamping/escalation behaviors) across the numpy engine, the jit engine,
and both distributed local-search paths. That inlined GEMM is the O(c)
cost ceiling the SPANN-style scale plan has to remove before partitions
can multiply: many smaller partitions are only affordable if choosing
them costs o(c).

This module makes the probe stage a first-class ``Router``:

- ``FlatRouter``: the exact flat GEMM + top-t, op-for-op identical to the
  pre-refactor inline code on both engines (bitwise probe sets, pinned by
  tests/test_router.py) — the default everywhere, so existing traces,
  jaxpr pins, and committed baselines are unchanged;
- ``TreeRouter``: a two-level k-means-over-centroids router (SPANN's
  "small index over the centroids"): score ``t_route`` super-clusters,
  then top-t among only their children — O(S·d + t_route·cmax·d) per
  query instead of O(c·d), which unlocks configs with 8-32x more,
  smaller partitions at a fraction of the probe FLOPs.

Routers are jax pytrees (array leaves + static aux), so they pass
straight through jit boundaries; every router answers both engines
(``route`` traced / ``route_numpy`` host) and owns the clamping and
filtered-escalation policy the call sites used to duplicate:

- ``clamp``: the single source of the ``top_t = min(top_t, c)`` rule;
- ``escalated``: one doubling step of the selectivity-escalation ladder —
  flat doubles top_t; tree doubles BOTH top_t and t_route, so escalation
  widens the reachable candidate set, not just the cut within it.

The route contract: ``route(Q, top_t) -> (scores (nq, t'), parts
(nq, t'))`` with partitions ordered by descending score and
``t' = min(top_t, reachable)``. A starved slot (tree router with fewer
reachable children than top_t) carries score ``-inf`` and partition 0 —
downstream the PQ path adds the -inf coarse score (candidates never
surface) and the exact path at worst re-probes partition 0's window
(duplicates dedup away), so results stay valid.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def clamp_top_t(top_t: int, n_partitions: int) -> int:
    """THE probe-width clamp (`argpartition` needs kth < c, `lax.top_k`
    width <= c). Previously duplicated — with drift — in search_numpy,
    _search_block, and AnnEngine; every entry point now routes through
    here (regression-pinned by tests/test_router.py)."""
    return max(0, min(int(top_t), int(n_partitions)))


def check_query_dim(Q, d: int, what: str = "index centroids"):
    """Clear ValueError instead of an opaque GEMM broadcast error when the
    query dimensionality does not match the index."""
    qd = Q.shape[-1] if getattr(Q, "ndim", 1) else None
    if qd != d:
        raise ValueError(
            f"query feature dim {qd} does not match {what} dim {d} "
            f"(Q.shape={tuple(Q.shape)})")


@jax.tree_util.register_pytree_node_class
class FlatRouter:
    """Exact flat probe: one Q·Cᵀ GEMM + top-t. Bitwise-identical to the
    pre-refactor inline code on both engines."""

    def __init__(self, centroids):
        self.centroids = centroids

    def tree_flatten(self):
        return (self.centroids,), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0])

    @property
    def n_partitions(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def d(self) -> int:
        return int(self.centroids.shape[1])

    def clamp(self, top_t: int) -> int:
        return clamp_top_t(top_t, self.n_partitions)

    def can_escalate(self, top_t: int) -> bool:
        return top_t < self.n_partitions

    def escalated(self, top_t: int):
        """One escalation step: doubled top_t, same router."""
        return self, self.clamp(2 * top_t)

    def probe_flops(self, top_t: int) -> int:
        """Per-query probe-stage multiply count (the O(c) ceiling)."""
        return self.n_partitions * self.d

    def device(self) -> "FlatRouter":
        """jnp-backed copy (pack-time upload for the jit serving path)."""
        return FlatRouter(jnp.asarray(self.centroids))

    def route(self, Q, top_t: int):
        """(nq, d) -> (scores (nq, t), parts (nq, t)), score-descending.
        EXACTLY the ops of the pre-refactor inline probe (jaxpr-pinned)."""
        scores_c = Q @ self.centroids.T                    # (nq, c) one GEMM
        return jax.lax.top_k(scores_c, top_t)

    def route_numpy(self, Q, top_t: int):
        """Host probe, op-for-op the pre-refactor `_search_numpy_pass`
        head: argpartition + score-descending reorder (bitwise probe
        sets; pinned by tests/test_router.py)."""
        C = np.asarray(self.centroids)
        scores_c = Q @ C.T                                 # (nq, c)
        top_parts = np.argpartition(-scores_c, top_t - 1,
                                    axis=1)[:, :top_t]
        row = np.arange(Q.shape[0])[:, None]
        ordsel = np.argsort(-scores_c[row, top_parts], axis=1)
        top_parts = top_parts[row, ordsel]
        return scores_c[row, top_parts], top_parts


@jax.tree_util.register_pytree_node_class
class TreeRouter:
    """Two-level centroid router: k-means over the centroids themselves.

    Arrays (pytree leaves):
      super_centroids: (S, d) f32 — the second-level codebook;
      children:        (S, cmax) int32 partition ids per super, -1 pad;
      child_centroids: (S, cmax, d) f32 — centroid rows grouped by super
                       (zeros at padding; masked by children >= 0).

    Static aux: t_route (supers probed per query), n_partitions (the
    total partition count c, for the clamp/escalation policy).

    route = top-t_route supers by one (nq, S) GEMM, then top-t among only
    their children — per-query probe FLOPs S·d + t_route·cmax·d vs c·d
    flat. At t_route = S every child is scored and routing degrades to
    exact flat routing (same probe sets; property-pinned).
    """

    def __init__(self, super_centroids, children, child_centroids,
                 t_route: int, n_partitions: int):
        self.super_centroids = super_centroids
        self.children = children
        self.child_centroids = child_centroids
        self.t_route = int(t_route)
        self._n_partitions = int(n_partitions)
        self._host = None          # lazy (SC, CH, CC) numpy mirror

    def tree_flatten(self):
        return ((self.super_centroids, self.children, self.child_centroids),
                (self.t_route, self._n_partitions))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, t_route=aux[0], n_partitions=aux[1])

    # ------------------------------------------------------------ geometry
    @property
    def n_partitions(self) -> int:
        return self._n_partitions

    @property
    def n_super(self) -> int:
        return int(self.super_centroids.shape[0])

    @property
    def cmax(self) -> int:
        return int(self.children.shape[1])

    @property
    def d(self) -> int:
        return int(self.super_centroids.shape[1])

    @property
    def eff_t_route(self) -> int:
        return max(1, min(self.t_route, self.n_super))

    def clamp(self, top_t: int) -> int:
        return clamp_top_t(top_t, self.n_partitions)

    def can_escalate(self, top_t: int) -> bool:
        # escalation can widen the cut (top_t) OR the reachable set
        # (t_route); exhausted only when both are maxed
        return (top_t < self.n_partitions
                or self.eff_t_route < self.n_super)

    def escalated(self, top_t: int):
        """One escalation step THROUGH the router: doubled top_t and
        doubled t_route — a thin filtered window needs more reachable
        partitions, not just a wider cut among the same children."""
        return (self.with_t_route(min(2 * self.eff_t_route, self.n_super)),
                self.clamp(2 * top_t))

    def with_t_route(self, t_route: int) -> "TreeRouter":
        return TreeRouter(self.super_centroids, self.children,
                          self.child_centroids, t_route=t_route,
                          n_partitions=self._n_partitions)

    def probe_flops(self, top_t: int) -> int:
        return self.d * (self.n_super + self.eff_t_route * self.cmax)

    def device(self) -> "TreeRouter":
        """jnp-backed copy (pack-time upload for the jit serving path)."""
        return TreeRouter(jnp.asarray(self.super_centroids),
                          jnp.asarray(self.children),
                          jnp.asarray(self.child_centroids),
                          t_route=self.t_route,
                          n_partitions=self._n_partitions)

    def pruned(self, live) -> "TreeRouter":
        """Drop children whose partitions hold no live slots (-1 them),
        so probe slots are not wasted on empty partitions — the router
        refresh hook MutableIVF runs at snapshot time after compaction /
        tombstone churn. `live` is a (c,) bool host array. The trained
        tables stay frozen; pruning is a derived view."""
        ch = np.asarray(self.children)
        live = np.asarray(live, bool)
        keep = (ch >= 0) & live[np.maximum(ch, 0)]
        if keep.all():
            return self
        return TreeRouter(self.super_centroids,
                          np.where(keep, ch, -1).astype(np.int32),
                          self.child_centroids, t_route=self.t_route,
                          n_partitions=self._n_partitions)

    # ------------------------------------------------------------ routing
    def route(self, Q, top_t: int):
        """Traced two-level probe. Dispatches to the fused Pallas kernel
        on TPU (kernels/tree_route.py), the chunked jnp reference
        elsewhere; final top-t over the (nq, t_route·cmax) candidate
        scores happens here either way."""
        from repro.kernels.tree_route import tree_route
        scores, cand = tree_route(Q, self.super_centroids,
                                  self.child_centroids, self.children,
                                  t_route=self.eff_t_route)
        k = min(top_t, scores.shape[-1])
        v, pos = jax.lax.top_k(scores, k)
        parts = jnp.take_along_axis(cand, pos, axis=-1)
        # starved slots (fewer reachable children than top_t): partition 0
        # with a -inf score — see the module docstring's contract
        return v, jnp.maximum(parts, 0)

    def _host_arrays(self):
        if self._host is None:
            self._host = (np.asarray(self.super_centroids),
                          np.asarray(self.children),
                          np.asarray(self.child_centroids))
        return self._host

    def route_numpy(self, Q, top_t: int):
        SC, CH, CC = self._host_arrays()
        nq = Q.shape[0]
        tr = self.eff_t_route
        ss = Q @ SC.T                                      # (nq, S)
        sup = np.argpartition(-ss, tr - 1, axis=1)[:, :tr]
        cand = CH[sup].reshape(nq, -1)                     # (nq, tr·cmax)
        cc = CC[sup].reshape(nq, cand.shape[1], -1)
        sc = np.einsum("qkd,qd->qk", cc, Q)
        sc[cand < 0] = -np.inf
        k = min(top_t, cand.shape[1])
        row = np.arange(nq)[:, None]
        topc = np.argpartition(-sc, k - 1, axis=1)[:, :k]
        ordsel = np.argsort(-sc[row, topc], axis=1)
        topc = topc[row, ordsel]
        return sc[row, topc], np.maximum(cand[row, topc], 0)


def train_tree_router(key, centroids, n_super: Optional[int] = None,
                      t_route: Optional[int] = None, iters: int = 8
                      ) -> TreeRouter:
    """Two-level router training: k-means over the c centroids via the
    SAME fused Lloyd sweep as the main build (kernels/lloyd.py through
    core/kmeans.train_kmeans — one scan per iteration, nothing (c, S)-
    shaped materialized), then an exact Euclidean child assignment and a
    counting-sort grouping into the padded (S, cmax) children table.

    Defaults: n_super = round(sqrt(c)) (the O(sqrt(c)) balance point),
    t_route = ceil(n_super / 8) (probe ~1/8 of the supers; the recall/
    FLOPs benches sweep this).
    """
    from repro.core.kmeans import assign_euclidean, train_kmeans

    C = np.asarray(centroids, np.float32)
    c, d = C.shape
    S = int(n_super) if n_super else max(1, int(round(math.sqrt(c))))
    S = min(S, c)
    if t_route is None:
        t_route = max(1, -(-S // 8))
    if key is None:
        key = jax.random.PRNGKey(0)
    if S >= c:                      # degenerate: every centroid its own super
        SC = C.copy()
        assign = np.arange(c, dtype=np.int32)
    else:
        km = train_kmeans(key, C, S, iters=iters, final_assign=False)
        SC = np.asarray(km.centroids, np.float32)
        assign = np.asarray(assign_euclidean(jnp.asarray(C),
                                             jnp.asarray(SC)))
    counts = np.bincount(assign, minlength=S)
    cmax = max(1, int(counts.max()))
    children = np.full((S, cmax), -1, np.int32)
    order = np.argsort(assign, kind="stable")
    sp = assign[order]
    pos = np.arange(c) - np.searchsorted(sp, sp)
    children[sp, pos] = order.astype(np.int32)
    child_centroids = np.zeros((S, cmax, d), np.float32)
    child_centroids[sp, pos] = C[order]
    return TreeRouter(SC, children, child_centroids,
                      t_route=int(t_route), n_partitions=c)


def as_router(spec, centroids, key=None, **kw):
    """Resolve a router spec at build time: None -> None (flat inline
    behavior, nothing stored), "flat" -> FlatRouter over the index's own
    centroids, "tree" -> train_tree_router(**kw), or pass a Router
    instance through (the frozen-router rebuild contract)."""
    if spec is None:
        return None
    if isinstance(spec, str):
        if spec == "flat":
            return FlatRouter(np.asarray(centroids, np.float32))
        if spec == "tree":
            return train_tree_router(key, centroids, **kw)
        raise ValueError(f"unknown router spec {spec!r}")
    return spec
