"""ANN search over a (possibly spilled) IVF index.

Two execution paths, both candidate-local (DESIGN.md §3.6): every per-query
intermediate is bounded by the probed candidate window (top_t·pmax entries),
never by the database size n — the property that keeps SOAR's spilled IVF
sublinear at serving time.

- `search_numpy`: host-orchestrated ragged search (like ScaNN's CPU engine):
  jit'd centroid scoring, one batch-level CSR gather, vectorized PQ LUT
  scoring, per-query segment dedup (a point may appear in 2+ searched
  partitions under spilling), exact rerank. Used by the recall/QPS benchmarks.

- `search_jit`: fixed-budget, fully-jit pipeline (padded partitions) — the
  TPU-target path the Pallas kernels and the distributed serving engine use.
  Batched centroid GEMM + top-t, gathered candidate windows, PQ LUT scoring
  through the one-hot MXU Pallas kernel on TPU (jnp gather fallback
  elsewhere), sort-based dedup-by-max over the window, exact rerank.
  `search_jit_batched` streams large query batches through `bq`-sized tiles
  so live buffers stay bounded regardless of nq.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import IVFIndex
from repro.quant.pq import pq_lut, PQCodebook


class SearchStats(NamedTuple):
    points_read: np.ndarray     # (nq,) assignments scanned (incl. duplicates)
    unique_candidates: np.ndarray


def _ragged_gather(starts: np.ndarray, top_parts: np.ndarray):
    """Batch-level CSR gather: one flat index vector for every (query,
    partition) segment in the batch.

    Returns (cand_rows, qidx, seg_part, row_lens): flat CSR row of each
    candidate, its query, its source partition, and per-query totals.
    """
    nq, t = top_parts.shape
    seg_starts = starts[top_parts].ravel()                       # (nq*t,)
    seg_lens = (starts[top_parts + 1] - starts[top_parts]).ravel()
    offs = np.concatenate([[0], np.cumsum(seg_lens)])
    total = int(offs[-1])
    ar = np.arange(total, dtype=np.int64)
    cand_rows = ar - np.repeat(offs[:-1], seg_lens) + np.repeat(seg_starts,
                                                                seg_lens)
    row_lens = seg_lens.reshape(nq, t).sum(axis=1)
    qidx = np.repeat(np.arange(nq, dtype=np.int64), row_lens)
    seg_part = np.repeat(top_parts.ravel(), seg_lens)
    return cand_rows, qidx, seg_part, row_lens


def _group_ranks(group: np.ndarray, n_groups: int) -> np.ndarray:
    """Rank of each element within its (sorted, contiguous) group."""
    starts = np.searchsorted(group, np.arange(n_groups))
    return np.arange(len(group)) - starts[group]


def search_numpy(index: IVFIndex, Q: np.ndarray, top_t: int,
                 final_k: int = 10, rerank_budget: int = 0):
    """Returns (ids (nq, final_k), SearchStats). rerank_budget=0 → exact
    scoring of all candidates (no PQ stage).

    Fully vectorized over the batch: one ragged CSR gather, one LUT gather,
    and `np.lexsort`-based per-query segment dedup — no per-query Python loop.
    """
    Q = np.asarray(Q, np.float32)
    nq = Q.shape[0]
    C = index.centroids
    scores_c = Q @ C.T                                   # (nq, c)
    top_parts = np.argpartition(-scores_c, top_t - 1, axis=1)[:, :top_t]
    # order the selected partitions by score (stable probe order)
    row = np.arange(nq)[:, None]
    ordsel = np.argsort(-scores_c[row, top_parts], axis=1)
    top_parts = top_parts[row, ordsel]

    use_pq = index.codes is not None and rerank_budget > 0
    data = index.rerank_f32
    if data is None:
        from repro.quant.int8 import int8_dequantize
        data = np.asarray(int8_dequantize(index.rerank_int8))

    cand_rows, qidx, seg_part, row_lens = _ragged_gather(index.starts,
                                                         top_parts)
    cand_ids = index.point_ids[cand_rows].astype(np.int64)
    # composite (query, id) key: one dedup pass for the whole batch
    key = qidx * np.int64(index.n_points) + cand_ids

    if use_pq:
        luts = np.asarray(
            jax.vmap(lambda q: pq_lut(index.pq, q))(jnp.asarray(Q)))
        codes = index.codes[cand_rows]                    # (total, m)
        m = codes.shape[1]
        approx = luts[qidx[:, None], np.arange(m)[None, :],
                      codes].sum(axis=1)
        approx = approx + scores_c[qidx, seg_part]        # + <q, centroid>
        # dedup: keep best approx score per (query, id)
        order = np.lexsort((-approx, key))
        key_s = key[order]
        keep = np.ones(len(order), bool)
        keep[1:] = key_s[1:] != key_s[:-1]
        sel = order[keep]
        # per-query budget truncation by approx (descending)
        sel = sel[np.lexsort((-approx[sel], qidx[sel]))]
        sel = sel[_group_ranks(qidx[sel], nq) < rerank_budget]
    else:
        sel = np.unique(key, return_index=True)[1]        # first per (q, id)

    qs, ids_sel = qidx[sel], cand_ids[sel]
    uniq = np.bincount(qs, minlength=nq).astype(np.int64)
    exact = np.einsum("ij,ij->i", data[ids_sel], Q[qs])
    order = np.lexsort((-exact, qs))
    qs, ids_sel = qs[order], ids_sel[order]
    rank = _group_ranks(qs, nq)
    top = rank < final_k
    out = np.full((nq, final_k), -1, np.int32)
    out[qs[top], rank[top]] = ids_sel[top]
    return out, SearchStats(row_lens, uniq)


# --------------------------------------------------------------------------
# Fixed-budget jit path (TPU target; used by distributed serving + kernels)
# --------------------------------------------------------------------------

class PackedIVF(NamedTuple):
    """Dense, padded IVF layout for the jit path.

    part_ids:    (c, pmax) int32 point ids, -1 padded
    part_codes:  (c, pmax, m) uint8 PQ codes (zeros where padded)
    part_codes2: (c, pmax, ceil(m/2)) int16/int32 pre-offset PAIR-merged
                 codes (ScaNN-style LUT merging, DESIGN.md §3.6): entry j
                 is codes[2j]·16 + codes[2j+1] + j·256 (+ a single-subspace
                 tail when m is odd), directly indexable into the merged
                 per-query LUT — halves the gather count of CPU scoring
    sizes:       (c,) int32
    """
    centroids: jax.Array
    part_ids: jax.Array
    part_codes: Optional[jax.Array]
    part_codes2: Optional[jax.Array]
    sizes: jax.Array
    pq: Optional[PQCodebook]
    rerank: jax.Array           # (n, d) f32


def _paired_codes(codes: np.ndarray, n_centers: int = 16) -> np.ndarray:
    """(..., m) uint8 → (..., ceil(m/2)) pre-offset pair-merged codes."""
    m = codes.shape[-1]
    npairs, rem = divmod(m, 2)
    kk = n_centers * n_centers
    c32 = codes.astype(np.int32)
    out = c32[..., 0:2 * npairs:2] * n_centers + c32[..., 1:2 * npairs:2]
    out = out + np.arange(npairs, dtype=np.int32) * kk
    if rem:
        out = np.concatenate([out, c32[..., -1:] + npairs * kk], axis=-1)
    dt = np.int16 if npairs * kk + n_centers < 2 ** 15 else np.int32
    return out.astype(dt)


def _merged_luts(luts):
    """(nq, m, 16) per-subspace LUTs → (nq, npairs·256 [+16]) merged pair
    LUTs matching `_paired_codes` offsets. The merge is a tiny outer sum
    (nq·(m/2)·256 adds) that halves the per-candidate gather count."""
    nq, m, k = luts.shape
    npairs, rem = divmod(m, 2)
    l2 = luts[:, 0:2 * npairs:2, :, None] + luts[:, 1:2 * npairs:2, None, :]
    l2 = l2.reshape(nq, npairs * k * k)
    if rem:
        l2 = jnp.concatenate([l2, luts[:, -1, :]], axis=-1)
    return l2


def pack_ivf(index: IVFIndex, pmax: Optional[int] = None,
             pair_codes: Optional[bool] = None) -> PackedIVF:
    """Pack an IVFIndex into the dense jit layout.

    pair_codes: build the CPU pair-merged code table (part_codes2). Default
    (None) auto-detects — it is only read by the non-TPU scoring path, so
    TPU backends skip the host pass and the extra device allocation.
    Callers that only consume the raw arrays (e.g. the sharded builders)
    pass False explicitly.
    """
    if pair_codes is None:
        pair_codes = jax.default_backend() != "tpu"
    c = index.n_partitions
    sizes = index.partition_sizes()
    pmax = int(pmax or sizes.max())
    m = index.codes.shape[1] if index.codes is not None else 0
    ids = np.full((c, pmax), -1, np.int32)
    codes = np.zeros((c, pmax, m), np.uint8) if m else None
    # vectorized CSR → padded scatter (no per-partition Python loop)
    part = np.repeat(np.arange(c), sizes)                # (n_assign,)
    pos = np.arange(index.n_assignments) - np.repeat(index.starts[:-1], sizes)
    keep = pos < pmax
    ids[part[keep], pos[keep]] = index.point_ids[keep]
    if m:
        codes[part[keep], pos[keep]] = index.codes[keep]
    data = index.rerank_f32
    if data is None:
        from repro.quant.int8 import int8_dequantize
        data = np.asarray(int8_dequantize(index.rerank_int8))
    return PackedIVF(
        jnp.asarray(index.centroids), jnp.asarray(ids),
        jnp.asarray(codes) if codes is not None else None,
        (jnp.asarray(_paired_codes(codes))
         if codes is not None and pair_codes else None),
        jnp.asarray(np.minimum(sizes, pmax).astype(np.int32)),
        index.pq, jnp.asarray(data))


def window_pq_scores(luts, codes):
    """(nq, m, 16) LUTs × (nq, cand, m) candidate-window codes → (nq, cand).

    Routes through the one-hot MXU Pallas kernel on TPU. Elsewhere: flat
    per-query LUT gather — indexing the (nq, m·16) LUT with precomputed
    flat offsets keeps the gather operand tiny, where the naive
    `take_along_axis(luts[:, None], ...)` form (kernels/ref.py) broadcasts
    the LUT to (nq, cand, m, 16) — gigabytes at serving shapes.
    """
    if jax.default_backend() == "tpu":
        from repro.kernels.ops import pq_score_window
        return pq_score_window(luts, codes)
    nq, cand, m = codes.shape
    lutflat = luts.reshape(nq, m * luts.shape[-1])
    idx = codes.astype(jnp.int32) + jnp.arange(m, dtype=jnp.int32) * luts.shape[-1]
    g = jnp.take_along_axis(lutflat, idx.reshape(nq, cand * m), axis=-1)
    return g.reshape(nq, cand, m).sum(axis=-1)


def dedup_topk_window(ids, scores, k: int, multiplicity: int = 2):
    """Candidate-local dedup-by-max + top-k, batched over leading axes.

    Two stages, both window-local (nothing ever scales with the database):

    1. cheap `top_k` of the raw window down to multiplicity·k entries — a
       point occupies at most `multiplicity` window slots (primary + spills),
       so the raw top multiplicity·k provably contains every copy that could
       reach the deduped top-k, and in particular each survivor's max;
    2. lexicographic sort of that small set by (id asc, score desc) so the
       first slot of every run of equal ids carries that id's best score;
       the rest (and -1 padding) mask to -inf before the final top-k.

    Stage 1 exists because XLA:CPU's variadic sort is ~10x slower than
    top_k at window width; the split leaves the expensive sort on O(k)
    elements. Pass multiplicity ≥ 1 + n_spills for multi-spill indexes
    (default 2 covers "naive"/"soar" single-spill).

    Returns (ids (..., k) int32, scores (..., k)); k is clamped to the
    window length.
    """
    raw = min(multiplicity * k, ids.shape[-1])
    if raw < ids.shape[-1]:
        scores, pos = jax.lax.top_k(scores, raw)
        ids = jnp.take_along_axis(ids, pos, axis=-1)
    ids_s, neg_s = jax.lax.sort((ids, -scores), num_keys=2)
    scores_s = -neg_s
    first = jnp.concatenate(
        [jnp.ones_like(ids_s[..., :1], dtype=bool),
         ids_s[..., 1:] != ids_s[..., :-1]], axis=-1)
    scores_s = jnp.where(first & (ids_s >= 0), scores_s, -jnp.inf)
    k = min(k, ids.shape[-1])
    v, pos = jax.lax.top_k(scores_s, k)
    return jnp.take_along_axis(ids_s, pos, axis=-1).astype(jnp.int32), v


def _pad_topk(ids, vals, k: int):
    """Pad (..., k') top-k outputs to width k with -1 ids / -inf scores —
    degenerate indexes (t·pmax < k, e.g. a fully-tombstoned mutable index)
    keep the caller-visible (nq, final_k) contract."""
    short = k - ids.shape[-1]
    if short <= 0:
        return ids, vals
    pads = [(0, 0)] * (ids.ndim - 1) + [(0, short)]
    return (jnp.pad(ids, pads, constant_values=-1),
            jnp.pad(vals, pads, constant_values=-jnp.inf))


def _search_block(packed: PackedIVF, Q, top_t: int, final_k: int,
                  rerank_budget: int, multiplicity: int = 2):
    """Candidate-local search body shared by search_jit / search_jit_batched.

    All per-query work is O(top_t·pmax): centroid scoring is one batched
    GEMM, candidate gather/scoring/dedup operate on the (nq, t·pmax) window.
    """
    scores_c = Q @ packed.centroids.T                  # (nq, c) one GEMM
    psc, parts = jax.lax.top_k(scores_c, top_t)        # (nq, t)
    ids = packed.part_ids[parts]                       # (nq, t, pmax)
    nq, t, pmax = ids.shape
    ids = ids.reshape(nq, t * pmax)
    valid = ids >= 0
    if packed.part_codes is None:
        # no PQ stage → exact-score the whole window (search_numpy's
        # rerank_budget=0 semantics); rerank_budget is ignored
        exact = jnp.einsum("qwd,qd->qw",
                           packed.rerank[jnp.maximum(ids, 0)], Q)
        exact = jnp.where(valid, exact, -jnp.inf)
        di, dv = dedup_topk_window(ids, exact, final_k, multiplicity)
        return _pad_topk(di, dv, final_k)
    luts = jax.vmap(lambda q: pq_lut(packed.pq, q))(Q)         # (nq, m, 16)
    if jax.default_backend() != "tpu" and packed.part_codes2 is not None:
        # CPU: pair-merged LUT gather (half the lookups of per-subspace)
        idx = packed.part_codes2[parts].reshape(nq, -1).astype(jnp.int32)
        g = jnp.take_along_axis(_merged_luts(luts), idx, axis=-1)
        approx = g.reshape(nq, t * pmax, -1).sum(axis=-1)
    else:
        # TPU one-hot MXU kernel, or raw-code fallback (pair_codes=False)
        codes = packed.part_codes[parts].reshape(nq, t * pmax, -1)
        approx = window_pq_scores(luts, codes)
    approx = approx + jnp.repeat(psc, pmax, axis=-1)           # + <q, centroid>
    approx = jnp.where(valid, approx, -jnp.inf)
    bi, bv = dedup_topk_window(ids, approx, rerank_budget, multiplicity)
    exact = jnp.einsum("qbd,qd->qb", packed.rerank[jnp.maximum(bi, 0)], Q)
    exact = jnp.where(jnp.isfinite(bv), exact, -jnp.inf)
    fv, fpos = jax.lax.top_k(exact, min(final_k, exact.shape[-1]))
    return _pad_topk(jnp.take_along_axis(bi, fpos, axis=-1), fv, final_k)


@functools.partial(jax.jit, static_argnames=("top_t", "final_k",
                                              "rerank_budget", "multiplicity"))
def search_jit(packed: PackedIVF, Q, top_t: int, final_k: int,
               rerank_budget: int = 256, multiplicity: int = 2):
    """Fully-jit batched search. Returns (ids, scores) of shape (nq, final_k).

    Pipeline: batched centroid MIPS top-t → gather per-query candidate
    windows → PQ LUT scoring (+ centroid offset; Pallas one-hot MXU kernel
    on TPU) → sort-based dedup-by-max over the window → top rerank_budget →
    exact rerank → top final_k. No intermediate scales with n.
    """
    return _search_block(packed, Q, top_t, final_k, rerank_budget,
                         multiplicity)


@functools.partial(jax.jit,
                   static_argnames=("top_t", "final_k", "rerank_budget", "bq",
                                    "multiplicity"))
def search_jit_batched(packed: PackedIVF, Q, top_t: int, final_k: int,
                       rerank_budget: int = 256, bq: int = 128,
                       multiplicity: int = 2):
    """`search_jit` streamed over bq-query tiles via lax.map.

    Live buffers are O(bq·top_t·pmax) regardless of nq — the driver for
    large offline batches and the serving engine's bulk path, where a flat
    vmap over nq would blow VMEM/HBM.
    """
    nq, d = Q.shape
    pad = (-nq) % bq
    Qp = jnp.pad(Q, ((0, pad), (0, 0))) if pad else Q
    tiles = Qp.reshape(-1, bq, d)
    ids, vals = jax.lax.map(
        lambda qb: _search_block(packed, qb, top_t, final_k, rerank_budget,
                                 multiplicity), tiles)
    k = ids.shape[-1]
    return ids.reshape(-1, k)[:nq], vals.reshape(-1, k)[:nq]
