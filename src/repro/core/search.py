"""ANN search over a (possibly spilled) IVF index.

Two execution paths:

- `search_numpy`: host-orchestrated ragged search (like ScaNN's CPU engine):
  jit'd centroid scoring, numpy CSR gathers, vectorized PQ LUT scoring,
  dedup (a point may appear in 2+ searched partitions under spilling),
  exact rerank. Used by the recall/QPS benchmarks.

- `search_jit`: fixed-budget, fully-jit pipeline (padded partitions) — the
  TPU-target path the Pallas kernels and the distributed serving engine use.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import IVFIndex
from repro.quant.pq import pq_lut, PQCodebook


class SearchStats(NamedTuple):
    points_read: np.ndarray     # (nq,) assignments scanned (incl. duplicates)
    unique_candidates: np.ndarray


def search_numpy(index: IVFIndex, Q: np.ndarray, top_t: int,
                 final_k: int = 10, rerank_budget: int = 0):
    """Returns (ids (nq, final_k), SearchStats). rerank_budget=0 → exact
    scoring of all candidates (no PQ stage)."""
    Q = np.asarray(Q, np.float32)
    C = index.centroids
    scores_c = Q @ C.T                                   # (nq, c)
    top_parts = np.argpartition(-scores_c, top_t - 1, axis=1)[:, :top_t]
    # order the selected partitions by score (needed for correct LUT offsets)
    row = np.arange(Q.shape[0])[:, None]
    ordsel = np.argsort(-scores_c[row, top_parts], axis=1)
    top_parts = top_parts[row, ordsel]

    starts, pids = index.starts, index.point_ids
    use_pq = index.codes is not None and rerank_budget > 0
    data = index.rerank_f32
    if data is None:
        from repro.quant.int8 import int8_dequantize
        data = np.asarray(int8_dequantize(index.rerank_int8))

    out = np.zeros((Q.shape[0], final_k), np.int32)
    points_read = np.zeros(Q.shape[0], np.int64)
    uniq = np.zeros(Q.shape[0], np.int64)
    luts = None
    if use_pq:
        luts = np.asarray(jax.vmap(lambda q: pq_lut(index.pq, q))(jnp.asarray(Q)))

    for qi in range(Q.shape[0]):
        parts = top_parts[qi]
        segs = [np.arange(starts[p], starts[p + 1]) for p in parts]
        seg_part = np.concatenate(
            [np.full(len(s), p, np.int32) for s, p in zip(segs, parts)])
        cand_rows = np.concatenate(segs).astype(np.int64)
        cand_ids = pids[cand_rows]
        points_read[qi] = len(cand_ids)

        if use_pq:
            codes = index.codes[cand_rows]               # (cand, m)
            lut = luts[qi]                                # (m, 16)
            approx = lut[np.arange(lut.shape[0])[None, :], codes].sum(axis=1)
            approx = approx + scores_c[qi, seg_part]      # + <q, centroid>
            # dedup: keep best approx score per point id
            order = np.argsort(-approx, kind="stable")
            ids_sorted = cand_ids[order]
            first = np.unique(ids_sorted, return_index=True)[1]
            dedup_ids = ids_sorted[np.sort(first)][:rerank_budget]
        else:
            dedup_ids = np.unique(cand_ids)
        uniq[qi] = len(dedup_ids)
        exact = data[dedup_ids] @ Q[qi]
        k = min(final_k, len(dedup_ids))
        top = np.argpartition(-exact, k - 1)[:k] if len(dedup_ids) > k else np.arange(len(dedup_ids))
        top = top[np.argsort(-exact[top])]
        out[qi, :k] = dedup_ids[top]
        if k < final_k:
            out[qi, k:] = -1
    return out, SearchStats(points_read, uniq)


# --------------------------------------------------------------------------
# Fixed-budget jit path (TPU target; used by distributed serving + kernels)
# --------------------------------------------------------------------------

class PackedIVF(NamedTuple):
    """Dense, padded IVF layout for the jit path.

    part_ids:   (c, pmax) int32 point ids, -1 padded
    part_codes: (c, pmax, m) uint8 PQ codes (zeros where padded)
    sizes:      (c,) int32
    """
    centroids: jax.Array
    part_ids: jax.Array
    part_codes: Optional[jax.Array]
    sizes: jax.Array
    pq: Optional[PQCodebook]
    rerank: jax.Array           # (n, d) f32


def pack_ivf(index: IVFIndex, pmax: Optional[int] = None) -> PackedIVF:
    c = index.n_partitions
    sizes = index.partition_sizes()
    pmax = int(pmax or sizes.max())
    m = index.codes.shape[1] if index.codes is not None else 0
    ids = np.full((c, pmax), -1, np.int32)
    codes = np.zeros((c, pmax, m), np.uint8) if m else None
    for p in range(c):
        s, e = index.starts[p], index.starts[p + 1]
        ln = min(e - s, pmax)
        ids[p, :ln] = index.point_ids[s:s + ln]
        if m:
            codes[p, :ln] = index.codes[s:s + ln]
    data = index.rerank_f32
    if data is None:
        from repro.quant.int8 import int8_dequantize
        data = np.asarray(int8_dequantize(index.rerank_int8))
    return PackedIVF(
        jnp.asarray(index.centroids), jnp.asarray(ids),
        jnp.asarray(codes) if codes is not None else None,
        jnp.asarray(np.minimum(sizes, pmax).astype(np.int32)),
        index.pq, jnp.asarray(data))


@functools.partial(jax.jit, static_argnames=("top_t", "final_k", "rerank_budget"))
def search_jit(packed: PackedIVF, Q, top_t: int, final_k: int,
               rerank_budget: int = 256):
    """Fully-jit batched search. Returns (ids, scores) of shape (nq, final_k).

    Pipeline per query: centroid MIPS top-t → gather padded partitions →
    PQ LUT scoring (+ centroid offset) → dedup-by-max via scatter-max →
    top rerank_budget → exact rerank → top final_k.
    """
    C, ids_all, codes_all = packed.centroids, packed.part_ids, packed.part_codes
    n = packed.rerank.shape[0]

    def one(q):
        sc = C @ q                                         # (c,)
        psc, parts = jax.lax.top_k(sc, top_t)
        ids = ids_all[parts].reshape(-1)                   # (t*pmax,)
        valid = ids >= 0
        if codes_all is not None:
            lut = pq_lut(packed.pq, q)                     # (m, 16)
            codes = codes_all[parts].reshape(ids.shape[0], -1)
            approx = jnp.sum(
                jnp.take_along_axis(lut[None], codes[:, :, None].astype(jnp.int32),
                                    axis=2)[:, :, 0], axis=-1)
            approx = approx + jnp.repeat(psc, ids_all.shape[1])
        else:
            approx = jnp.repeat(psc, ids_all.shape[1])
        approx = jnp.where(valid, approx, -jnp.inf)
        # dedup: scatter-max into a dense per-point buffer
        dense = jnp.full((n,), -jnp.inf, approx.dtype)
        dense = dense.at[jnp.where(valid, ids, n - 1)].max(
            jnp.where(valid, approx, -jnp.inf))
        bv, bi = jax.lax.top_k(dense, rerank_budget)
        exact = packed.rerank[bi] @ q
        exact = jnp.where(jnp.isfinite(bv), exact, -jnp.inf)
        fv, fpos = jax.lax.top_k(exact, final_k)
        return bi[fpos].astype(jnp.int32), fv

    return jax.vmap(one)(Q)
