"""ANN search over a (possibly spilled) IVF index.

Two execution paths, both candidate-local (DESIGN.md §3.6): every per-query
intermediate is bounded by the probed candidate window (top_t·pmax entries),
never by the database size n — the property that keeps SOAR's spilled IVF
sublinear at serving time.

- `search_numpy`: host-orchestrated ragged search (like ScaNN's CPU engine):
  jit'd centroid scoring, one batch-level CSR gather, vectorized PQ LUT
  scoring, per-query segment dedup (a point may appear in 2+ searched
  partitions under spilling), exact rerank. Used by the recall/QPS benchmarks.

- `search_jit`: fixed-budget, fully-jit pipeline (padded partitions) — the
  TPU-target path the Pallas kernels and the distributed serving engine use.
  Batched centroid GEMM + top-t, gathered candidate windows, PQ LUT scoring
  through the one-hot MXU Pallas kernel on TPU (jnp gather fallback
  elsewhere), sort-based dedup-by-max over the window, exact rerank.
  `search_jit_batched` streams large query batches through `bq`-sized tiles
  so live buffers stay bounded regardless of nq.

Both engines serve **filtered / subset queries** (DESIGN.md §3.9): an
index-side (n,) bitmap is gathered per candidate window — never expanded
per query — so the candidate-local invariant survives filtering, and a
selectivity-adaptive probe escalation (host-driven re-probe loop in the
numpy engine, one fixed doubled-top_t second pass in the jit engine)
rescues queries whose surviving window is thinner than the rerank budget.

The partition-probe stage of both engines is a pluggable `Router`
(core/router.py, DESIGN.md §3.10): the default `FlatRouter` reproduces
the historical inline `Q @ centroids.T` + top-t op-for-op (bitwise probe
sets, so the jaxpr/HLO pins and committed baselines are unchanged), and
`TreeRouter` replaces the O(c) GEMM with a two-level O(√c·t_route) probe.
Clamping and filtered escalation are router policy — the escalation
paths below ask the router for the next (router, top_t) step instead of
hardcoding the doubling.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import IVFIndex
from repro.core.router import FlatRouter, check_query_dim
from repro.quant.pq import pq_lut, PQCodebook


class SearchStats(NamedTuple):
    points_read: np.ndarray     # (nq,) assignments scanned (incl. duplicates)
    unique_candidates: np.ndarray


def _ragged_gather(starts: np.ndarray, top_parts: np.ndarray,
                   part_scores: np.ndarray):
    """Batch-level CSR gather: one flat index vector for every (query,
    partition) segment in the batch.

    Returns (cand_rows, qidx, seg_score, row_lens): flat CSR row of each
    candidate, its query, its source partition's ROUTER score (the coarse
    <q, centroid> term the PQ stage adds back), and per-query totals.
    Broadcasting the router's (nq, t) scores here is what lets the probe
    stage avoid materializing the full (nq, c) score matrix for routers
    that never compute it (TreeRouter)."""
    nq, t = top_parts.shape
    seg_starts = starts[top_parts].ravel()                       # (nq*t,)
    seg_lens = (starts[top_parts + 1] - starts[top_parts]).ravel()
    offs = np.concatenate([[0], np.cumsum(seg_lens)])
    total = int(offs[-1])
    ar = np.arange(total, dtype=np.int64)
    cand_rows = ar - np.repeat(offs[:-1], seg_lens) + np.repeat(seg_starts,
                                                                seg_lens)
    row_lens = seg_lens.reshape(nq, t).sum(axis=1)
    qidx = np.repeat(np.arange(nq, dtype=np.int64), row_lens)
    seg_score = np.repeat(np.asarray(part_scores, np.float32).ravel(),
                          seg_lens)
    return cand_rows, qidx, seg_score, row_lens


def _group_ranks(group: np.ndarray, n_groups: int) -> np.ndarray:
    """Rank of each element within its (sorted, contiguous) group."""
    starts = np.searchsorted(group, np.arange(n_groups))
    return np.arange(len(group)) - starts[group]


def search_numpy(index: IVFIndex, Q: np.ndarray, top_t: int,
                 final_k: int = 10, rerank_budget: int = 0,
                 filter_mask: Optional[np.ndarray] = None,
                 escalate: bool = True, router=None):
    """Returns (ids (nq, final_k), SearchStats). rerank_budget=0 → exact
    scoring of all candidates (no PQ stage).

    Fully vectorized over the batch: one ragged CSR gather, one LUT gather,
    and `np.lexsort`-based per-query segment dedup — no per-query Python loop.

    filter_mask: optional (n_points,) bool/uint8 subset bitmap; candidates
    with a 0 bit are dropped at the ragged-gather stage (Rii-style
    candidate-side subset masking). Short masks zero-pad (ids beyond the
    mask are excluded), matching MutableIVF.filter_bitmap. With `escalate`,
    queries whose surviving unique-candidate set is thinner than the stage
    budget (rerank_budget with a PQ stage, else final_k — the same signal
    as the jit engine, additionally capped at the filter's population so a
    subset smaller than the budget stops escalating once fully found)
    re-probe through the router's escalation ladder (doubled top_t; a
    TreeRouter also doubles t_route) — host-driven, repeated until
    satisfied or the router is exhausted, so very selective filters
    degrade toward filtered brute force instead of returning starved
    windows.

    router: probe-stage Router (core/router.py); default is the index's
    build-time router, else the flat probe (historical behavior, bitwise).
    """
    Q = np.asarray(Q, np.float32)
    if router is None:
        router = index.router or FlatRouter(index.centroids)
    check_query_dim(Q, index.centroids.shape[1])
    if Q.shape[0] == 0:                      # empty batch → empty results
        z = np.zeros(0, np.int64)
        return np.full((0, final_k), -1, np.int32), SearchStats(z, z)
    top_t = router.clamp(top_t)              # argpartition kth ∈ [0, c)
    fm = None
    if filter_mask is not None:
        mm = np.asarray(filter_mask).astype(bool).ravel()[:index.n_points]
        fm = np.zeros(index.n_points, bool)
        fm[:mm.shape[0]] = mm
    data = index.rerank_f32
    if data is None:
        from repro.quant.int8 import int8_dequantize
        data = np.asarray(int8_dequantize(index.rerank_int8))
    out, row_lens, uniq = _search_numpy_pass(index, Q, data, router, top_t,
                                             final_k, rerank_budget, fm)
    if fm is not None and escalate:
        use_pq = index.codes is not None and rerank_budget > 0
        thresh = min(rerank_budget if use_pq else final_k, int(fm.sum()))
        r, t = router, top_t
        thin = np.flatnonzero(uniq < thresh)
        while thin.size and r.can_escalate(t):
            r, t = r.escalated(t)
            o2, r2, u2 = _search_numpy_pass(index, Q[thin], data, r, t,
                                            final_k, rerank_budget, fm)
            out[thin], row_lens[thin], uniq[thin] = o2, r2, u2
            thin = thin[u2 < thresh]
    return out, SearchStats(row_lens, uniq)


def _search_numpy_pass(index: IVFIndex, Q: np.ndarray, data: np.ndarray,
                       router, top_t: int, final_k: int, rerank_budget: int,
                       fm: Optional[np.ndarray]):
    """One fixed-top_t pass of the host engine; returns (out, points_read,
    unique_candidates) so the escalation driver can splice per-query rows."""
    nq = Q.shape[0]
    # probe stage: router picks the partitions (score-descending) and
    # reports their coarse scores — the flat router reproduces the old
    # inline argpartition head bitwise
    psc, top_parts = router.route_numpy(Q, top_t)

    use_pq = index.codes is not None and rerank_budget > 0

    cand_rows, qidx, seg_score, row_lens = _ragged_gather(index.starts,
                                                          top_parts, psc)
    cand_ids = index.point_ids[cand_rows].astype(np.int64)
    if fm is not None:
        # subset masking at the gather stage: filtered candidates never
        # reach scoring, dedup, or the rerank budget
        keep = fm[cand_ids]
        cand_rows, qidx = cand_rows[keep], qidx[keep]
        seg_score, cand_ids = seg_score[keep], cand_ids[keep]
    # composite (query, id) key: one dedup pass for the whole batch
    key = qidx * np.int64(index.n_points) + cand_ids

    if use_pq:
        luts = np.asarray(
            jax.vmap(lambda q: pq_lut(index.pq, q))(jnp.asarray(Q)))
        codes = index.codes[cand_rows]                    # (total, m)
        m = codes.shape[1]
        approx = luts[qidx[:, None], np.arange(m)[None, :],
                      codes].sum(axis=1)
        approx = approx + seg_score                       # + <q, centroid>
        # dedup: keep best approx score per (query, id)
        order = np.lexsort((-approx, key))
        key_s = key[order]
        keep = np.ones(len(order), bool)
        keep[1:] = key_s[1:] != key_s[:-1]
        sel = order[keep]
        # per-query budget truncation by approx (descending)
        sel = sel[np.lexsort((-approx[sel], qidx[sel]))]
        sel = sel[_group_ranks(qidx[sel], nq) < rerank_budget]
    else:
        sel = np.unique(key, return_index=True)[1]        # first per (q, id)

    qs, ids_sel = qidx[sel], cand_ids[sel]
    uniq = np.bincount(qs, minlength=nq).astype(np.int64)
    exact = np.einsum("ij,ij->i", data[ids_sel], Q[qs])
    order = np.lexsort((-exact, qs))
    qs, ids_sel = qs[order], ids_sel[order]
    rank = _group_ranks(qs, nq)
    top = rank < final_k
    out = np.full((nq, final_k), -1, np.int32)
    out[qs[top], rank[top]] = ids_sel[top]
    return out, row_lens, uniq


# --------------------------------------------------------------------------
# Fixed-budget jit path (TPU target; used by distributed serving + kernels)
# --------------------------------------------------------------------------

class PackedIVF(NamedTuple):
    """Dense, padded IVF layout for the jit path.

    part_ids:    (c, pmax) int32 point ids, -1 padded
    part_codes:  (c, pmax, m) uint8 PQ codes (zeros where padded)
    part_codes2: (c, pmax, ceil(m/2)) int16/int32 pre-offset PAIR-merged
                 codes (ScaNN-style LUT merging, DESIGN.md §3.6): entry j
                 is codes[2j]·16 + codes[2j+1] + j·256 (+ a single-subspace
                 tail when m is odd), directly indexable into the merged
                 per-query LUT — halves the gather count of CPU scoring
    sizes:       (c,) int32
    router:      optional probe-stage Router (core/router.py) attached at
                 pack time; None → flat probe over `centroids` (the
                 historical trace, bitwise)
    """
    centroids: jax.Array
    part_ids: jax.Array
    part_codes: Optional[jax.Array]
    part_codes2: Optional[jax.Array]
    sizes: jax.Array
    pq: Optional[PQCodebook]
    rerank: jax.Array           # (n, d) f32
    router: Optional[object] = None


def _paired_codes(codes: np.ndarray, n_centers: int = 16) -> np.ndarray:
    """(..., m) uint8 → (..., ceil(m/2)) pre-offset pair-merged codes."""
    m = codes.shape[-1]
    npairs, rem = divmod(m, 2)
    kk = n_centers * n_centers
    c32 = codes.astype(np.int32)
    out = c32[..., 0:2 * npairs:2] * n_centers + c32[..., 1:2 * npairs:2]
    out = out + np.arange(npairs, dtype=np.int32) * kk
    if rem:
        out = np.concatenate([out, c32[..., -1:] + npairs * kk], axis=-1)
    dt = np.int16 if npairs * kk + n_centers < 2 ** 15 else np.int32
    return out.astype(dt)


def _merged_luts(luts):
    """(nq, m, 16) per-subspace LUTs → (nq, npairs·256 [+16]) merged pair
    LUTs matching `_paired_codes` offsets. The merge is a tiny outer sum
    (nq·(m/2)·256 adds) that halves the per-candidate gather count."""
    nq, m, k = luts.shape
    npairs, rem = divmod(m, 2)
    l2 = luts[:, 0:2 * npairs:2, :, None] + luts[:, 1:2 * npairs:2, None, :]
    l2 = l2.reshape(nq, npairs * k * k)
    if rem:
        l2 = jnp.concatenate([l2, luts[:, -1, :]], axis=-1)
    return l2


def pack_ivf(index: IVFIndex, pmax: Optional[int] = None,
             pair_codes: Optional[bool] = None) -> PackedIVF:
    """Pack an IVFIndex into the dense jit layout.

    pair_codes: build the CPU pair-merged code table (part_codes2). Default
    (None) auto-detects — it is only read by the non-TPU scoring path, so
    TPU backends skip the host pass and the extra device allocation.
    Callers that only consume the raw arrays (e.g. the sharded builders)
    pass False explicitly.
    """
    if pair_codes is None:
        pair_codes = jax.default_backend() != "tpu"
    c = index.n_partitions
    sizes = index.partition_sizes()
    # honor an EXPLICIT pmax=0 (it is a cap, not "unset"); `pmax or max()`
    # conflated the two and an empty/fully-tombstoned index then produced a
    # zero-width pack whose downstream top_k crashed. Arrays are laid out at
    # width >= 1 so a degenerate pack is all -1 sentinels and search returns
    # all -1 ids through the _pad_topk contract.
    if pmax is None:
        pmax = int(sizes.max()) if sizes.size else 0
    pmax = int(pmax)
    width = max(pmax, 1)
    m = index.codes.shape[1] if index.codes is not None else 0
    ids = np.full((c, width), -1, np.int32)
    codes = np.zeros((c, width, m), np.uint8) if m else None
    # vectorized CSR → padded scatter (no per-partition Python loop)
    part = np.repeat(np.arange(c), sizes)                # (n_assign,)
    pos = np.arange(index.n_assignments) - np.repeat(index.starts[:-1], sizes)
    keep = pos < pmax
    ids[part[keep], pos[keep]] = index.point_ids[keep]
    if m:
        codes[part[keep], pos[keep]] = index.codes[keep]
    data = index.rerank_f32
    if data is None:
        from repro.quant.int8 import int8_dequantize
        data = np.asarray(int8_dequantize(index.rerank_int8))
    rt = index.router
    return PackedIVF(
        jnp.asarray(index.centroids), jnp.asarray(ids),
        jnp.asarray(codes) if codes is not None else None,
        (jnp.asarray(_paired_codes(codes))
         if codes is not None and pair_codes else None),
        jnp.asarray(np.minimum(sizes, pmax).astype(np.int32)),
        index.pq, jnp.asarray(data),
        rt.device() if rt is not None else None)


def window_pq_scores(luts, codes):
    """(nq, m, 16) LUTs × (nq, cand, m) candidate-window codes → (nq, cand).

    Routes through the one-hot MXU Pallas kernel on TPU. Elsewhere: flat
    per-query LUT gather — indexing the (nq, m·16) LUT with precomputed
    flat offsets keeps the gather operand tiny, where the naive
    `take_along_axis(luts[:, None], ...)` form (kernels/ref.py) broadcasts
    the LUT to (nq, cand, m, 16) — gigabytes at serving shapes.
    """
    if jax.default_backend() == "tpu":
        from repro.kernels.ops import pq_score_window
        return pq_score_window(luts, codes)
    nq, cand, m = codes.shape
    lutflat = luts.reshape(nq, m * luts.shape[-1])
    idx = codes.astype(jnp.int32) + jnp.arange(m, dtype=jnp.int32) * luts.shape[-1]
    g = jnp.take_along_axis(lutflat, idx.reshape(nq, cand * m), axis=-1)
    return g.reshape(nq, cand, m).sum(axis=-1)


def dedup_topk_window(ids, scores, k: int, multiplicity: int = 2):
    """Candidate-local dedup-by-max + top-k, batched over leading axes.

    Two stages, both window-local (nothing ever scales with the database):

    1. cheap `top_k` of the raw window down to multiplicity·k entries — a
       point occupies at most `multiplicity` window slots (primary + spills),
       so the raw top multiplicity·k provably contains every copy that could
       reach the deduped top-k, and in particular each survivor's max;
    2. lexicographic sort of that small set by (id asc, score desc) so the
       first slot of every run of equal ids carries that id's best score;
       the rest (and -1 padding) mask to -inf before the final top-k.

    Stage 1 exists because XLA:CPU's variadic sort is ~10x slower than
    top_k at window width; the split leaves the expensive sort on O(k)
    elements. Pass multiplicity ≥ 1 + n_spills for multi-spill indexes
    (default 2 covers "naive"/"soar" single-spill).

    Returns (ids (..., k) int32, scores (..., k)); k is clamped to the
    window length.
    """
    raw = min(multiplicity * k, ids.shape[-1])
    if raw < ids.shape[-1]:
        scores, pos = jax.lax.top_k(scores, raw)
        ids = jnp.take_along_axis(ids, pos, axis=-1)
    ids_s, neg_s = jax.lax.sort((ids, -scores), num_keys=2)
    scores_s = -neg_s
    first = jnp.concatenate(
        [jnp.ones_like(ids_s[..., :1], dtype=bool),
         ids_s[..., 1:] != ids_s[..., :-1]], axis=-1)
    scores_s = jnp.where(first & (ids_s >= 0), scores_s, -jnp.inf)
    k = min(k, ids.shape[-1])
    v, pos = jax.lax.top_k(scores_s, k)
    return jnp.take_along_axis(ids_s, pos, axis=-1).astype(jnp.int32), v


def _pad_topk(ids, vals, k: int):
    """Pad (..., k') top-k outputs to width k with -1 ids / -inf scores —
    degenerate indexes (t·pmax < k, e.g. a fully-tombstoned mutable index)
    keep the caller-visible (nq, final_k) contract."""
    short = k - ids.shape[-1]
    if short <= 0:
        return ids, vals
    pads = [(0, 0)] * (ids.ndim - 1) + [(0, short)]
    return (jnp.pad(ids, pads, constant_values=-1),
            jnp.pad(vals, pads, constant_values=-jnp.inf))


def _search_pass(packed: PackedIVF, Q, router, top_t: int, final_k: int,
                 rerank_budget: int, multiplicity: int = 2, filter=None):
    """One fixed-top_t candidate-local pass.

    All per-query work is O(top_t·pmax): the probe stage is one router
    call (flat: one batched GEMM + top-t, bitwise the historical trace;
    tree: the fused two-level kernel), candidate gather/scoring/dedup
    operate on the (nq, t·pmax) window. A router may return fewer than
    top_t columns (tree with fewer reachable children); every downstream
    width derives from the probe output, and starved slots arrive as
    partition 0 at -inf coarse score per the router contract — the PQ
    path masks them via the -inf offset, the exact path at worst rescans
    partition 0's window (duplicates dedup away).

    `filter` is an index-side (n,) uint8 bitmap gathered PER WINDOW (the
    (n,) array is an input, never a per-query intermediate — the §3.6
    candidate-local invariant survives filtering, jaxpr-pinned in
    tests/test_filtered_search.py). Filtered candidates are rewritten to
    the -1 padding sentinel before dedup, so a spilled point that passes
    still dedups to one slot and a starved window pads with -1 ids rather
    than leaking filtered ids at -inf. Returns (ids, vals, n_surviving)
    where n_surviving (None unfiltered) counts UNIQUE surviving candidates
    capped at the stage budget — the escalation signal, matching the numpy
    engine's unique-candidate count.
    """
    psc, parts = router.route(Q, top_t)                # (nq, t) probe stage
    ids = packed.part_ids[parts]                       # (nq, t, pmax)
    nq, t, pmax = ids.shape
    ids = ids.reshape(nq, t * pmax)
    valid = ids >= 0
    surviving = None
    if filter is not None:
        fbits = filter[jnp.maximum(ids, 0)]            # (nq, t·pmax) gather
        valid = valid & (fbits > 0)
        ids = jnp.where(valid, ids, -1)                # filter-aware dedup
    if packed.part_codes is None:
        # no PQ stage → exact-score the whole window (search_numpy's
        # rerank_budget=0 semantics); rerank_budget is ignored
        exact = jnp.einsum("qwd,qd->qw",
                           packed.rerank[jnp.maximum(ids, 0)], Q)
        exact = jnp.where(valid, exact, -jnp.inf)
        di, dv = dedup_topk_window(ids, exact, final_k, multiplicity)
        di, dv = _pad_topk(di, dv, final_k)
        if filter is not None:
            # unique survivors, capped at final_k (finite ⟺ a real deduped
            # candidate filled the slot) — matches the numpy engine's
            # unique-count escalation signal
            surviving = jnp.sum(jnp.isfinite(dv), axis=-1)
        return di, dv, surviving
    luts = jax.vmap(lambda q: pq_lut(packed.pq, q))(Q)         # (nq, m, 16)
    if jax.default_backend() != "tpu" and packed.part_codes2 is not None:
        # CPU: pair-merged LUT gather (half the lookups of per-subspace)
        idx = packed.part_codes2[parts].reshape(nq, -1).astype(jnp.int32)
        g = jnp.take_along_axis(_merged_luts(luts), idx, axis=-1)
        approx = g.reshape(nq, t * pmax, -1).sum(axis=-1)
    else:
        # TPU one-hot MXU kernel, or raw-code fallback (pair_codes=False)
        codes = packed.part_codes[parts].reshape(nq, t * pmax, -1)
        approx = window_pq_scores(luts, codes)
    approx = approx + jnp.repeat(psc, pmax, axis=-1)           # + <q, centroid>
    approx = jnp.where(valid, approx, -jnp.inf)
    bi, bv = dedup_topk_window(ids, approx, rerank_budget, multiplicity)
    if filter is not None:
        # unique survivors, capped at rerank_budget (a -inf slot means the
        # deduped candidate set ran short of the budget) — slot-counting
        # the raw window instead would over-count spilled duplicates and
        # skip escalation the numpy engine's unique count would take
        surviving = jnp.sum(jnp.isfinite(bv), axis=-1)
    exact = jnp.einsum("qbd,qd->qb", packed.rerank[jnp.maximum(bi, 0)], Q)
    exact = jnp.where(jnp.isfinite(bv), exact, -jnp.inf)
    fv, fpos = jax.lax.top_k(exact, min(final_k, exact.shape[-1]))
    fi, fv = _pad_topk(jnp.take_along_axis(bi, fpos, axis=-1), fv, final_k)
    return fi, fv, surviving


def _search_block(packed: PackedIVF, Q, top_t: int, final_k: int,
                  rerank_budget: int, multiplicity: int = 2, filter=None,
                  escalate: bool = False, router=None):
    """Search body shared by search_jit / search_jit_batched: one
    `_search_pass`, plus — on the filtered path only — a SECOND fixed pass
    one router-escalation step up (flat: doubled top_t; tree: doubled
    top_t AND t_route) whose rows are selected per-query where the first
    pass's surviving window was thinner than the rerank budget (the jit
    engine's shape-static analogue of the numpy engine's host-driven
    escalation loop). Unfiltered traces are byte-for-byte the single pass.
    """
    if router is None:
        router = packed.router if packed.router is not None \
            else FlatRouter(packed.centroids)
    check_query_dim(Q, packed.centroids.shape[1])
    top_t = router.clamp(top_t)            # lax.top_k width ∈ [0, c]
    ids1, vals1, surv1 = _search_pass(packed, Q, router, top_t, final_k,
                                      rerank_budget, multiplicity, filter)
    if filter is None or not escalate or not router.can_escalate(top_t):
        return ids1, vals1
    thresh = rerank_budget if packed.part_codes is not None else final_k
    r2, t2 = router.escalated(top_t)
    ids2, vals2, _ = _search_pass(packed, Q, r2, t2, final_k,
                                  rerank_budget, multiplicity, filter)
    # the escalated probe set is a superset for the flat router (top-2t ⊇
    # top-t of the same centroid scores) and reaches strictly more
    # children for the tree router, so taking pass-2 rows never loses
    # candidates
    need = (surv1 < thresh)[:, None]
    return jnp.where(need, ids2, ids1), jnp.where(need, vals2, vals1)


@functools.partial(jax.jit, static_argnames=("top_t", "final_k",
                                              "rerank_budget", "multiplicity",
                                              "escalate"))
def search_jit(packed: PackedIVF, Q, top_t: int, final_k: int,
               rerank_budget: int = 256, multiplicity: int = 2,
               filter=None, escalate: bool = True, router=None):
    """Fully-jit batched search. Returns (ids, scores) of shape (nq, final_k).

    Pipeline: router probe top-t (flat: batched centroid MIPS; tree: fused
    two-level kernel) → gather per-query candidate windows → PQ LUT
    scoring (+ coarse offset; Pallas one-hot MXU kernel on TPU) →
    sort-based dedup-by-max over the window → top rerank_budget → exact
    rerank → top final_k. No intermediate scales with n.

    filter: optional (n,) uint8 device bitmap over point ids (0 = drop);
    gathered per candidate window, never expanded per query. With
    `escalate` a second fixed router-escalated pass backstops thin
    surviving windows (selectivity escalation, DESIGN.md §3.9). Passing
    filter=None traces exactly the unfiltered PR 4 pipeline.

    router: probe-stage Router pytree (core/router.py); default is the
    router packed on the index, else the flat probe (historical trace).
    """
    return _search_block(packed, Q, top_t, final_k, rerank_budget,
                         multiplicity, filter, escalate, router)


def bq_bucket(nq: int, bq: int) -> int:
    """Power-of-two query-count bucket (≥ 8), capped at the serving tile
    size. Serving callers pad their batch to a bucket multiple BEFORE the
    jit boundary and slice the result — the traced Q shape (not just the
    static bq) keys the compile cache, so per-distinct-nq executables were
    a recompile storm for small online batches."""
    return min(bq, max(8, 1 << (max(nq, 1) - 1).bit_length()))


def pad_queries(Q: np.ndarray, bq_cap: int, multiple: int = 1):
    """Host-side bucket padding for serving entry points: (nq, d) float32
    → (padded Q, nq, bucket). Callers pass `bq=bucket` to
    search_jit_batched and slice results back to [:nq].

    `multiple` additionally pads the batch to a multiple of that many
    rows — the replica fan-out path (core/distributed.py
    make_replicated_search) shards the padded batch over R devices, so
    the row count must divide by R as well as land on a compile-cache
    bucket. Power-of-two R ≤ bucket costs no extra padding; otherwise the
    batch rounds up to lcm(bucket, R) rows. Pad rows are zero queries
    whose results are sliced off — per-query results are unaffected
    (every pipeline stage is query-local)."""
    Q = np.atleast_2d(np.asarray(Q, np.float32))
    nq = Q.shape[0]
    bq = bq_bucket(nq, bq_cap)
    step = bq * multiple // np.gcd(bq, multiple) if multiple > 1 else bq
    pad = (-nq) % step
    Qp = np.pad(Q, ((0, pad), (0, 0))) if pad else Q
    return Qp, nq, bq


@functools.partial(jax.jit,
                   static_argnames=("top_t", "final_k", "rerank_budget", "bq",
                                    "multiplicity", "escalate"))
def search_jit_batched(packed: PackedIVF, Q, top_t: int, final_k: int,
                       rerank_budget: int = 256, bq: int = 128,
                       multiplicity: int = 2, filter=None,
                       escalate: bool = True, router=None):
    """`search_jit` streamed over bq-query tiles via lax.map.

    Live buffers are O(bq·top_t·pmax) regardless of nq — the driver for
    large offline batches and the serving engine's bulk path, where a flat
    vmap over nq would blow VMEM/HBM. `filter`/`escalate`/`router` as in
    search_jit (bitmap and router tables are closed over, shared across
    tiles).
    """
    nq, d = Q.shape
    if nq == 0:          # static at trace time: empty batch, no tiles
        return (jnp.zeros((0, final_k), jnp.int32),
                jnp.zeros((0, final_k), jnp.float32))
    pad = (-nq) % bq
    Qp = jnp.pad(Q, ((0, pad), (0, 0))) if pad else Q
    tiles = Qp.reshape(-1, bq, d)
    ids, vals = jax.lax.map(
        lambda qb: _search_block(packed, qb, top_t, final_k, rerank_budget,
                                 multiplicity, filter, escalate, router),
        tiles)
    k = ids.shape[-1]
    return ids.reshape(-1, k)[:nq], vals.reshape(-1, k)[:nq]
