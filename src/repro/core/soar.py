"""SOAR: Spilling with Orthogonality-Amplified Residuals (the paper's core).

Theorem 3.1: for weight w(t)=|t|^lambda and hypersphere-uniform queries,

    L(r', r) ∝ ||r'||^2 + lambda * ||proj_r r'||^2 ,   r' = x - c'.

The spilled assignment is argmin_{c' != pi(x)} of that loss. We expand it
into matmul-friendly form (everything reassociated so the inner loop is two
GEMMs against the codebook — this is also the form the Pallas kernel uses):

    ||x - c||^2            = ||c||^2 - 2<x,c> + const_i
    <r_hat, x - c>^2       = (<r_hat,x> - <r_hat,c>)^2

so  loss_ij = ||c_j||^2 - 2 X C^T + lambda (rx_i - R_hat C^T)^2  (+ const_i).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils import chunked_map


def _unit_residuals(X, C, primary, eps=1e-12):
    r = X - C[primary]
    rn = jnp.linalg.norm(r, axis=-1, keepdims=True)
    return r, r / jnp.maximum(rn, eps)


@functools.partial(jax.jit, static_argnames=("chunk",))
def soar_assign(X, C, primary, lam: float = 1.0, chunk: int = 8192):
    """Single spilled assignment per point under the SOAR loss.

    Args:
      X: (n, d) datapoints. C: (c, d) fixed VQ codebook.
      primary: (n,) int32 primary assignments pi(x).
      lam: the SOAR lambda (paper uses 1.0 at 1M scale, 1.5 at 1B scale).
    Returns:
      (n,) int32 spilled assignments pi'(x), guaranteed != primary.
    """
    _, rhat = _unit_residuals(X, C, primary)
    Cn = jnp.sum(C * C, axis=-1)
    packed = jnp.concatenate(
        [X, rhat, primary[:, None].astype(X.dtype)], axis=-1)
    d = X.shape[-1]

    def f(blk):
        xb, rb, pb = blk[:, :d], blk[:, d:2 * d], blk[:, -1].astype(jnp.int32)
        xc = xb @ C.T                       # <x, c_j>
        rc = rb @ C.T                       # <r_hat, c_j>
        rx = jnp.sum(rb * xb, axis=-1)      # <r_hat, x>
        loss = Cn[None, :] - 2.0 * xc + lam * (rx[:, None] - rc) ** 2
        loss = jnp.where(
            jax.nn.one_hot(pb, C.shape[0], dtype=bool), jnp.inf, loss)
        return jnp.argmin(loss, axis=-1).astype(jnp.int32)

    return chunked_map(f, packed, chunk)


@functools.partial(jax.jit, static_argnames=("n_spills", "chunk"))
def soar_assign_multi(X, C, primary, lam: float = 1.0, n_spills: int = 1,
                      chunk: int = 8192):
    """Generalization to >1 spilled assignment (paper §3.5.1).

    Each subsequent assignment penalizes parallelism with ALL prior residuals:
        loss = ||r'||^2 + lam * sum_k <r_hat_k, r'>^2.
    Returns (n, 1 + n_spills) assignments, column 0 = primary.
    """
    n = X.shape[0]
    cn = C.shape[0]
    Cn = jnp.sum(C * C, axis=-1)
    assigns = [primary.astype(jnp.int32)]
    rhats = []
    for _ in range(n_spills):
        _, rh = _unit_residuals(X, C, assigns[-1])
        rhats.append(rh)
        A = jnp.stack(assigns, axis=1)              # (n, a)
        R = jnp.stack(rhats, axis=1)                # (n, a, d)
        d = X.shape[-1]
        a = R.shape[1]
        packed = jnp.concatenate(
            [X, R.reshape(n, a * d), A.astype(X.dtype)], axis=-1)

        def f(blk, a=a, d=d):
            xb = blk[:, :d]
            rb = blk[:, d:d + a * d].reshape(-1, a, d)
            pb = blk[:, d + a * d:].astype(jnp.int32)           # (chunk, a)
            xc = xb @ C.T
            rc = jnp.einsum("bad,cd->bac", rb, C)               # <rhat_k, c_j>
            rx = jnp.sum(rb * xb[:, None, :], axis=-1)          # <rhat_k, x>
            pen = jnp.sum((rx[:, :, None] - rc) ** 2, axis=1)   # sum over k
            loss = Cn[None, :] - 2.0 * xc + lam * pen
            used = jnp.any(
                jax.nn.one_hot(pb, cn, dtype=bool), axis=1)     # mask all prior
            loss = jnp.where(used, jnp.inf, loss)
            return jnp.argmin(loss, axis=-1).astype(jnp.int32)

        assigns.append(chunked_map(f, packed, chunk))
    return jnp.stack(assigns, axis=1)


@functools.partial(jax.jit, static_argnames=("chunk",))
def naive_spill_assign(X, C, primary, chunk: int = 8192):
    """Baseline: spill to the second-closest centroid (no SOAR loss)."""
    return soar_assign(X, C, primary, lam=0.0, chunk=chunk)


def soar_loss_values(X, C, primary, candidate, lam: float = 1.0):
    """Loss value of a candidate spilled assignment (for tests/analysis)."""
    r, rhat = _unit_residuals(X, C, primary)
    rp = X - C[candidate]
    return jnp.sum(rp * rp, axis=-1) + lam * jnp.sum(rhat * rp, axis=-1) ** 2
