"""Deterministic, resumable, host-shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — `batch_at(step)` —
so resume-after-preemption needs only the step counter (saved in the
checkpoint), and each data-parallel host can produce exactly its shard
without coordination. This is the property real pipelines (e.g. grain with
index-based sampling) provide; we implement it directly.

Token stream modes:
- "markov": tokens follow a noisy affine recurrence over the vocab, so a
  small LM measurably learns (loss drops within a few hundred steps) —
  used by examples/train_lm.py.
- "uniform": i.i.d. tokens (throughput benchmarking).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class PipelineSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "markov"
    frontend: str = ""          # "", "audio", "vision"
    d_model: int = 0
    n_prefix: int = 0


def for_model(cfg: ModelConfig, seq_len: int, global_batch: int,
              seed: int = 0, mode: str = "markov") -> "TokenPipeline":
    return TokenPipeline(PipelineSpec(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch,
        seed=seed, mode=mode, frontend=cfg.frontend, d_model=cfg.d_model,
        n_prefix=cfg.n_prefix_embeds))


class TokenPipeline:
    def __init__(self, spec: PipelineSpec):
        self.spec = spec

    def _tokens(self, key, batch: int):
        s = self.spec
        if s.mode == "uniform":
            return jax.random.randint(key, (batch, s.seq_len + 1), 0,
                                      s.vocab_size)
        # markov: x_{t+1} = (a*x_t + c + eps) mod V, eps in {0, 1, 2}
        k0, k1 = jax.random.split(key)
        x0 = jax.random.randint(k0, (batch,), 0, s.vocab_size)
        eps = jax.random.randint(k1, (batch, s.seq_len + 1), 0, 3)
        a, c = 31, 7

        def step(x, e):
            nxt = (a * x + c + e) % s.vocab_size
            return nxt, nxt

        _, seq = jax.lax.scan(step, x0, eps.T)
        return seq.T

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Pure: the batch (dict of np arrays) for global step `step`.

        shard/n_shards slice the global batch for per-host data loading.
        """
        s = self.spec
        assert s.global_batch % n_shards == 0
        b_local = s.global_batch // n_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(s.seed), step), shard)
        toks = self._tokens(key, b_local)
        out = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
               "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if s.frontend == "audio":
            kf = jax.random.fold_in(key, 999)
            out = {"frames": jax.random.normal(
                       kf, (b_local, s.seq_len, s.d_model), jnp.float32),
                   "labels": out["labels"]}
        elif s.frontend == "vision":
            kp = jax.random.fold_in(key, 998)
            out["patches"] = jax.random.normal(
                kp, (b_local, s.n_prefix, s.d_model), jnp.float32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
