"""Synthetic vector datasets for ANN experiments.

The container is offline (no Glove / SPACEV downloads), so we generate
datasets with the structural properties that make ANN search non-trivial and
that the paper's figures rely on:

- clustered structure (mixture of anisotropic Gaussians) so VQ partitions are
  meaningful;
- power-law cluster sizes (natural-data imbalance);
- unit-norm vectors (Glove is used in angular/MIPS mode);
- queries drawn near the data manifold (perturbed held-out samples), which is
  what makes nearest neighbors concentrated and rank structure interesting.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class VectorDataset:
    X: np.ndarray          # (n, d) float32, database
    Q: np.ndarray          # (nq, d) float32, queries
    name: str

    @property
    def n(self):
        return self.X.shape[0]

    @property
    def d(self):
        return self.X.shape[1]


def make_clustered(key, n: int, d: int, n_clusters: int = 256, nq: int = 1000,
                   intra_scale: float = 0.35, zipf_a: float = 1.2,
                   normalize: bool = True, name: str = "synthetic") -> VectorDataset:
    """Glove-like synthetic data: zipf-sized anisotropic Gaussian clusters."""
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    centers = jax.random.normal(k1, (n_clusters, d))
    centers = centers / jnp.linalg.norm(centers, axis=-1, keepdims=True)
    # power-law cluster weights
    ranks = jnp.arange(1, n_clusters + 1, dtype=jnp.float32)
    w = ranks ** (-zipf_a)
    w = w / w.sum()
    assign = jax.random.choice(k2, n_clusters, (n + nq,), p=w)
    # anisotropic intra-cluster noise: per-cluster random diagonal scales
    scales = 0.5 + jax.random.uniform(k3, (n_clusters, d))
    noise = jax.random.normal(k4, (n + nq, d)) * intra_scale * scales[assign]
    pts = centers[assign] + noise
    if normalize:
        pts = pts / jnp.linalg.norm(pts, axis=-1, keepdims=True)
    X = pts[:n]
    # queries: held-out points, mildly perturbed (near-manifold queries)
    qnoise = jax.random.normal(k5, (nq, d)) * 0.05
    Q = pts[n:] + qnoise
    if normalize:
        Q = Q / jnp.linalg.norm(Q, axis=-1, keepdims=True)
    del k6
    return VectorDataset(np.asarray(X, np.float32), np.asarray(Q, np.float32), name)


def make_uniform(key, n: int, d: int, nq: int = 1000, name: str = "uniform") -> VectorDataset:
    """Unstructured control dataset (hard, near-orthogonal regime)."""
    k1, k2 = jax.random.split(key)
    X = jax.random.normal(k1, (n, d))
    X = X / jnp.linalg.norm(X, axis=-1, keepdims=True)
    Q = jax.random.normal(k2, (nq, d))
    Q = Q / jnp.linalg.norm(Q, axis=-1, keepdims=True)
    return VectorDataset(np.asarray(X, np.float32), np.asarray(Q, np.float32), name)


def make_manifold(key, n: int, d: int, nq: int = 1000, intrinsic_dim: int = 12,
                  hidden: int = 256, name: str = "manifold") -> VectorDataset:
    """Continuous low-intrinsic-dim manifold: random 2-layer MLP embedding.

    x = normalize(W2 tanh(2 W1 z)), z ~ N(0, I_p). This is the generator that
    reproduces the paper's regime (validated in EXPERIMENTS.md §Data):
    k-means UNDERFITS a continuum (residual norm ~ neighborhood scale), which
    creates the heavy tail of badly-ranked neighbors (paper Fig 1) with
    cos-theta-driven score error (Fig 2) — finite-mixture data does NOT have
    this property (k-means fits it exactly, no tail, and spilling cannot pay
    for its 2x partition-size cost). Queries are fresh draws from the same
    process, like ann-benchmarks' held-out query sets.

    intrinsic_dim controls difficulty: ~read-fraction at fixed recall.
    """
    ks = jax.random.split(key, 3)
    W1 = jax.random.normal(ks[0], (intrinsic_dim, hidden)) / np.sqrt(intrinsic_dim)
    W2 = jax.random.normal(ks[1], (hidden, d)) / np.sqrt(hidden)
    z = jax.random.normal(ks[2], (n + nq, intrinsic_dim))
    x = jnp.tanh(2.0 * (z @ W1)) @ W2
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    return VectorDataset(np.asarray(x[:n], np.float32),
                         np.asarray(x[n:], np.float32), name)


_CACHE: dict = {}


def glove_like(n: int = 200_000, d: int = 100, nq: int = 1000, seed: int = 0,
               intrinsic_dim: int = 12) -> VectorDataset:
    """The default benchmark dataset (cached per process)."""
    key_t = ("glove_like", n, d, nq, seed, intrinsic_dim)
    if key_t not in _CACHE:
        _CACHE[key_t] = make_manifold(
            jax.random.PRNGKey(seed), n=n, d=d, nq=nq,
            intrinsic_dim=intrinsic_dim,
            name=f"manifold-{n//1000}k-d{d}-p{intrinsic_dim}")
    return _CACHE[key_t]
