"""Deterministic fault injection for the durable-storage AND serving
paths (DESIGN.md §3.11, §3.13).

Grown out of ``ckpt/faults.py`` (which remains as an import shim): PR 7
proved the crash-recovery matrix for snapshots/WAL by actually dying at
every byte offset and protocol step; ISSUE 9 extends the same discipline
to the serving loop, where the interesting failures are not crashes but
*errors the system must contain*: an engine call raising, a call
suddenly taking 50 ms, a replica or shard dropping out. One seam, three
families of injection point:

- **byte-budget streams** — ``write(f, data, stream=NAME)``: when an
  installed plan targets ``NAME`` with a byte budget, exactly that many
  bytes are written (flushed + fsynced, so the on-disk prefix is what a
  real crash would leave) and the process dies. Streams:
  ``snapshot:arrays``, ``snapshot:manifest``, ``wal:append``.

- **named crash points** — ``crash_point(NAME)``: dies at the Nth hit of
  a protocol step. Points: ``commit:between_renames``,
  ``commit:before_cleanup``, ``wal:record``.

- **named serving points** — ``serve_point(NAME)``: instead of killing
  the process, fires a *recoverable* fault the serving tier is expected
  to contain — raise ``InjectedFault`` (mode ``"error"``), raise
  ``InjectedTransientFault`` (mode ``"transient"``, classified retryable
  by the serve/api.py taxonomy), sleep ``delay_ms`` (mode ``"delay"``, a
  latency spike), or still die (modes ``"raise"``/``"exit"``) for the
  crash-through-the-frontend recovery tests. Points threaded today:
  ``engine:search``, ``engine:add``, ``engine:remove`` (AnnEngine),
  ``replica:dispatch`` (ServingFrontend fan-out).

Plan grammar (``install(spec)`` / env ``REPRO_FAULT``; ``;``-separated
specs install several plans at once):

    "snapshot:arrays+4096"      die after 4096 bytes of that stream
    "commit:between_renames"    fire at the 1st hit of that point
    "wal:record@3"              fire at the 3rd hit
    "engine:search@2x3"         fire on hits 2,3,4 then go quiet
    "engine:search@1;engine:add@1"   two plans

Point-style plans without an ``xM`` window fire on EVERY hit from the
Nth on (a permanently-down dependency); ``xM`` bounds the outage (a
transient blip of M calls). Modes come from ``mode=`` /
``REPRO_FAULT_MODE`` (``raise`` | ``exit`` | ``error`` | ``transient``
| ``delay``), and ``delay_ms=`` / ``REPRO_FAULT_DELAY_MS`` sizes the
latency spike.

Also home to the **corruption injectors** (``flip_byte``,
``truncate_tail``) the load-path tests use to assert that a damaged
snapshot or WAL surfaces ``CorruptSnapshotError`` instead of garbage.

Zero overhead when nothing is installed: the hot-path checks are a
single ``if not _PLANS`` test. Hit counting is lock-protected — serving
points are hit from client threads and the dispatcher concurrently.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional


class InjectedCrash(BaseException):
    """Raised (mode="raise") at an injected crash point. BaseException on
    purpose: recovery code under test must never be able to catch this as
    an ordinary error and "handle" the crash away."""


class InjectedFault(RuntimeError):
    """An ordinary, containable failure fired at a serving point
    (mode="error"): the serving tier is expected to catch it, fail ONLY
    the affected request(s), and keep serving. Non-retryable."""
    retryable = False


class InjectedTransientFault(InjectedFault):
    """A transient serving failure (mode="transient"): classified
    retryable by serve/api.is_retryable, so the front-end's bounded
    retry + backoff should absorb it."""
    retryable = True


@dataclass
class FaultPlan:
    point: str                      # stream / crash-point / serve-point name
    after_bytes: int = -1           # >=0: byte budget for a stream target
    hits: int = 1                   # first firing hit of a named point
    times: Optional[int] = None     # point fires on hits [hits, hits+times)
    mode: str = "raise"             # raise | exit | error | transient | delay
    delay_ms: float = 0.0           # latency spike size for mode="delay"
    _written: int = field(default=0, repr=False)
    _hit_count: int = field(default=0, repr=False)

    @classmethod
    def parse(cls, spec: str, mode: str = "raise",
              delay_ms: float = 0.0) -> "FaultPlan":
        """Parse ONE plan of the grammar (module docstring)."""
        spec = spec.strip()
        if "+" in spec:
            name, _, nb = spec.rpartition("+")
            return cls(point=name, after_bytes=int(nb), mode=mode,
                       delay_ms=delay_ms)
        if "@" in spec:
            name, _, n = spec.rpartition("@")
            times = None
            if "x" in n:
                n, _, t = n.partition("x")
                times = int(t)
            return cls(point=name, hits=int(n), times=times, mode=mode,
                       delay_ms=delay_ms)
        return cls(point=spec, mode=mode, delay_ms=delay_ms)

    def _count_and_check(self) -> bool:
        """Advance the hit counter; True if this hit is inside the firing
        window [hits, hits + times)."""
        self._hit_count += 1
        if self._hit_count < self.hits:
            return False
        return self.times is None or self._hit_count < self.hits + self.times


_PLANS: List[FaultPlan] = []
_LOCK = threading.Lock()


def install(spec: Optional[str] = None, mode: Optional[str] = None,
            delay_ms: Optional[float] = None):
    """Install fault plan(s), REPLACING any currently installed set.
    With no args, reads ``REPRO_FAULT`` / ``REPRO_FAULT_MODE`` /
    ``REPRO_FAULT_DELAY_MS`` from the environment (the subprocess tests'
    channel); no-op if no spec is given. ``;`` separates multiple plans
    in one spec."""
    global _PLANS
    if spec is None:
        spec = os.environ.get("REPRO_FAULT")
    if mode is None:
        mode = os.environ.get("REPRO_FAULT_MODE", "raise")
    if delay_ms is None:
        delay_ms = float(os.environ.get("REPRO_FAULT_DELAY_MS", "0"))
    if not spec:
        return None
    with _LOCK:
        _PLANS = [FaultPlan.parse(s, mode=mode, delay_ms=delay_ms)
                  for s in spec.split(";") if s.strip()]
        return _PLANS[0] if len(_PLANS) == 1 else list(_PLANS)


def inject(spec: str, mode: str = "raise",
           delay_ms: float = 0.0) -> FaultPlan:
    """ADD one plan to the installed set (unlike install, which replaces)
    — lets a chaos test arm several independent points."""
    plan = FaultPlan.parse(spec, mode=mode, delay_ms=delay_ms)
    with _LOCK:
        _PLANS.append(plan)
    return plan


def uninstall():
    global _PLANS
    with _LOCK:
        _PLANS = []


def active() -> Optional[FaultPlan]:
    return _PLANS[0] if _PLANS else None


def _die(plan: FaultPlan):
    if plan.mode == "exit":
        os._exit(42)                 # a real crash: no cleanup of any kind
    raise InjectedCrash(plan.point)


def crash_point(name: str):
    """Named protocol step: dies when an installed plan targets `name`
    (point-style, not byte-budget) and this is the plan's Nth hit."""
    if not _PLANS:
        return
    with _LOCK:
        firing = [p for p in _PLANS
                  if p.after_bytes < 0 and p.point == name
                  and p._count_and_check()]
    for plan in firing:
        _die(plan)


def serve_point(name: str):
    """Named serving step: fires an installed plan targeting `name` as a
    CONTAINABLE fault — raise InjectedFault / InjectedTransientFault,
    sleep a latency spike, or (modes raise/exit) still die, for the
    crash-behind-the-frontend recovery tests. Firing order with several
    armed plans: delays apply first, then the first error-raising plan
    wins."""
    if not _PLANS:
        return
    with _LOCK:
        firing = [p for p in _PLANS
                  if p.after_bytes < 0 and p.point == name
                  and p._count_and_check()]
    err = None
    for plan in firing:
        if plan.mode == "delay":
            time.sleep(plan.delay_ms * 1e-3)
        elif plan.mode == "error" and err is None:
            err = InjectedFault(name)
        elif plan.mode == "transient" and err is None:
            err = InjectedTransientFault(name)
        elif plan.mode in ("raise", "exit"):
            _die(plan)
    if err is not None:
        raise err


def write(f, data: bytes, stream: str):
    """Byte-counted write through the injection seam. When an installed
    plan targets `stream` with a byte budget, writes exactly the budget's
    remaining bytes, forces them to disk (flush + fsync — the on-disk
    state must be the crash state, not "whatever the FILE* buffer held"),
    and dies."""
    plan = next((p for p in _PLANS
                 if p.after_bytes >= 0 and p.point == stream), None)
    if plan is None:
        f.write(data)
        return
    remaining = plan.after_bytes - plan._written
    if len(data) < remaining or remaining < 0:
        f.write(data)
        plan._written += len(data)
        return
    f.write(data[:max(remaining, 0)])
    f.flush()
    os.fsync(f.fileno())
    _die(plan)


# ------------------------------------------------------------ corruption
def flip_byte(path: str, offset: int):
    """XOR one byte at `offset` (negative: from EOF) — the bit-rot
    injector for the load-path CRC tests."""
    with open(path, "r+b") as f:
        size = os.fstat(f.fileno()).st_size
        off = offset if offset >= 0 else size + offset
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))


def truncate_tail(path: str, nbytes: int):
    """Drop the last `nbytes` bytes — the torn-write injector."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(0, size - nbytes))
