"""Fused Lloyd sweep: assignment + per-centroid accumulation in one pass.

The classic two-pass Lloyd iteration (materialize an (n,) assignment
vector, then re-read X for a segment-sum) is what made the build path the
wall after PR 2 sped up search. The sweep here streams X once per
iteration: each row-tile computes its chunk of the distance matrix,
reduces it to (argmin, min) on the spot, and folds the tile's per-centroid
sums/counts/loss into the scan carry — nothing (n,)- or (n, c)-shaped
ever exists outside a tile (pinned by a jaxpr test in
tests/test_build_perf.py).

Two routes share the reassociated one-GEMM distance form
||c||^2 - 2<x,c> (+ ||x||^2 added to the loss only):

- `lloyd_sweep` (any backend): jit'd `lax.scan` over row-chunks;
  per-chunk `segment_sum` accumulate (XLA:CPU scatter is ~15x faster than
  a one-hot GEMM there — measured, see DESIGN.md §3.8);
- `lloyd_sweep_pallas` (TPU): row-tile grid with full C resident in VMEM;
  the accumulate is a one-hot MXU contraction into VMEM scratch, which on
  TPU *is* the fast path; sums/counts leave the core once.

Exact-argmin note: the reduction uses a grouped min (vectorized lane min
over G-wide groups, then an argmin over group minima, then first-match
within the winning group). Ties resolve to the lowest index — identical
to `jnp.argmin` — but the index-tracking reduction runs on 1/G of the
data, which is ~1.8x faster on XLA:CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

ARGMIN_GROUP = 8

# below this feature dim, x·Cᵀ runs as an unrolled multiply-add chain over
# the s axis instead of a dot_general: XLA:CPU dispatches k<=8 GEMMs as
# hundreds of tiny Eigen calls (the PQ-subspace regime, s=d/m=4), while the
# unrolled form fuses into one elementwise pass. The s-loop accumulates
# left-to-right, so results are deterministic and identical between the
# per-subspace and vmapped-batched callers (the train_pq bitwise pin).
SMALL_D = 8


def _xct(xb, Ct):
    """xb (..., d) @ Ct (d, c) with the small-d unrolled fast path."""
    d = Ct.shape[0]
    if d > SMALL_D:
        return xb @ Ct
    acc = xb[..., 0:1] * Ct[0]
    for s in range(1, d):
        acc = acc + xb[..., s:s + 1] * Ct[s]
    return acc


def _grouped_argmin(dm, G: int = ARGMIN_GROUP):
    """Exact first-tie argmin+min over the last axis of (..., c).

    c must be a multiple of G (pad with +inf columns). Returns
    (idx int32, minval) — bitwise identical to (jnp.argmin, jnp.min).
    """
    shape = dm.shape
    # barrier: both reduction paths below must read the SAME bits — without
    # it XLA duplicates the (fused) distance computation into each consumer
    # and FMA-contracts them differently, silently corrupting tie-breaks
    dg = jax.lax.optimization_barrier(dm.reshape(shape[:-1] + (-1, G)))
    gmin = jnp.min(dg, -1)                         # vectorized lane min
    g = jnp.argmin(gmin, -1)                       # over c/G group minima
    mv = jnp.take_along_axis(gmin, g[..., None], -1)[..., 0]
    rowg = jnp.take_along_axis(dg, g[..., None, None], -2)[..., 0, :]
    within = jnp.argmin(rowg, -1)                  # first min in the group
    return (g * G + within).astype(jnp.int32), mv


@functools.partial(jax.jit, static_argnames=("c", "chunk"))
def lloyd_sweep(X, C, c: int, chunk: int = 8192):
    """One fused Lloyd iteration over X against C.

    Returns (new_C, counts (c,) f32, mean distortion). Empty clusters keep
    their old centroid. Chunk boundaries change only the f32 accumulation
    grouping of sums/loss (assignments — hence counts — are exact for any
    chunk); at chunk >= n the result is bitwise-identical to the unfused
    `core.kmeans.lloyd_step` reference.
    """
    n, d = X.shape
    cpad = (-c) % ARGMIN_GROUP
    Ct = jnp.pad(C, ((0, cpad), (0, 0))).T         # (d, c+pad) contiguous
    cn = jnp.pad(jnp.sum(C * C, axis=-1), (0, cpad),
                 constant_values=jnp.inf)[None, :]
    npad = (-n) % chunk
    Xc = jnp.pad(X, ((0, npad), (0, 0))).reshape(-1, chunk, d)
    starts = (jnp.arange(Xc.shape[0]) * chunk).astype(jnp.int32)

    def body(carry, inp):
        sums, counts, loss = carry
        xb, i0 = inp
        dm = cn - 2.0 * _xct(xb, Ct)
        idx, mv = _grouped_argmin(dm)
        mind = mv + jnp.sum(xb * xb, axis=-1)
        valid = (i0 + jnp.arange(chunk, dtype=jnp.int32)) < n
        idx_m = jnp.where(valid, idx, c)           # pad rows → overflow bin
        sums = sums + jax.ops.segment_sum(xb, idx_m, num_segments=c + 1)[:c]
        counts = counts + jax.ops.segment_sum(
            valid.astype(X.dtype), idx_m, num_segments=c + 1)[:c]
        loss = loss + jnp.sum(jnp.where(valid, mind, 0.0))
        return (sums, counts, loss), None

    init = (jnp.zeros((c, d), X.dtype), jnp.zeros((c,), X.dtype),
            jnp.zeros((), X.dtype))
    (sums, counts, loss), _ = jax.lax.scan(body, init, (Xc, starts))
    new_C = jnp.where(counts[:, None] > 0,
                      sums / jnp.maximum(counts[:, None], 1.0), C)
    return new_C, counts, loss / n


def _lloyd_kernel(x_ref, valid_ref, c_ref, cn_ref,
                  sums_ref, counts_ref, loss_ref,
                  acc_sums, acc_counts, acc_loss, *, c: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_sums[...] = jnp.zeros_like(acc_sums)
        acc_counts[...] = jnp.zeros_like(acc_counts)
        acc_loss[...] = jnp.zeros_like(acc_loss)

    x = x_ref[...]                                  # (bn, d)
    valid = valid_ref[...]                          # (bn, 1) f32 0/1
    cm = c_ref[...]                                 # (c, d) full codebook
    cn = cn_ref[...]                                # (1, c)
    dm = cn - 2.0 * jax.lax.dot_general(
        x, cm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    idx = jnp.argmin(dm, axis=-1)
    mind = jnp.min(dm, axis=-1) + jnp.sum(x * x, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, dm.shape, 1)
              == idx[:, None]).astype(jnp.float32) * valid
    # MXU contraction: on TPU the one-hot matmul IS the fast accumulate
    acc_sums[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_counts[...] += jnp.sum(onehot, axis=0)[None, :]
    acc_loss[...] += jnp.sum(mind * valid[:, 0])[None, None]

    @pl.when(i == pl.num_programs(0) - 1)
    def _write():
        sums_ref[...] = acc_sums[...]
        counts_ref[...] = acc_counts[...]
        loss_ref[...] = acc_loss[...]


@functools.partial(jax.jit, static_argnames=("c", "bn", "interpret"))
def lloyd_sweep_pallas(X, C, c: int, bn: int = 1024, interpret: bool = True):
    """TPU route of the fused sweep (same contract as `lloyd_sweep`).

    Grid over row-tiles only (sequential, so VMEM scratch accumulates);
    the full (c, d) codebook stays VMEM-resident — sized for the build
    regime c <= 4096, d <= 256.
    """
    n, d = X.shape
    npad = (-n) % bn
    Xp = jnp.pad(X.astype(jnp.float32), ((0, npad), (0, 0)))
    valid = (jnp.arange(Xp.shape[0]) < n).astype(jnp.float32)[:, None]
    cn = jnp.sum(C * C, axis=-1).astype(jnp.float32)[None, :]
    grid = (Xp.shape[0] // bn,)
    sums, counts, loss = pl.pallas_call(
        functools.partial(_lloyd_kernel, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((c, d), jnp.float32),
            pltpu.VMEM((1, c), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(Xp, valid, C.astype(jnp.float32), cn)
    counts = counts[0]
    new_C = jnp.where(counts[:, None] > 0,
                      sums / jnp.maximum(counts[:, None], 1.0), C)
    return new_C, counts, loss[0, 0] / n


@functools.partial(jax.jit, static_argnames=("c", "chunk"))
def lloyd_sweep_batched(Xb, Cb, c: int, chunk: int = 16384):
    """`lloyd_sweep` over a leading batch of m independent problems
    (e.g. the m PQ subspaces trained jointly): one scan whose tiles carry
    all m slices, so the whole batch advances in a single device program
    per iteration.

    Hand-batched rather than vmap'd (vmap of the scan is ~2.5x slower on
    XLA:CPU), mirroring `lloyd_sweep` op-for-op in (m, ...) form: the
    small-d contraction is the same unrolled multiply-add chain, argmin
    the same grouped reduction, accumulation the same per-chunk vmapped
    segment-sum — per-slice results are bitwise-identical to calling
    `lloyd_sweep` per problem (pinned by tests/test_build_perf.py).
    """
    m, n, d = Xb.shape
    cpad = (-c) % ARGMIN_GROUP
    Cp = jnp.pad(Cb, ((0, 0), (0, cpad), (0, 0)))            # (m, c+pad, d)
    cn = jnp.pad(jnp.sum(Cb * Cb, axis=-1), ((0, 0), (0, cpad)),
                 constant_values=jnp.inf)[:, None, :]        # (m, 1, c+pad)
    npad = (-n) % chunk
    Xc = jnp.pad(Xb, ((0, 0), (0, npad), (0, 0))).reshape(
        m, -1, chunk, d).transpose(1, 0, 2, 3)               # (nch, m, chunk, d)
    starts = (jnp.arange(Xc.shape[0]) * chunk).astype(jnp.int32)

    def body(carry, inp):
        sums, counts, loss = carry
        xb, i0 = inp                                         # (m, chunk, d)
        if d <= SMALL_D:                                     # mirror _xct
            ip = xb[..., 0:1] * Cp[:, None, :, 0]
            for j in range(1, d):
                ip = ip + xb[..., j:j + 1] * Cp[:, None, :, j]
        else:
            ip = jnp.einsum("mbd,mcd->mbc", xb, Cp)
        dm = cn - 2.0 * ip
        idx, mv = _grouped_argmin(dm)                        # (m, chunk)
        mind = mv + jnp.sum(xb * xb, axis=-1)
        valid = (i0 + jnp.arange(chunk, dtype=jnp.int32)) < n
        idx_m = jnp.where(valid[None, :], idx, c)
        sums = sums + jax.vmap(
            lambda x, a: jax.ops.segment_sum(x, a, num_segments=c + 1)
        )(xb, idx_m)[:, :c]
        counts = counts + jax.vmap(
            lambda a: jax.ops.segment_sum(
                valid.astype(Xb.dtype), a, num_segments=c + 1))(idx_m)[:, :c]
        loss = loss + jnp.sum(jnp.where(valid[None, :], mind, 0.0), axis=-1)
        return (sums, counts, loss), None

    init = (jnp.zeros((m, c, d), Xb.dtype), jnp.zeros((m, c), Xb.dtype),
            jnp.zeros((m,), Xb.dtype))
    (sums, counts, loss), _ = jax.lax.scan(body, init, (Xc, starts))
    new_C = jnp.where(counts[..., None] > 0,
                      sums / jnp.maximum(counts[..., None], 1.0), Cb)
    return new_C, counts, loss / n


def lloyd_sweep_auto(X, C, c: int, chunk: int = 8192,
                     use_pallas: bool = None, interpret: bool = None):
    """Backend dispatch: Pallas on TPU (codebook fits VMEM), scan elsewhere."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas and c * X.shape[1] <= 1 << 20:
        return lloyd_sweep_pallas(X, C, c, interpret=interpret)
    return lloyd_sweep(X, C, c, chunk=chunk)
