"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode — the kernel body
runs as traced jnp ops, validating block logic exactly. On a real TPU
backend, `interpret=False` compiles to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.pq_score import pq_score_pallas, pq_score_window_pallas
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.soar_assign import soar_assign_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pq_score(luts, codes, **kw):
    """Batched PQ LUT scoring: (nq, m, 16) × (n, m) → (nq, n)."""
    return pq_score_pallas(luts, codes, interpret=_interpret(), **kw)


def pq_score_window(luts, codes, **kw):
    """Per-query candidate-window scoring: (nq, m, 16) × (nq, cand, m) →
    (nq, cand) — the candidate-local search_jit hot path."""
    return pq_score_window_pallas(luts, codes, interpret=_interpret(), **kw)


def vq_assign(X, C, **kw):
    """Fused nearest-centroid: (n, d) × (c, d) → (idx (n,), sqdist (n,))."""
    return vq_assign_pallas(X, C, interpret=_interpret(), **kw)


def soar_assign(X, rhat, primary, C, lam: float = 1.0, **kw):
    """Fused SOAR spilled assignment → (idx (n,), loss (n,))."""
    return soar_assign_pallas(X, rhat, primary, C, lam=lam,
                              interpret=_interpret(), **kw)
