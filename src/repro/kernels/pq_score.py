"""Pallas TPU kernels: batched PQ LUT scoring as a one-hot MXU contraction.

TPU adaptation of ScaNN's AVX2 LUT16 (DESIGN.md §3): instead of in-register
shuffles, codes are expanded to one-hot IN VMEM and contracted against the
per-query LUTs on the MXU. The LUT block stays VMEM-resident across the whole
point dimension; HBM traffic is one streaming read of the (packed) codes.

Two variants:

- `pq_score_pallas`: shared code matrix — every query scores every point.
      score[q, i] = sum_m luts[q, m, codes[i, m]]
                  = luts[q].reshape(m*16) · onehot(codes[i]).reshape(m*16)

- `pq_score_window_pallas`: per-query candidate windows — query q scores only
  ITS OWN gathered candidates (the t·pmax window the IVF search probes), the
  shape the candidate-local `search_jit` pipeline produces (DESIGN.md §3.6).
      score[q, i] = sum_m luts[q, m, codes[q, i, m]]
  The contraction is a per-query batched matvec on the MXU: each grid cell
  holds BQ LUT rows and BQ×BN code rows and contracts them batch-wise.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# Block sizes: BQ queries × BN points per grid cell. m*16 is the contraction
# dim (m=16 subspaces → 256, MXU-aligned). VMEM footprint per cell:
#   luts BQ×(m·16)·4B + codes BN×m·4B + onehot BN×(m·16)·4B + out BQ×BN·4B
#   ≈ 128·256·4 + 512·16·4 + 512·256·4 + 128·512·4 ≈ 0.9 MB  « 16 MB VMEM.
DEFAULT_BQ = 128
DEFAULT_BN = 512

# Window variant: the one-hot block is BQ×BN×(m·16), so BQ stays small.
#   8·512·256·4B ≈ 4 MB one-hot + 8·512·16·4B codes + 8·256·4B luts « 16 MB.
DEFAULT_WIN_BQ = 8
DEFAULT_WIN_BN = 512


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """None → auto-detect: compile to Mosaic on TPU, interpret elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pq_score_kernel(lut_ref, codes_ref, out_ref, *, n_centers: int):
    codes = codes_ref[...]                                   # (BN, m) int32
    onehot = (codes[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_centers), 2))
    onehot = onehot.astype(jnp.float32).reshape(codes.shape[0], -1)  # (BN, m*16)
    lut = lut_ref[...]                                       # (BQ, m*16)
    out_ref[...] = jax.lax.dot_general(
        lut, onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (BQ, BN)


@functools.partial(jax.jit, static_argnames=("n_centers", "bq", "bn", "interpret"))
def pq_score_pallas(luts, codes, n_centers: int = 16,
                    bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                    interpret: Optional[bool] = None):
    """luts (nq, m, 16) f32, codes (n, m) int32 → (nq, n) f32 scores.

    interpret=None auto-detects the backend (Mosaic on TPU, interpret mode
    elsewhere) — pass an explicit bool only to force one mode.
    """
    interpret = _resolve_interpret(interpret)
    nq, m, k = luts.shape
    n = codes.shape[0]
    assert k == n_centers
    lutmat = luts.reshape(nq, m * k)
    # pad to block multiples (zero LUT rows / zero codes are harmless: stripped)
    qpad = (-nq) % bq
    npad = (-n) % bn
    lutmat = jnp.pad(lutmat, ((0, qpad), (0, 0)))
    codes_p = jnp.pad(codes.astype(jnp.int32), ((0, npad), (0, 0)))
    grid = (lutmat.shape[0] // bq, codes_p.shape[0] // bn)
    out = pl.pallas_call(
        functools.partial(_pq_score_kernel, n_centers=n_centers),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, m * k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (lutmat.shape[0], codes_p.shape[0]), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(lutmat, codes_p)
    return out[:nq, :n]


def _pq_score_window_kernel(lut_ref, codes_ref, out_ref, *, n_centers: int):
    codes = codes_ref[...]                                   # (BQ, BN, m) int32
    onehot = (codes[:, :, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, 1, n_centers), 3))
    onehot = onehot.astype(jnp.float32).reshape(
        codes.shape[0], codes.shape[1], -1)                  # (BQ, BN, m*16)
    lut = lut_ref[...]                                       # (BQ, m*16)
    # batched matvec: out[b, i] = lut[b, :] · onehot[b, i, :]
    out_ref[...] = jax.lax.dot_general(
        lut, onehot, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                  # (BQ, BN)


@functools.partial(jax.jit, static_argnames=("n_centers", "bq", "bn", "interpret"))
def pq_score_window_pallas(luts, codes, n_centers: int = 16,
                           bq: int = DEFAULT_WIN_BQ, bn: int = DEFAULT_WIN_BN,
                           interpret: Optional[bool] = None):
    """luts (nq, m, 16) f32, codes (nq, cand, m) int → (nq, cand) f32 scores.

    Per-query candidate-window scoring: row q of `codes` is query q's own
    gathered candidate window (already in partition-probe order). This is the
    hot-path shape of the candidate-local `search_jit` pipeline.
    """
    interpret = _resolve_interpret(interpret)
    nq, m, k = luts.shape
    assert k == n_centers
    assert codes.shape[0] == nq and codes.shape[2] == m, (luts.shape, codes.shape)
    cand = codes.shape[1]
    lutmat = luts.reshape(nq, m * k)
    qpad = (-nq) % bq
    npad = (-cand) % bn
    lutmat = jnp.pad(lutmat, ((0, qpad), (0, 0)))
    codes_p = jnp.pad(codes.astype(jnp.int32), ((0, qpad), (0, npad), (0, 0)))
    grid = (lutmat.shape[0] // bq, codes_p.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_pq_score_window_kernel, n_centers=n_centers),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, m * k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bn, m), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (lutmat.shape[0], codes_p.shape[1]), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(lutmat, codes_p)
    return out[:nq, :cand]
