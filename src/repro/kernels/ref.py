"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_score_ref(luts, codes):
    """luts (nq, m, 16) f32, codes (n, m) int → scores (nq, n).

    score[q, i] = sum_m luts[q, m, codes[i, m]].
    """
    gathered = jnp.take_along_axis(
        luts[:, None, :, :],                                  # (nq, 1, m, 16)
        codes[None, :, :, None].astype(jnp.int32), axis=3)    # (nq, n, m, 1)
    return jnp.sum(gathered[..., 0], axis=-1)


def pq_score_window_ref(luts, codes):
    """luts (nq, m, 16) f32, codes (nq, cand, m) int → scores (nq, cand).

    Per-query candidate-window scoring (the candidate-local search_jit hot
    path): score[q, i] = sum_m luts[q, m, codes[q, i, m]].
    """
    gathered = jnp.take_along_axis(
        luts[:, None, :, :],                                  # (nq, 1, m, 16)
        codes.astype(jnp.int32)[..., None], axis=3)           # (nq, cand, m, 1)
    return jnp.sum(gathered[..., 0], axis=-1)


def vq_assign_ref(X, C):
    """Nearest centroid by squared L2. Returns (idx (n,), sqdist (n,))."""
    d2 = (jnp.sum(C * C, -1)[None, :] - 2.0 * (X @ C.T)
          + jnp.sum(X * X, -1)[:, None])
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return idx, jnp.take_along_axis(d2, idx[:, None], axis=1)[:, 0]


def soar_assign_ref(X, rhat, primary, C, lam: float):
    """SOAR spilled assignment (Theorem 3.1 loss), excluding the primary.

    loss_ij = ||x_i - c_j||^2 + lam * <rhat_i, x_i - c_j>^2
    Returns (idx (n,), loss-at-idx (n,)); loss includes the ||x||^2 term.
    """
    xc = X @ C.T
    rc = rhat @ C.T
    rx = jnp.sum(rhat * X, axis=-1)
    loss = (jnp.sum(C * C, -1)[None, :] - 2.0 * xc
            + jnp.sum(X * X, -1)[:, None]
            + lam * (rx[:, None] - rc) ** 2)
    loss = jnp.where(
        jax.nn.one_hot(primary, C.shape[0], dtype=bool), jnp.inf, loss)
    idx = jnp.argmin(loss, axis=-1).astype(jnp.int32)
    return idx, jnp.take_along_axis(loss, idx[:, None], axis=1)[:, 0]
