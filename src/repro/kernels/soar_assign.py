"""Pallas TPU kernel: fused SOAR spilled assignment (Theorem 3.1 loss).

loss_ij = ||c_j||^2 - 2<x_i,c_j> + lam*(<rhat_i,x_i> - <rhat_i,c_j>)^2
          (+ ||x_i||^2, constant in j)

Two MXU passes per (point-tile × centroid-tile): X·Cᵀ and R̂·Cᵀ, then
elementwise penalty + primary-exclusion mask + running argmin in VMEM
scratch — the full (n × c) loss matrix never exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BN = 512
DEFAULT_BC = 512


def _soar_kernel(x_ref, rhat_ref, rx_ref, prim_ref, c_ref, cn_ref,
                 idx_ref, val_ref, best_val, best_idx, *, bc: int, lam: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_val[...] = jnp.full_like(best_val, jnp.inf)
        best_idx[...] = jnp.zeros_like(best_idx)

    x = x_ref[...]
    rhat = rhat_ref[...]
    rx = rx_ref[...]                                          # (BN, 1)
    prim = prim_ref[...]                                      # (BN, 1) int32
    c = c_ref[...]
    cn = cn_ref[...]                                          # (1, BC)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    rc = jax.lax.dot_general(rhat, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    loss = cn - 2.0 * xc + lam * (rx - rc) ** 2               # (BN, BC)
    gids = j * bc + jax.lax.broadcasted_iota(jnp.int32, loss.shape, 1)
    loss = jnp.where(gids == prim, jnp.inf, loss)
    local_idx = jnp.argmin(loss, axis=-1)
    local_val = jnp.min(loss, axis=-1)
    gidx = (j * bc + local_idx).astype(jnp.int32)
    better = local_val < best_val[:, 0]
    best_val[...] = jnp.where(better, local_val, best_val[:, 0])[:, None]
    best_idx[...] = jnp.where(better, gidx, best_idx[:, 0])[:, None]

    @pl.when(j == pl.num_programs(1) - 1)
    def _write():
        idx_ref[...] = best_idx[...]
        val_ref[...] = best_val[...]


@functools.partial(jax.jit,
                   static_argnames=("lam", "bn", "bc", "interpret"))
def soar_assign_pallas(X, rhat, primary, C, lam: float = 1.0,
                       bn: int = DEFAULT_BN, bc: int = DEFAULT_BC,
                       interpret: bool = True):
    """Returns (idx (n,) int32, loss-at-idx (n,) incl. ||x||^2 term)."""
    n, d = X.shape
    c = C.shape[0]
    npad = (-n) % bn
    cpad = (-c) % bc
    Xp = jnp.pad(X.astype(jnp.float32), ((0, npad), (0, 0)))
    Rp = jnp.pad(rhat.astype(jnp.float32), ((0, npad), (0, 0)))
    rx = jnp.sum(rhat * X, axis=-1, keepdims=True).astype(jnp.float32)
    rx = jnp.pad(rx, ((0, npad), (0, 0)))
    prim = jnp.pad(primary.astype(jnp.int32)[:, None], ((0, npad), (0, 0)),
                   constant_values=-1)
    Cp = jnp.pad(C.astype(jnp.float32), ((0, cpad), (0, 0)))
    cn = jnp.sum(C * C, axis=-1).astype(jnp.float32)
    cn = jnp.pad(cn, (0, cpad), constant_values=jnp.inf)[None, :]
    grid = (Xp.shape[0] // bn, Cp.shape[0] // bc)
    idx, val = pl.pallas_call(
        functools.partial(_soar_kernel, bc=bc, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, 1), jnp.float32),
            pltpu.VMEM((bn, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(Xp, Rp, rx, prim, Cp, cn)
    xn = jnp.sum(X * X, axis=-1)
    return idx[:n, 0], val[:n, 0] + xn


# --------------------------------------------------------------------------
# Batched/fused primary + spill assignment (the sharded-build hot path)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_spills", "chunk"))
def _fused_assign_gemm(X, C, lam: float, n_spills: int, chunk: int):
    """Chunked fused primary + spill assignment (non-TPU backends).

    Per tile of X: ONE X·Cᵀ GEMM shared by the primary argmin and every
    spill step's distance term (the reassociated two-GEMM loss form of
    core/soar.py); each spill adds one R̂·Cᵀ GEMM and accumulates its
    orthogonality penalty, so the full multi-spill objective of
    `soar_assign_multi` is preserved. Total 1 + n_spills GEMM passes over
    the data vs 2 + 2·n_spills for the unfused train-then-spill sequence.

    The codebook is column-padded to the argmin group width with
    ||c||² = +inf sentinels (never selected) and argmins run through the
    grouped exact reduction of kernels/lloyd.py — identical indices to
    `jnp.argmin` (pinned against the core/soar.py compositions in
    tests/test_build.py), ~1.8x faster on XLA:CPU.
    """
    from repro.kernels.lloyd import ARGMIN_GROUP, _grouped_argmin
    from repro.utils import chunked_map

    c = C.shape[0]
    cpad = (-c) % ARGMIN_GROUP
    Cp = jnp.pad(C, ((0, cpad), (0, 0)))
    Ct = Cp.T
    cn = jnp.pad(jnp.sum(C * C, axis=-1), (0, cpad),
                 constant_values=jnp.inf)

    def f(xb):
        xc = xb @ Ct                                        # shared GEMM
        prim, _ = _grouped_argmin(cn[None, :] - 2.0 * xc)
        assigns = [prim]
        used = jax.nn.one_hot(prim, c + cpad, dtype=bool)
        pen = jnp.zeros_like(xc)
        for _ in range(n_spills):
            r = xb - Cp[assigns[-1]]
            rn = jnp.linalg.norm(r, axis=-1, keepdims=True)
            rhat = r / jnp.maximum(rn, 1e-12)
            rc = rhat @ Ct                                  # one GEMM / spill
            rx = jnp.sum(rhat * xb, axis=-1)
            pen = pen + (rx[:, None] - rc) ** 2
            loss = cn[None, :] - 2.0 * xc + lam * pen
            loss = jnp.where(used, jnp.inf, loss)
            nxt, _ = _grouped_argmin(loss)
            assigns.append(nxt)
            used = used | jax.nn.one_hot(nxt, c + cpad, dtype=bool)
        return jnp.stack(assigns, axis=1)

    return chunked_map(f, X.astype(jnp.float32), chunk)


def assign_fused(X, C, lam: float = 1.0, n_spills: int = 1,
                 chunk: int = 8192, use_pallas: bool = None,
                 interpret: bool = None):
    """Primary + spilled assignment(s) against a FROZEN codebook, fused.

    The sharded build driver (core/build.py) and the incremental-insert
    path (core/mutable.py) both route through here: assignment is the only
    per-point work at build time, so it runs as streamed tiles with nothing
    materialized at O(n × c).

    On TPU (or use_pallas=True) the single-spill case runs the Pallas
    kernel above (two MXU passes per tile, loss matrix never leaves VMEM)
    after a fused `vq_assign` primary pass; multi-spill and other backends
    use the chunked two-GEMM jnp path, which shares the X·Cᵀ GEMM between
    the primary argmin and every spill step.

    Returns (n, 1 + n_spills) int32 assignments, column 0 primary.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    X = jnp.asarray(X, jnp.float32)
    C = jnp.asarray(C, jnp.float32)
    if n_spills == 0:
        from repro.utils import pairwise_neg_sqdist_argmin
        prim, _ = pairwise_neg_sqdist_argmin(X, C, chunk=chunk)
        return prim[:, None]
    if not use_pallas or n_spills > 1:
        return _fused_assign_gemm(X, C, lam=lam, n_spills=n_spills,
                                  chunk=chunk)
    from repro.kernels.vq_assign import vq_assign_pallas
    prim, _ = vq_assign_pallas(X, C, interpret=interpret)
    r = X - C[prim]
    rhat = r / jnp.maximum(jnp.linalg.norm(r, axis=-1, keepdims=True), 1e-12)
    sec, _ = soar_assign_pallas(X, rhat, prim, C, lam=lam,
                                interpret=interpret)
    return jnp.stack([prim, sec], axis=1)
