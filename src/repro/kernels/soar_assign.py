"""Pallas TPU kernel: fused SOAR spilled assignment (Theorem 3.1 loss).

loss_ij = ||c_j||^2 - 2<x_i,c_j> + lam*(<rhat_i,x_i> - <rhat_i,c_j>)^2
          (+ ||x_i||^2, constant in j)

Two MXU passes per (point-tile × centroid-tile): X·Cᵀ and R̂·Cᵀ, then
elementwise penalty + primary-exclusion mask + running argmin in VMEM
scratch — the full (n × c) loss matrix never exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BN = 512
DEFAULT_BC = 512


def _soar_kernel(x_ref, rhat_ref, rx_ref, prim_ref, c_ref, cn_ref,
                 idx_ref, val_ref, best_val, best_idx, *, bc: int, lam: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_val[...] = jnp.full_like(best_val, jnp.inf)
        best_idx[...] = jnp.zeros_like(best_idx)

    x = x_ref[...]
    rhat = rhat_ref[...]
    rx = rx_ref[...]                                          # (BN, 1)
    prim = prim_ref[...]                                      # (BN, 1) int32
    c = c_ref[...]
    cn = cn_ref[...]                                          # (1, BC)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    rc = jax.lax.dot_general(rhat, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    loss = cn - 2.0 * xc + lam * (rx - rc) ** 2               # (BN, BC)
    gids = j * bc + jax.lax.broadcasted_iota(jnp.int32, loss.shape, 1)
    loss = jnp.where(gids == prim, jnp.inf, loss)
    local_idx = jnp.argmin(loss, axis=-1)
    local_val = jnp.min(loss, axis=-1)
    gidx = (j * bc + local_idx).astype(jnp.int32)
    better = local_val < best_val[:, 0]
    best_val[...] = jnp.where(better, local_val, best_val[:, 0])[:, None]
    best_idx[...] = jnp.where(better, gidx, best_idx[:, 0])[:, None]

    @pl.when(j == pl.num_programs(1) - 1)
    def _write():
        idx_ref[...] = best_idx[...]
        val_ref[...] = best_val[...]


@functools.partial(jax.jit,
                   static_argnames=("lam", "bn", "bc", "interpret"))
def soar_assign_pallas(X, rhat, primary, C, lam: float = 1.0,
                       bn: int = DEFAULT_BN, bc: int = DEFAULT_BC,
                       interpret: bool = True):
    """Returns (idx (n,) int32, loss-at-idx (n,) incl. ||x||^2 term)."""
    n, d = X.shape
    c = C.shape[0]
    npad = (-n) % bn
    cpad = (-c) % bc
    Xp = jnp.pad(X.astype(jnp.float32), ((0, npad), (0, 0)))
    Rp = jnp.pad(rhat.astype(jnp.float32), ((0, npad), (0, 0)))
    rx = jnp.sum(rhat * X, axis=-1, keepdims=True).astype(jnp.float32)
    rx = jnp.pad(rx, ((0, npad), (0, 0)))
    prim = jnp.pad(primary.astype(jnp.int32)[:, None], ((0, npad), (0, 0)),
                   constant_values=-1)
    Cp = jnp.pad(C.astype(jnp.float32), ((0, cpad), (0, 0)))
    cn = jnp.sum(C * C, axis=-1).astype(jnp.float32)
    cn = jnp.pad(cn, (0, cpad), constant_values=jnp.inf)[None, :]
    grid = (Xp.shape[0] // bn, Cp.shape[0] // bc)
    idx, val = pl.pallas_call(
        functools.partial(_soar_kernel, bc=bc, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, 1), jnp.float32),
            pltpu.VMEM((bn, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(Xp, Rp, rx, prim, Cp, cn)
    xn = jnp.sum(X * X, axis=-1)
    return idx[:n, 0], val[:n, 0] + xn
