"""Fused two-level centroid routing (the TreeRouter probe stage).

Given the two-level tables of core/router.TreeRouter — super centroids
(S, d), a padded (S, cmax) children table, and the child centroid rows
grouped to match — produce, per query, the scores and partition ids of
every child of its top-``t_route`` super-clusters:

    (nq, d) -> scores (nq, t_route·cmax) f32, ids (nq, t_route·cmax) i32

(-inf / -1 at children-table padding). The final top-t cut happens in the
caller (core/router.TreeRouter.route) — the kernel's job is the fused
middle: super GEMM -> per-query super selection -> child gather+score,
with nothing (nq, S)- or (nq, t_route·cmax·d)-shaped leaving the tile.

Two routes, same contract (mirroring kernels/soar_assign.py):

- ``tree_route_ref`` (any backend): jit'd form — one (nq, S) GEMM +
  ``lax.top_k``, then a statically-unrolled per-round gather + einsum so
  the live child-centroid gather is bounded at (nq, cmax, d) per round
  instead of (nq, t_route·cmax, d);
- ``tree_route_pallas`` (TPU): query-tile grid with the super codebook
  and both child tables VMEM-resident; per round the selected super is
  materialized as a one-hot and the child block/id gathers run as
  one-hot MXU contractions (the same gather-as-matmul idiom as
  kernels/pq_score.py and the lloyd accumulate) — no dynamic gather
  lowering needed, and the (bq, S) score matrix never leaves VMEM.
  Sized for the routing regime S·d and cmax·d ≲ a few MB of VMEM
  (S ~ sqrt(c) ≤ 512, d ≤ 256); larger configs fall back to the ref.

The two routes select supers in the same order (iterative argmax ==
descending top-k with first-index tie-breaks); child scores may differ
by f32 reduction order only (allclose-pinned in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BQ = 128


@functools.partial(jax.jit, static_argnames=("t_route",))
def tree_route_ref(Q, SC, CC, CH, t_route: int):
    """Reference route: (nq, S) GEMM + top-k supers, then one gathered
    (nq, cmax, d) einsum per round (statically unrolled, memory bounded
    per round regardless of t_route)."""
    ss = Q @ SC.T                                          # (nq, S)
    _, sup = jax.lax.top_k(ss, t_route)                    # (nq, tr)
    scores, ids = [], []
    for r in range(t_route):
        s_r = sup[:, r]
        cid = CH[s_r]                                      # (nq, cmax)
        cc = CC[s_r]                                       # (nq, cmax, d)
        sc = jnp.einsum("qcd,qd->qc", cc, Q)
        scores.append(jnp.where(cid >= 0, sc, -jnp.inf))
        ids.append(cid)
    return jnp.concatenate(scores, -1), jnp.concatenate(ids, -1)


def _tree_route_kernel(q_ref, sc_ref, ccf_ref, chf_ref,
                       scores_ref, ids_ref, *, t_route: int, cmax: int,
                       d: int):
    q = q_ref[...]                                         # (bq, d)
    ss = jax.lax.dot_general(q, sc_ref[...],
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bq, S)
    ccf = ccf_ref[...]                                     # (S, cmax·d)
    chf = chf_ref[...]                                     # (S, cmax) f32
    bq = q.shape[0]
    for r in range(t_route):
        idx = jnp.argmax(ss, axis=-1)                      # (bq,)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, ss.shape, 1)
                  == idx[:, None]).astype(jnp.float32)
        # one-hot MXU gather: selected super's child block / id row
        blk = jax.lax.dot_general(onehot, ccf,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        cid = jax.lax.dot_general(onehot, chf,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        sc = jnp.sum(blk.reshape(bq, cmax, d) * q[:, None, :], axis=-1)
        sc = jnp.where(cid > -0.5, sc, -jnp.inf)
        scores_ref[:, r * cmax:(r + 1) * cmax] = sc
        ids_ref[:, r * cmax:(r + 1) * cmax] = cid.astype(jnp.int32)
        ss = jnp.where(onehot > 0, -jnp.inf, ss)           # extract-and-mask


@functools.partial(jax.jit, static_argnames=("t_route", "bq", "interpret"))
def tree_route_pallas(Q, SC, CC, CH, t_route: int, bq: int = DEFAULT_BQ,
                      interpret: bool = True):
    """Pallas route (TPU target; interpret mode elsewhere/CI)."""
    nq, d = Q.shape
    S, cmax, _ = CC.shape
    npad = (-nq) % bq
    Qp = jnp.pad(Q.astype(jnp.float32), ((0, npad), (0, 0)))
    ccf = CC.astype(jnp.float32).reshape(S, cmax * d)
    chf = CH.astype(jnp.float32)
    w = t_route * cmax
    grid = (Qp.shape[0] // bq,)
    scores, ids = pl.pallas_call(
        functools.partial(_tree_route_kernel, t_route=t_route, cmax=cmax,
                          d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((S, d), lambda i: (0, 0)),
            pl.BlockSpec((S, cmax * d), lambda i: (0, 0)),
            pl.BlockSpec((S, cmax), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, w), lambda i: (i, 0)),
            pl.BlockSpec((bq, w), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp.shape[0], w), jnp.float32),
            jax.ShapeDtypeStruct((Qp.shape[0], w), jnp.int32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(Qp, SC.astype(jnp.float32), ccf, chf)
    return scores[:nq], ids[:nq]


def tree_route(Q, SC, CC, CH, t_route: int, use_pallas: bool = None,
               interpret: bool = None):
    """Backend dispatch, mirroring assign_fused: Pallas on TPU when the
    child tables fit VMEM, the jit'd reference elsewhere."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, cmax, d = CC.shape
    if use_pallas and cmax * d <= 1 << 18 and S * d <= 1 << 20:
        return tree_route_pallas(Q, SC, CC, CH, t_route,
                                 interpret=interpret)
    return tree_route_ref(Q, SC, CC, CH, t_route)
