"""Pallas TPU kernel: fused nearest-centroid assignment.

Computes argmin_j ||x - c_j||^2 over centroid tiles with a running
(min, argmin) kept in VMEM scratch — only the final index/value leave the
core (HBM write O(n) instead of the O(n·c) distance matrix). The distance is
reassociated to the one-GEMM form ||c||^2 - 2<x,c> (+ ||x||^2 outside).

Grid: (points/BN, centroids/BC); the centroid dim is sequential
("arbitrary") so the scratch accumulates across tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BN = 512
DEFAULT_BC = 512


def _vq_assign_kernel(x_ref, c_ref, cn_ref, idx_ref, val_ref,
                      best_val, best_idx, *, bc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_val[...] = jnp.full_like(best_val, jnp.inf)
        best_idx[...] = jnp.zeros_like(best_idx)

    x = x_ref[...]                                            # (BN, d)
    c = c_ref[...]                                            # (BC, d)
    cn = cn_ref[...]                                          # (1, BC) — +inf padded
    scores = cn - 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (BN, BC)
    local_idx = jnp.argmin(scores, axis=-1)                   # (BN,)
    local_val = jnp.min(scores, axis=-1)
    gidx = (j * bc + local_idx).astype(jnp.int32)
    better = local_val < best_val[:, 0]
    best_val[...] = jnp.where(better, local_val, best_val[:, 0])[:, None]
    best_idx[...] = jnp.where(better, gidx, best_idx[:, 0])[:, None]

    @pl.when(j == pl.num_programs(1) - 1)
    def _write():
        idx_ref[...] = best_idx[...]
        val_ref[...] = best_val[...]


@functools.partial(jax.jit, static_argnames=("bn", "bc", "interpret"))
def vq_assign_pallas(X, C, bn: int = DEFAULT_BN, bc: int = DEFAULT_BC,
                     interpret: bool = True):
    """X (n, d), C (c, d) → (idx (n,) int32, sqdist (n,) f32)."""
    n, d = X.shape
    c = C.shape[0]
    npad = (-n) % bn
    cpad = (-c) % bc
    Xp = jnp.pad(X.astype(jnp.float32), ((0, npad), (0, 0)))
    Cp = jnp.pad(C.astype(jnp.float32), ((0, cpad), (0, 0)))
    cn = jnp.sum(C * C, axis=-1).astype(jnp.float32)
    cn = jnp.pad(cn, (0, cpad), constant_values=jnp.inf)[None, :]  # (1, cp)
    grid = (Xp.shape[0] // bn, Cp.shape[0] // bc)
    idx, val = pl.pallas_call(
        functools.partial(_vq_assign_kernel, bc=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, 1), jnp.float32),
            pltpu.VMEM((bn, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(Xp, Cp, cn)
    xn = jnp.sum(X * X, axis=-1)
    return idx[:n, 0], val[:n, 0] + xn
