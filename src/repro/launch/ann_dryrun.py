import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the DISTRIBUTED SOAR SERVING step on the production meshes —
the paper's own workload at big-ann-benchmarks scale (SPACEV-like: ~0.5B
vectors), sharded over all mesh axes.

    PYTHONPATH=src python -m repro.launch.ann_dryrun [--mesh single|multi|both]

Per shard: 1M vectors, 2500 partitions (the paper's 400 pts/partition),
f32 rerank data. 256 shards (single pod) / 512 (multi) → 256M / 512M
vectors total. The search step is lowered + compiled with
ShapeDtypeStructs; memory/cost/collective analysis goes to
artifacts/dryrun/ann_serve_<mesh>.json.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.distributed import (abstract_sharded_ivf,  # noqa: E402
                                    abstract_sharded_ivf_pq,
                                    make_distributed_search,
                                    make_distributed_search_pq,
                                    sharded_ivf_pq_pspecs,
                                    sharded_ivf_pspecs)
from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS,  # noqa: E402
                                 fmt_summary)
from repro.launch.hlo_analysis import analyze  # noqa: E402
from repro.launch.mesh import (make_production_mesh, set_mesh,  # noqa: E402
                               to_shardings)

N_LOCAL = 1_000_000
C_LOCAL = 2_500
PMAX = 1_000          # ~2x mean partition size (spilled)
D = 100
NQ = 1_024
TOP_T = 40
FINAL_K = 10


def run(multi_pod: bool, pq: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_shards = 512 if multi_pod else 256
    q = jax.ShapeDtypeStruct((NQ, D), jnp.float32)
    from jax.sharding import PartitionSpec as P
    if pq:
        m = D // 4   # s=4 dims/subspace
        ivf = abstract_sharded_ivf_pq(n_shards, N_LOCAL, C_LOCAL, PMAX, D, m)
        search = make_distributed_search_pq(mesh, axes, top_t=TOP_T,
                                            final_k=FINAL_K)
        in_sh = (sharded_ivf_pq_pspecs(axes), P())
    else:
        ivf = abstract_sharded_ivf(n_shards, N_LOCAL, C_LOCAL, PMAX, D)
        search = make_distributed_search(mesh, axes, top_t=TOP_T,
                                         final_k=FINAL_K)
        in_sh = (sharded_ivf_pspecs(axes), P())
    t0 = time.time()
    with set_mesh(mesh):
        lowered = jax.jit(search, in_shardings=to_shardings(mesh, in_sh),
                          out_shardings=to_shardings(mesh, (P(), P()))
                          ).lower(ivf, q)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
    an = analyze(compiled.as_text())
    terms = {
        "compute_s": an["flops"] / PEAK_FLOPS,
        "memory_s": an["hbm_bytes"] / HBM_BW,
        "collective_s": an["collective_bytes_total"] / ICI_BW,
    }
    result = dict(
        arch="soar-ann-serve" + ("-pq" if pq else ""),
        shape=f"{n_shards}x{N_LOCAL//1000}k_q{NQ}",
        mesh="multi" if multi_pod else "single",
        compile_s=round(time.time() - t0, 1),
        memory=dict(argument_bytes=mem.argument_size_in_bytes,
                    temp_bytes=mem.temp_size_in_bytes,
                    output_bytes=mem.output_size_in_bytes,
                    peak_bytes=mem.argument_size_in_bytes + mem.temp_size_in_bytes),
        collectives={k: v for k, v in an["collectives"].items() if v["count"]},
        collective_bytes_total=an["collective_bytes_total"],
        roofline=dict(**{k: float(f"{v:.6g}") for k, v in terms.items()},
                      dominant=max(terms, key=terms.get),
                      model_flops_total=0, model_flops_per_device=0,
                      useful_flops_ratio=0,
                      bound_step_s=max(terms.values())),
        n_chips=n_shards,
    )
    os.makedirs("artifacts/dryrun", exist_ok=True)
    tag = "ann_serve_pq" if pq else "ann_serve"
    with open(f"artifacts/dryrun/{tag}_{result['mesh']}.json", "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="both",
                    choices=["baseline", "pq", "both"])
    args = ap.parse_args()
    variants = {"baseline": [False], "pq": [True],
                "both": [False, True]}[args.variant]
    for mp in {"single": [False], "multi": [True],
               "both": [False, True]}[args.mesh]:
        for pq in variants:
            r = run(mp, pq=pq)
            print(fmt_summary(r))


if __name__ == "__main__":
    main()
