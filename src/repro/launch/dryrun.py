import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell and
extract memory / cost / collective analysis for the roofline report.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all

Artifacts: artifacts/dryrun/<arch>_<shape>_<mesh>.json
"""
import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_rule_overrides  # noqa: E402
from repro.launch.mesh import (build_rules, make_production_mesh,  # noqa: E402
                               set_mesh, to_shardings)
from repro.launch import specs as S                                 # noqa: E402
from repro.launch.hlo_analysis import analyze                       # noqa: E402
from repro.models.config import SHAPES, cell_applicable             # noqa: E402
from repro.models.layers import set_logical_rules                   # noqa: E402

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_extra: dict | None = None, save: bool = True) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    ok, why = cell_applicable(cfg, cell)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        result["skipped"] = why
        return result

    n_chips = 512 if multi_pod else 256
    overrides = dict(get_rule_overrides(arch))
    if rules_extra:
        overrides.update(rules_extra)
    rules = build_rules(overrides, multi_pod=multi_pod,
                        batch_size=cell.global_batch)
    if cell.kind == "decode":
        # H2 (EXPERIMENTS §Perf): per-STEP param re-gather dominates decode;
        # prefill amortizes the gather over the whole sequence, so it keeps
        # FSDP (replication there only raises peak memory).
        rules = S.serve_rules(cfg, rules)
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_logical_rules(rules)

    if cell.kind == "train":
        fn, args, in_sh, out_sh = S.train_cell_specs(cfg, cell, rules, multi_pod)
        donate = (0, 1)         # params + optimizer state update in place
    elif cell.kind == "prefill":
        fn, args, in_sh, out_sh = S.prefill_cell_specs(cfg, cell, rules)
        donate = ()
    else:
        fn, args, in_sh, out_sh = S.decode_cell_specs(cfg, cell, rules)
        donate = (2,)           # KV cache updated in place

    t0 = time.time()
    with set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=to_shardings(mesh, in_sh),
                          out_shardings=to_shardings(mesh, out_sh),
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware static analysis (XLA's cost_analysis counts loop
    # bodies once — see hlo_analysis.py); per-device program values.
    an = analyze(hlo)
    flops = float(an["flops"])
    bytes_acc = float(an["hbm_bytes"])
    colls = {k: v for k, v in an["collectives"].items() if v["count"]}
    coll_total = float(an["collective_bytes_total"])
    mf = S.model_flops(cfg, cell)

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    # collective term: bytes leaving/entering ONE device over its ICI links
    collective_s = coll_total / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    result.update(dict(
        rules={k: str(v) for k, v in rules.items()},
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        per_device=dict(
            flops=flops, bytes_accessed=bytes_acc,
            output_bytes=float(cost.get("bytes accessed output", 0.0)),
        ),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
            output_bytes=getattr(mem, "output_size_in_bytes", 0),
            temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
            peak_bytes=(getattr(mem, "temp_size_in_bytes", 0)
                        + getattr(mem, "argument_size_in_bytes", 0)),
        ),
        collectives=colls,
        collective_bytes_total=coll_total,
        roofline=dict(
            **{k: float(f"{v:.6g}") for k, v in terms.items()},
            dominant=dominant,
            model_flops_total=mf,
            model_flops_per_device=mf / n_chips,
            useful_flops_ratio=float(f"{(mf / n_chips) / max(flops, 1):.4g}"),
            bound_step_s=float(f"{max(terms.values()):.6g}"),
        ),
        n_chips=n_chips,
    ))
    if save:
        os.makedirs("artifacts/dryrun", exist_ok=True)
        path = f"artifacts/dryrun/{arch}_{shape_name}_{mesh_name}.json"
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def fmt_summary(r: dict) -> str:
    if "skipped" in r:
        return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
                f"SKIP ({r['skipped']})")
    rf = r["roofline"]
    mem_gb = r["memory"]["peak_bytes"] / 2**30
    return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
            f"compile {r['compile_s']:6.1f}s mem {mem_gb:6.2f}GiB "
            f"compute {rf['compute_s']:.3g}s mem-term {rf['memory_s']:.3g}s "
            f"coll {rf['collective_s']:.3g}s → {rf['dominant']}"
            f" useful={rf['useful_flops_ratio']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        try:
            r = run_cell(a, s, mp)
            print(fmt_summary(r), flush=True)
        except Exception as e:
            failures += 1
            print(f"{a:22s} {s:12s} {'multi' if mp else 'single':6s} "
                  f"FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("all cells passed")


if __name__ == "__main__":
    main()
