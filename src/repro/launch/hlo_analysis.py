"""Static analyzer for post-SPMD optimized HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE (verified
empirically — flops are identical for 1 vs 32 scan iterations), which makes
it useless for scanned-layer models. This module re-derives the three
roofline inputs by walking the HLO call graph with loop-trip multipliers:

- matmul FLOPs: every `dot` (2 * prod(output) * contraction), inside
  fusion bodies included, scaled by the product of enclosing while trips;
- HBM bytes: per top-level instruction, operands + output (a fusion's
  HBM traffic is its boundary, which is exactly why XLA fuses), scaled by
  trips — re-reading a tensor every iteration costs every iteration;
- collective bytes: output-shape bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute (+ async -start forms),
  scaled by trips.

Trip counts: a scan's condition region compares the induction variable to a
constant — we take the max s32 constant in the condition computation.
Validated in tests/test_hlo_analysis.py against hand-computable programs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*[a-z]*)\[([0-9,]*)\]")
_INSTR_HDR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+) = ")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_PARAM_IN_HDR = re.compile(
    r"([\w\.\-]+):\s*((?:\((?:[^()]|\([^()]*\))*\))|"
    r"(?:[a-z]\d*\w*\[[0-9,]*\](?:\{[^}]*\})?))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _split_instr(line: str):
    """'%name = TYPE opcode(operands), attrs' → (name, type, op, rest).

    Robust to tuple types containing nested parens and '/*index=N*/'
    comments (which contain '=', defeating naive regexes).
    """
    m = _INSTR_HDR.match(line)
    if not m:
        return None
    name = m.group(1)
    rem = line[m.end():]
    if rem.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rem):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str = rem[:end + 1]
        rest = rem[end + 1:]
    else:
        sp = rem.find(" ")
        if sp < 0:
            return None
        type_str = rem[:sp]
        rest = rem[sp:]
    m2 = _OP_RE.match(rest)
    if not m2:
        return None
    return name, type_str, m2.group(1), rest[m2.end():]

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of a shape string, incl. tuple types '(f32[2,3], s32[4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    out_type: str
    op: str
    rest: str                      # text after the opening paren
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # symbol → type str


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            if s.endswith("{") and "->" in s and (s.startswith("%")
                                                  or s.startswith("ENTRY")):
                is_entry = s.startswith("ENTRY")
                name_part = s[len("ENTRY"):].strip() if is_entry else s
                name = name_part.split()[0].split("(")[0].lstrip("%")
                cur = Computation(name)
                if is_entry:
                    entry = cur.name
                # parameter shapes from the header
                hdr = line[line.find("(") + 1: line.rfind("->")]
                for pname, ptype in _PARAM_IN_HDR.findall(hdr):
                    cur.shapes[pname] = ptype
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _split_instr(line)
        if parsed is None:
            continue
        name, otype, op, rest = parsed
        # operand names: %tokens up to the matching close paren
        depth = 1
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnds = re.findall(r"%([\w\.\-]+)", rest[:end])
        ins = Instr(name, otype, op, rest, opnds)
        cur.instrs.append(ins)
        cur.shapes[name] = otype
    return comps, entry


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(cond: Computation) -> int:
    """Max s32 constant in the condition region ≈ loop bound (jax scans)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.out_type.startswith("s32"):
            m = re.search(r"constant\((-?\d+)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = shape_dims(ins.out_type) or []
    out_n = 1
    for d in out_dims:
        out_n *= d
    # contraction size: product of lhs contracting dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    k = 1
    if m and ins.operands:
        lhs_type = comp.shapes.get(ins.operands[0], "")
        lhs_dims = shape_dims(lhs_type) or []
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_n * k


_SKIP_HBM = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota",
             # loop carries are buffer-aliased in place; body traffic is
             # counted inside the body (× trips) already
             "while", "conditional", "call"}


_PASSTHROUGH = ("convert", "bitcast", "copy", "reshape")


def _sliced_param_sizes(comp: Computation) -> Dict[int, float]:
    """For a fusion body: parameter indices that are only consumed (possibly
    through convert/bitcast/copy chains) via dynamic-slice /
    dynamic-update-slice, mapped to the bytes actually moved. A scanned
    layer-stack buffer fused with its DUS/DS must be charged at slice size,
    not buffer size (DUS aliases in place)."""
    pidx: Dict[str, int] = {}
    for ins in comp.instrs:
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", "parameter(" + ins.rest)
            if m:
                pidx[ins.name] = int(m.group(1))
    users: Dict[str, List[Instr]] = {}
    for ins in comp.instrs:
        for o in ins.operands:
            users.setdefault(o, []).append(ins)

    def moved_bytes(name: str, depth: int = 0) -> float:
        """Bytes moved for all (transitive) uses of `name`; inf = full."""
        if depth > 8:
            return float("inf")
        total = 0.0
        for u in users.get(name, []):
            if u.op == "dynamic-slice" and u.operands[0] == name:
                total += shape_bytes(u.out_type)
            elif u.op == "dynamic-update-slice" and u.operands[0] == name:
                total += (shape_bytes(comp.shapes.get(u.operands[1], ""))
                          if len(u.operands) > 1 else float("inf"))
            elif u.op in _PASSTHROUGH:
                total += moved_bytes(u.name, depth + 1)
            else:
                return float("inf")
        return total

    out: Dict[int, float] = {}
    for pname, idx in pidx.items():
        mv = moved_bytes(pname)
        if mv != float("inf"):
            out[idx] = mv
    return out


def _unwrap_root(comp: Computation) -> Optional[Instr]:
    """Follow the root through convert/bitcast/copy to the real producer."""
    if not comp.instrs:
        return None
    by_name = {i.name: i for i in comp.instrs}
    root = comp.instrs[-1]
    for _ in range(8):
        if root.op in _PASSTHROUGH and root.operands:
            nxt = by_name.get(root.operands[0])
            if nxt is None:
                return root
            root = nxt
        else:
            break
    return root


def _instr_hbm_bytes(ins: Instr, comp: Computation,
                     comps: Dict[str, "Computation"]) -> float:
    """HBM traffic of one top-level instruction.

    Slice-family ops move only the slice, not the buffer they index into
    (charging the full operand would bill a scanned layer stack once per
    trip); dynamic-update-slice moves the update twice (read-modify-write).
    """
    out_b = shape_bytes(ins.out_type)
    if ins.op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b
    if ins.op == "dynamic-update-slice":
        upd = (shape_bytes(comp.shapes.get(ins.operands[1], ""))
               if len(ins.operands) > 1 else out_b)
        return 2.0 * upd
    sliced: Dict[int, float] = {}
    if ins.op == "fusion":
        tgt = _attr(ins.rest, "calls")
        if tgt and tgt in comps:
            sliced = _sliced_param_sizes(comps[tgt])
            # if the fusion root is (modulo converts) a DUS of a sliced
            # param, its output aliases the buffer: charge the update size
            root = _unwrap_root(comps[tgt])
            if root is not None and root.op == "dynamic-update-slice":
                upd = (shape_bytes(
                    comps[tgt].shapes.get(root.operands[1], ""))
                    if len(root.operands) > 1 else out_b)
                out_b = min(out_b, upd)
    b = out_b
    for i, o in enumerate(ins.operands):
        if i in sliced:
            b += sliced[i]
        else:
            b += shape_bytes(comp.shapes.get(o, ""))
    return b


def analyze(text: str, top_n: int = 0) -> dict:
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: the largest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs))

    memo_flops: Dict[str, float] = {}
    memo_inner_dots: Dict[str, float] = {}

    def fusion_flops(cname: str) -> float:
        """dot flops inside a fusion body (recursively)."""
        if cname in memo_inner_dots:
            return memo_inner_dots[cname]
        comp = comps.get(cname)
        if comp is None:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            if ins.op == "dot":
                total += _dot_flops(ins, comp)
            for key in ("calls", "to_apply"):
                tgt = _attr(ins.rest, key)
                if tgt and tgt in comps:
                    total += fusion_flops(tgt)
        memo_inner_dots[cname] = total
        return total

    result = {"flops": 0.0, "hbm_bytes": 0.0,
              "collectives": {c: {"count": 0.0, "bytes": 0.0}
                              for c in COLLECTIVES}}
    contributors: list = []
    coll_contributors: list = []

    seen_stack = set()

    def visit(cname: str, mult: float):
        comp = comps.get(cname)
        if comp is None or cname in seen_stack:
            return
        seen_stack.add(cname)
        for ins in comp.instrs:
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base_op in COLLECTIVES:
                b = shape_bytes(ins.out_type)
                result["collectives"][base_op]["count"] += mult
                result["collectives"][base_op]["bytes"] += mult * b
                if top_n:
                    coll_contributors.append(
                        (mult * b, cname, base_op, ins.name,
                         ins.out_type[:70], mult))
            if ins.op == "dot":
                result["flops"] += mult * _dot_flops(ins, comp)
            if ins.op == "fusion":
                tgt = _attr(ins.rest, "calls")
                if tgt:
                    result["flops"] += mult * fusion_flops(tgt)
            if ins.op == "while":
                body = _attr(ins.rest, "body")
                cond = _attr(ins.rest, "condition")
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    visit(body, mult * trips)
                if cond in comps:
                    visit(cond, mult * trips)
            elif ins.op in ("call", "conditional", "async-start"):
                for key in ("to_apply", "calls", "true_computation",
                            "false_computation"):
                    tgt = _attr(ins.rest, key)
                    if tgt:
                        visit(tgt, mult)
            # HBM traffic at computation top level
            if ins.op in _SKIP_HBM or ins.op.endswith("-done"):
                continue
            b = _instr_hbm_bytes(ins, comp, comps)
            result["hbm_bytes"] += mult * b
            if top_n:
                contributors.append((mult * b, cname, ins.op, ins.name,
                                     ins.out_type[:60], mult))
        seen_stack.discard(cname)

    visit(entry, 1.0)
    result["collective_bytes_total"] = sum(
        v["bytes"] for v in result["collectives"].values())
    if top_n:
        contributors.sort(reverse=True)
        result["top_hbm"] = [
            dict(bytes=float(f"{b:.4g}"), comp=c, op=o, name=n,
                 type=t, mult=m)
            for b, c, o, n, t, m in contributors[:top_n]]
        coll_contributors.sort(reverse=True)
        result["top_coll"] = [
            dict(bytes=float(f"{b:.4g}"), comp=c, op=o, name=n,
                 type=t, mult=m)
            for b, c, o, n, t, m in coll_contributors[:top_n]]
    return result
