"""Production meshes + logical→physical sharding rules.

IMPORTANT: importing this module never touches jax device state; meshes are
built inside functions only (so smoke tests see 1 CPU device while
dryrun.py, which sets XLA_FLAGS first, sees 512).
"""
from __future__ import annotations

import numpy as np

import jax


def set_mesh(mesh):
    """Activate `mesh` as the ambient mesh for the following block.

    jax.set_mesh on current jax; on jax<0.5 (no set_mesh) the Mesh object
    itself is the context manager that installs the global mesh.
    """
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def to_shardings(mesh, tree):
    """PartitionSpec pytree → NamedSharding pytree.

    jax<0.5's jit rejects bare PartitionSpecs in in_shardings/out_shardings;
    NamedSharding works on every version. is_leaf guard: PartitionSpec is a
    tuple subclass, so tree.map would otherwise flatten into it.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(
        lambda x: NamedSharding(mesh, x) if isinstance(x, PartitionSpec) else x,
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    assert len(devs) >= n, (
        f"need {n} devices for mesh {shape}; have {len(devs)} — dryrun.py "
        f"must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        f"before any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for subprocess-based distribution tests."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


# --------------------------------------------------------------------------
# Logical axis rules (DESIGN.md §6)
# --------------------------------------------------------------------------

BASE_RULES = {
    # parameters: FSDP over "data" on the embed dim, TP over "model"
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "head": None,
    "kv_heads": None,
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "shead": "model",     # sLSTM (head × block) sub-heads
    # activations
    "batch": "data",
    "act_embed": None,
    "kv_seq": "model",
}


def build_rules(arch_overrides: dict | None = None, *, multi_pod: bool = False,
                batch_size: int | None = None, dp_degree: int = 16) -> dict:
    """Resolve the rule set for one (arch × shape × mesh) cell.

    - multi-pod: batch additionally shards over the outer "pod" axis.
    - batch=1 cells (long_500k): batch unshardable → the KV seq dim takes
      ALL mesh axes instead (524288/512 = 1024 rows per chip).
    """
    rules = dict(BASE_RULES)
    if multi_pod:
        rules["batch"] = ("pod", "data")
    if arch_overrides:
        rules.update(arch_overrides)
    if batch_size is not None:
        dp = dp_degree * (2 if multi_pod else 1)
        if batch_size < dp:
            rules["batch"] = None
            rules["kv_seq"] = (("pod", "data", "model") if multi_pod
                               else ("data", "model"))
    return rules
