import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-cell roofline profile: top HBM and collective contributors from the
trip-count-aware HLO analysis (the §Perf iteration tool).

    PYTHONPATH=src python -m repro.launch.profile_cell --arch xlstm-350m \
        --shape train_4k [--mesh single]
"""
import argparse          # noqa: E402

import jax               # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_rule_overrides  # noqa: E402
from repro.launch import specs as S                                 # noqa: E402
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS          # noqa: E402
from repro.launch.hlo_analysis import analyze                       # noqa: E402
from repro.launch.mesh import (build_rules, make_production_mesh,  # noqa: E402
                               set_mesh, to_shardings)
from repro.models.config import SHAPES                              # noqa: E402
from repro.models.layers import set_logical_rules                   # noqa: E402


def profile(arch: str, shape: str, multi_pod: bool = False, top_n: int = 12):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    rules = build_rules(dict(get_rule_overrides(arch)), multi_pod=multi_pod,
                        batch_size=cell.global_batch)
    if cell.kind == "decode":
        rules = S.serve_rules(cfg, rules)
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_logical_rules(rules)
    if cell.kind == "train":
        fn, args, insh, outsh = S.train_cell_specs(cfg, cell, rules, multi_pod)
        donate = (0, 1)
    elif cell.kind == "prefill":
        fn, args, insh, outsh = S.prefill_cell_specs(cfg, cell, rules)
        donate = ()
    else:
        fn, args, insh, outsh = S.decode_cell_specs(cfg, cell, rules)
        donate = (2,)
    with set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=to_shardings(mesh, insh),
                           out_shardings=to_shardings(mesh, outsh),
                           donate_argnums=donate).lower(*args).compile()
        mem = compiled.memory_analysis()
    r = analyze(compiled.as_text(), top_n=top_n)
    print(f"== {arch} {shape} {'multi' if multi_pod else 'single'}")
    print(f"terms: compute {r['flops']/PEAK_FLOPS:.3f}s  "
          f"memory {r['hbm_bytes']/HBM_BW:.3f}s  "
          f"collective {r['collective_bytes_total']/ICI_BW:.3f}s")
    print(f"peak mem: args {mem.argument_size_in_bytes/2**30:.2f} + temp "
          f"{mem.temp_size_in_bytes/2**30:.2f} GiB")
    print("-- top HBM contributors:")
    for c in r["top_hbm"]:
        print(f"  {c['bytes']:.3g}B x{c['mult']:.0f} {c['op'][:14]:14s} "
              f"{c['comp'][:34]:34s} {c['type']}")
    print("-- top collective contributors:")
    for c in r.get("top_coll", []):
        print(f"  {c['bytes']:.3g}B x{c['mult']:.0f} {c['op'][:14]:14s} "
              f"{c['comp'][:34]:34s} {c['type']}")
    return r, mem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()
    profile(args.arch, args.shape, args.mesh == "multi", args.top)


if __name__ == "__main__":
    main()
