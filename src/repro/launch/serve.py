"""Serving launcher: batched prefill + greedy decode, reporting tokens/s.

    python -m repro.launch.serve --arch granite-3-2b --batch 4 --new 32
(CPU container → smoke config; on TPU pods the full config + production mesh.)
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import for_model
from repro.models import transformer as T
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke_config()
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    pipe = for_model(cfg, seq_len=args.prompt_len, global_batch=args.batch)
    inputs = {k: v for k, v in pipe.batch_at(0).items() if k != "labels"}

    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.new
                         + cfg.n_prefix_embeds)
    t0 = time.time()
    out = engine.generate(inputs, n_new=args.new)
    dt = time.time() - t0
    toks = args.batch * args.new
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, incl. compile)")
    print("sample:", np.asarray(out[0][:16]))


if __name__ == "__main__":
    main()
