"""input_specs(): ShapeDtypeStruct stand-ins + PartitionSpecs for every
(arch × shape) cell — weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeCell
from repro.train import optimizer as opt


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, B: int, S: int, rules: dict
                ) -> Tuple[dict, dict]:
    """(abstract batch, PartitionSpecs) for a training/prefill batch."""
    b = rules.get("batch")
    if cfg.frontend == "audio":
        ab = {"frames": _sds((B, S, cfg.d_model), jnp.float32),
              "labels": _sds((B, S), jnp.int32)}
        sp = {"frames": P(b, None, None), "labels": P(b, None)}
    elif cfg.frontend == "vision":
        St = S - cfg.n_prefix_embeds
        ab = {"tokens": _sds((B, St), jnp.int32),
              "patches": _sds((B, cfg.n_prefix_embeds, cfg.d_model),
                              jnp.float32),
              "labels": _sds((B, St), jnp.int32)}
        sp = {"tokens": P(b, None), "patches": P(b, None, None),
              "labels": P(b, None)}
    else:
        ab = {"tokens": _sds((B, S), jnp.int32),
              "labels": _sds((B, S), jnp.int32)}
        sp = {"tokens": P(b, None), "labels": P(b, None)}
    return ab, sp


def train_accum(cfg: ModelConfig, local_batch: int) -> int:
    """Grad-accum microbatching: target micro-local-batch 2 (1 for wide
    models, whose activations/recurrent states dominate) to bound
    activation memory (DESIGN.md §6)."""
    target = 1 if cfg.d_model >= 4096 else 2
    return max(1, local_batch // target)


def train_cell_specs(cfg: ModelConfig, cell: ShapeCell, rules: dict,
                     multi_pod: bool):
    """Returns (fn, abstract_args, in_shardings, out_shardings) to lower."""
    from repro.train.train_loop import make_train_step

    dp = 16 * (2 if multi_pod else 1)
    accum = train_accum(cfg, cell.global_batch // dp)
    lr_fn = opt.warmup_cosine(3e-4, warmup=100, total=10_000)
    step_fn = make_train_step(cfg, lr_fn, accum=accum)

    params_abs = T.abstract_params(cfg)
    pspec = T.param_pspecs(cfg, rules)
    opt_abs = opt.AdamWState(
        _sds((), jnp.int32),
        jax.tree.map(lambda s: s, params_abs),
        jax.tree.map(lambda s: s, params_abs))
    ospec = opt.AdamWState(P(), jax.tree.map(lambda s: s, pspec),
                           jax.tree.map(lambda s: s, pspec))
    batch_abs, bspec = batch_specs(cfg, cell.global_batch, cell.seq_len, rules)
    metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
    return (step_fn, (params_abs, opt_abs, batch_abs),
            (pspec, ospec, bspec), (pspec, ospec, metrics_spec))


def _serve_params_abs(cfg: ModelConfig):
    """Serving uses bf16 weights (standard practice; halves weight memory
    vs the fp32 training master copies)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                        T.abstract_params(cfg))


def param_count(cfg: ModelConfig) -> int:
    from repro.models.params import ParamDef
    flat, _ = jax.tree_util.tree_flatten(
        T.model_defs(cfg), is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(int(np.prod(d.shape)) for d in flat)


def serve_rules(cfg: ModelConfig, rules: dict, tp_degree: int = 16) -> dict:
    """Serving sharding policy (§Perf H2): FSDP'ing weights over "data"
    makes every decode step re-all-gather the full parameter set (measured:
    10.7 GiB/step for jamba long_500k — 100% of its roofline bound).
    When bf16 weights fit per-device under TP alone, replicate over "data"
    instead; keep FSDP only for models where they don't (mistral-123b)."""
    bf16_per_dev = param_count(cfg) * 2 / tp_degree
    if bf16_per_dev < 8e9:
        rules = dict(rules)
        rules["embed"] = None
    return rules


def prefill_cell_specs(cfg: ModelConfig, cell: ShapeCell, rules: dict):
    params_abs = _serve_params_abs(cfg)
    pspec = T.param_pspecs(cfg, rules)
    batch_abs, bspec = batch_specs(cfg, cell.global_batch, cell.seq_len, rules)
    batch_abs.pop("labels")
    bspec.pop("labels")
    b = rules.get("batch")

    if not cfg.has_decode:
        def encode_step(params, inputs):
            x, _ = T.forward(params, inputs, cfg)
            return T.logits_from_hidden(params, x, cfg)
        out_spec = P(b, None, rules.get("vocab"))
        return encode_step, (params_abs, batch_abs), (pspec, bspec), out_spec

    def prefill_step(params, inputs):
        return T.prefill(params, inputs, cfg, max_seq=cell.seq_len)

    cspec = T.cache_pspecs(cfg, cell.global_batch, cell.seq_len, rules)
    out_spec = (P(b, None, rules.get("vocab")), cspec)
    return prefill_step, (params_abs, batch_abs), (pspec, bspec), out_spec


def decode_cell_specs(cfg: ModelConfig, cell: ShapeCell, rules: dict):
    from repro.serve.engine import make_serve_step

    params_abs = _serve_params_abs(cfg)
    pspec = T.param_pspecs(cfg, rules)
    B = cell.global_batch
    cache_abs = T.cache_defs(cfg, B, cell.seq_len)
    cspec = T.cache_pspecs(cfg, B, cell.seq_len, rules)
    b = rules.get("batch")
    tok_abs = _sds((B, 1), jnp.int32)
    idx_abs = _sds((), jnp.int32)
    step = make_serve_step(cfg)
    return (step, (params_abs, tok_abs, cache_abs, idx_abs),
            (pspec, P(b, None), cspec, P()),
            (P(b, None), cspec))


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS per step: 6·N·D train (2·N·D fwd-only), N = active params."""
    n_total = 0
    n_expert = 0
    from repro.models.params import ParamDef
    flat, _ = jax.tree_util.tree_flatten_with_path(
        T.model_defs(cfg), is_leaf=lambda x: isinstance(x, ParamDef))
    for path, d in flat:
        n = int(np.prod(d.shape))
        n_total += n
        if "expert" in d.axes:
            tag = jax.tree_util.keystr(path)
            if "router" not in tag:
                n_expert += n
    active = n_total - n_expert
    if cfg.n_experts:
        active += n_expert * cfg.experts_per_token / cfg.n_experts
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * cell.global_batch
