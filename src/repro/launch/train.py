"""Production training launcher.

    # real cluster (TPU pods): full config on the production mesh
    python -m repro.launch.train --arch granite-3-2b --mesh single

    # this container (1 CPU device): reduced config, same code path
    python -m repro.launch.train --arch granite-3-2b --mesh cpu --steps 50

Flags demonstrate the distributed-optimization features:
  --accum N           gradient-accumulation microbatching (compute/comm overlap)
  --no-fsdp           disable ZeRO-style param sharding over "data"
(int8 error-feedback gradient reduction lives in train/grad_compress.py,
validated in tests/test_grad_compress.py for the cross-pod reduce.)
"""
from __future__ import annotations

import argparse
import os


from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config, get_rule_overrides
from repro.data.pipeline import for_model
from repro.launch.mesh import build_rules, make_production_mesh, set_mesh
from repro.models.layers import set_logical_rules
from repro.train.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=list(ARCH_IDS))
    ap.add_argument("--mesh", default="cpu", choices=["cpu", "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-fsdp", action="store_true")
    args = ap.parse_args()

    if args.mesh == "cpu":
        cfg = get_config(args.arch).smoke_config()
        seq = 64 if args.seq is None else args.seq
        batch = 8 if args.batch is None else args.batch
        ctx = None
    else:
        cfg = get_config(args.arch)
        seq = 4096 if args.seq is None else args.seq
        batch = 256 if args.batch is None else args.batch
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rules = build_rules(get_rule_overrides(args.arch),
                            multi_pod=(args.mesh == "multi"),
                            batch_size=batch)
        if args.no_fsdp:
            rules["embed"] = None
        set_logical_rules(rules)
        ctx = set_mesh(mesh)

    # XLA flags a real run would set for collective/compute overlap
    os.environ.setdefault(
        "LIBTPU_INIT_ARGS",
        "--xla_tpu_enable_async_collective_fusion=true "
        "--xla_tpu_enable_latency_hiding_scheduler=true")

    pipe = for_model(cfg, seq_len=seq, global_batch=batch, mode="markov")
    mgr = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name))
    if ctx is not None:
        with ctx:
            train(cfg, pipe, steps=args.steps, lr=args.lr, accum=args.accum,
                  ckpt_manager=mgr, ckpt_every=args.ckpt_every)
    else:
        train(cfg, pipe, steps=args.steps, lr=args.lr, accum=args.accum,
              ckpt_manager=mgr, ckpt_every=args.ckpt_every)


if __name__ == "__main__":
    main()
