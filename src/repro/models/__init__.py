from repro.models.config import ModelConfig, SHAPES, ShapeCell, cell_applicable  # noqa: F401
from repro.models import transformer  # noqa: F401
