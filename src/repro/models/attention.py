"""GQA attention: blockwise (memory-bounded) prefill/train, cached decode.

Sharding strategy (DESIGN.md §6):
- train/prefill: K/V expanded to full query heads, heads sharded over
  "model"; scores never materialize beyond (Bq_chunk × Bkv_chunk) tiles
  (pure-JAX online-softmax blockwise attention — the portable equivalent of
  a flash kernel; XLA fuses the inner loop well on TPU).
- decode: KV cache kept in grouped (g kv heads) form, cache SEQUENCE dim
  sharded over "model" ("kv_seq" logical axis). Plain jnp softmax over the
  sharded seq dim lowers, under GSPMD, to local partial attention + tiny
  all-reduces of the max / denominator / weighted values — the distributed
  online-softmax merge, without hand-written collectives.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import rope, shard
from repro.models.params import ParamDef

NEG_INF = -1e30


def attn_def(cfg) -> dict:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head")),
        "wk": ParamDef((d, g, hd), ("embed", "kv_heads", "head")),
        "wv": ParamDef((d, g, hd), ("embed", "kv_heads", "head")),
        "wo": ParamDef((h, hd, d), ("heads", "head", "embed")),
    }


def _expand_kv(k, h: int):
    """(B, S, g, hd) → (B, S, h, hd) by repeating each kv head h/g times."""
    g = k.shape[2]
    return jnp.repeat(k, h // g, axis=2)


def _mask(qpos, kpos, mode: str, n_prefix: int = 0):
    """qpos (Sq,), kpos (Sk,) → bool (Sq, Sk) True = attend."""
    if mode == "full":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    causal = kpos[None, :] <= qpos[:, None]
    if mode == "prefix":
        return causal | (kpos[None, :] < n_prefix)
    return causal


def blockwise_attention(q, k, v, mask_mode: str, n_prefix: int = 0,
                        q_chunk: int = 2048, kv_chunk: int = 2048):
    """Online-softmax blockwise attention.

    q (B, S, h, hd); k, v (B, S, h, hd) — already expanded. Returns (B,S,h,hd).
    """
    B, S, h, hd = q.shape
    scale = hd ** -0.5
    if S <= q_chunk:  # single tile: plain fused attention
        qpos = jnp.arange(S)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        m = _mask(qpos, qpos, mask_mode, n_prefix)
        logits = jnp.where(m[None, None], logits.astype(jnp.float32), NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", w, v)

    nq = S // q_chunk
    nk = S // kv_chunk
    qc = q.reshape(B, nq, q_chunk, h, hd)
    kc = k.reshape(B, nk, kv_chunk, h, hd)
    vc = v.reshape(B, nk, kv_chunk, h, hd)

    def per_q_chunk(qi, qblk):
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj):
            m_run, l_run, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kc, kj, axis=1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vc, kj, axis=1, keepdims=False)
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            logits = (jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk)
                      * scale).astype(jnp.float32)
            msk = _mask(qpos, kpos, mask_mode, n_prefix)
            logits = jnp.where(msk[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = (acc * corr[..., None]
                   + jnp.einsum("bhqk,bkhd->bhqd", p.astype(qblk.dtype),
                                vblk).astype(jnp.float32))
            return (m_new, l_new, acc), None

        init = (jnp.full((B, h, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, h, q_chunk), jnp.float32),
                jnp.zeros((B, h, q_chunk, hd), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)     # (B, qc, h, hd)

    outs = jax.lax.map(lambda i: per_q_chunk(i, qc[:, i]), jnp.arange(nq))
    # (nq, B, q_chunk, h, hd) → (B, S, h, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, h, hd)


class KVCache(NamedTuple):
    k: jax.Array     # (B, Smax, g, hd)
    v: jax.Array


def attention_block(p, x, positions, cfg, mask_mode: str = "causal",
                    cache: Optional[KVCache] = None,
                    cache_index: Optional[jax.Array] = None):
    """Full attention sub-block (projections + attention + out-proj).

    Prefill/train: cache is None → returns (out, KVCache of this segment).
    Decode: cache given, x is (B, 1, d), cache_index = current position.
    """
    dt = x.dtype
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"].astype(dt))
    q = rope(q, positions, cfg.rope_theta) if mask_mode != "full" else q
    k = rope(k, positions, cfg.rope_theta) if mask_mode != "full" else k

    if cache is None:
        q = shard(q, "batch", None, "heads", None)
        kf = shard(_expand_kv(k, h), "batch", None, "heads", None)
        vf = shard(_expand_kv(v, h), "batch", None, "heads", None)
        out = blockwise_attention(q, kf, vf, mask_mode, cfg.n_prefix_embeds)
        new_cache = KVCache(shard(k, "batch", "kv_seq", "kv_heads", None),
                            shard(v, "batch", "kv_seq", "kv_heads", None))
    else:
        # decode: q (B, 1, h, hd); cache (B, Smax, g, hd), seq-sharded.
        # The write uses a one-hot select rather than dynamic_update_slice:
        # GSPMD cannot partition a runtime-index DUS on a SHARDED dim (it
        # falls back to full replication + f32 round-trips — observed as
        # 2× full-cache f32 copies per layer); the select is elementwise
        # over the sharded seq dim and stays fully local.
        span0 = jnp.arange(cache.k.shape[1])
        hit = (span0 == cache_index)[None, :, None, None]
        kc = jnp.where(hit, k.astype(cache.k.dtype), cache.k)
        vc = jnp.where(hit, v.astype(cache.v.dtype), cache.v)
        kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
        vc = shard(vc, "batch", "kv_seq", "kv_heads", None)
        m = h // g
        qg = q.reshape(q.shape[0], 1, g, m, hd)
        logits = (jnp.einsum("bqgmk,bsgk->bgmqs", qg, kc.astype(dt))
                  * hd ** -0.5).astype(jnp.float32)
        span = jnp.arange(kc.shape[1])
        valid = span[None, :] <= cache_index                      # (1, Smax)
        logits = jnp.where(valid[None, None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgmqs,bsgk->bqgmk", w.astype(dt), vc.astype(dt))
        out = out.reshape(q.shape[0], 1, h, hd)
        new_cache = KVCache(kc, vc)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return shard(y, "batch", None, "act_embed"), new_cache


def init_cache_def(cfg, batch: int, max_seq: int):
    """ShapeDtypeStruct for one attention layer's KV cache."""
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.hd)
    cdt = jnp.dtype(cfg.cache_dtype)
    return KVCache(jax.ShapeDtypeStruct(shape, cdt),
                   jax.ShapeDtypeStruct(shape, cdt))
