"""Model configuration for the assigned architecture pool.

A model is a stack of GROUPS; each group is `block_pattern` applied once
(`n_layers == n_groups * len(block_pattern)`). Uniform transformers have
pattern ("attn",); hybrids interleave block kinds. Parameters of each
pattern-position are stacked over the group axis and the stack is scanned
(O(1) HLO in depth → 88-layer models lower in seconds).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # None → d_model // n_heads
    # block structure (one group): entries "attn" | "mamba" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    # which pattern positions carry an MoE MLP instead of dense (by index)
    moe_positions: Tuple[int, ...] = ()
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # MLP flavor: "swiglu" | "geglu" | "squared_relu" | "gelu" | "none"
    mlp: str = "swiglu"
    # SSM / recurrent dims
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # attention details
    rope_theta: float = 10_000.0
    causal: bool = True              # False → encoder-only (bidirectional)
    # modality frontend (stub per spec): "" | "audio" | "vision"
    frontend: str = ""
    n_prefix_embeds: int = 0         # VLM: # of patch embeddings prepended
    logit_softcap: float = 0.0
    norm_eps: float = 1e-6
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # serving / distribution knobs
    remat: str = "block"             # "none" | "block"
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        # `is None` sentinel, NOT `or`: an explicit head_dim=0 is a config
        # error that must surface, never silently coalesce to the default
        if self.head_dim is None:
            return self.d_model // self.n_heads
        return self.head_dim

    @property
    def cache_dtype(self) -> str:
        """KV-cache / recurrent-state dtype follows the compute dtype."""
        return "bfloat16" if self.compute_dtype == "bfloat16" else "float32"

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, \
            (self.name, self.n_layers, self.block_pattern)
        return self.n_layers // len(self.block_pattern)

    @property
    def has_decode(self) -> bool:
        return self.causal

    @property
    def d_inner(self) -> int:        # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab_size + 255) // 256) * 256

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke_config(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        n_pat = len(self.block_pattern)
        return self.replace(
            name=self.name + "-smoke",
            n_layers=n_pat * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            moe_d_ff=32 if self.moe_d_ff else 0,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            vocab_size=256,
            n_prefix_embeds=min(self.n_prefix_embeds, 4),
            ssm_state_dim=4,
        )


# --------------------------------------------------------------------------
# Shape cells (assigned input shapes; LM shapes are seq_len × global_batch)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Spec'd skip rules (documented in DESIGN.md §Shape skips)."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""
