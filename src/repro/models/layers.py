"""Shared model layers: norms, MLPs, rotary embeddings, logical-axis sharding.

All functions are pure; parameters arrive as dict pytrees (built in
transformer.py from ParamDefs). Activation sharding is expressed through
`shard` (logical constraint helper) so the same code runs on 1 CPU device
and on the 512-chip production mesh.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamDef

# ---------------------------------------------------------------- sharding

_MESH_RULES: dict = {}     # set by launch/mesh.py (logical → physical axes)


def set_logical_rules(rules: dict):
    global _MESH_RULES
    _MESH_RULES = dict(rules)


def get_logical_rules() -> dict:
    return dict(_MESH_RULES)


def shard(x, *axes):
    """Apply a logical sharding constraint if a mesh is active."""
    if not _MESH_RULES:
        return x
    spec = P(*[_MESH_RULES.get(a, None) if a is not None else None
               for a in axes])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except ValueError:
        return x   # no mesh context (e.g. plain CPU tests)


# ------------------------------------------------------------------- norms

def rmsnorm_def(d: int) -> dict:
    return {"scale": ParamDef((d,), (None,), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


# -------------------------------------------------------------------- MLPs

def mlp_def(cfg, d_ff: int) -> dict:
    d = cfg.d_model
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wi": ParamDef((d, 2, d_ff), ("embed", None, "mlp")),
            "wo": ParamDef((d_ff, d), ("mlp", "embed")),
        }
    return {   # squared_relu / gelu: plain 2-matrix MLP
        "wi": ParamDef((d, d_ff), ("embed", "mlp")),
        "wo": ParamDef((d_ff, d), ("mlp", "embed")),
    }


def mlp(p, x, cfg):
    dt = x.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        h = jnp.einsum("...d,dgf->...gf", x, p["wi"].astype(dt))
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        if cfg.mlp == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
    h = shard(h, "batch", None, "mlp")
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# -------------------------------------------------------------------- RoPE

def rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, hd); positions: (..., seq) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# -------------------------------------------------------------- embeddings

def embed_def(cfg) -> dict:
    return {"table": ParamDef((cfg.vocab_padded, cfg.d_model),
                              ("vocab", "embed"), scale=1.0)}


def embed(p, tokens, cfg):
    out = jnp.take(p["table"], tokens, axis=0)
    return shard(out.astype(cfg.compute_dtype), "batch", None, "act_embed")


def unembed(p, x, cfg):
    """Final projection to (padded) vocab logits, sharded over vocab."""
    logits = jnp.einsum("...d,vd->...v", x,
                        p["table"].astype(x.dtype))
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return shard(logits, "batch", None, "vocab")


def head_def(cfg) -> dict:
    """Separate output head (used when not tying to the embedding)."""
    return {"w": ParamDef((cfg.d_model, cfg.vocab_padded),
                          ("embed", "vocab"))}


def softmax_xent(logits, labels, vocab_size: int):
    """Cross entropy over the (padded) vocab dim; padded ids never occur in
    labels. fp32 accumulation."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    gold = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    return lse - gold
