"""Mixture-of-Experts MLP — sort-based (permutation) dispatch with explicit
expert parallelism.

Two execution paths:

- `_moe_dense` (no mesh / tests): single-device sort-scatter-compute-combine.
- `_moe_shardmap` (mesh active): expert parallelism done EXPLICITLY with
  shard_map. Activations are replicated over the "model" axis (they're
  sharded over batch→data only), so each model shard already holds every
  local token: it routes, keeps only the slots belonging to its E/ep local
  experts, runs its expert GEMMs, and contributes a partial output — merged
  by ONE psum per MoE layer (the same collective cost as a Megatron TP MLP;
  no all-to-all, no token send buffers).

  Why not GSPMD-auto: the global argsort/scatter in the dense path makes the
  partitioner materialize all-gathered token buffers (measured: 41 GiB peak
  and a 289 s collective term for qwen3-moe train_4k — see EXPERIMENTS.md
  §Perf iteration 1). The shard_map version is the production path.

Dispatch: tokens' top-k expert slots are stable-sorted by expert id; each
expert processes a fixed capacity C = ceil(T*k/E * capacity_factor) slots
(overflow dropped, standard practice). Everything is static-shaped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import get_logical_rules, shard
from repro.models.params import ParamDef


def moe_def(cfg) -> dict:
    # expert dim carries the EP ("model") axis; the per-expert ff dim uses
    # its own logical name ("expert_mlp" → unsharded) since a mesh axis can
    # appear at most once per tensor. The router is replicated (d×E is tiny
    # and every shard needs the full routing decision).
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    return {
        "router": ParamDef((d, e), ("embed", None)),
        "wi": ParamDef((e, d, 2, f), ("expert", "embed", None, "expert_mlp")),
        "wo": ParamDef((e, f, d), ("expert", "expert_mlp", "embed")),
    }


def _route(router, xt, k):
    """Top-k routing with renormalized gates. xt: (T, d)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    gates, eidx = jax.lax.top_k(logits, k)
    return jax.nn.softmax(gates, axis=-1), eidx


def _expert_compute(p, xe, dt):
    """(E?, cap, d) → (E?, cap, d) through the gated expert MLP."""
    h = jnp.einsum("ecd,edgf->ecgf", xe, p["wi"].astype(dt))
    h = jax.nn.silu(h[:, :, 0, :]) * h[:, :, 1, :]
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))


def _dispatch_compute_combine(p, xt, gates, eidx, e_lo, E_local, cap, dt):
    """Sort slots by (local) expert, capacity-drop, compute, scatter-add.

    e_lo/E_local select this shard's expert range ([0, E) on 1 device).
    """
    T, d = xt.shape
    k = eidx.shape[1]
    flat_e = eidx.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    le = flat_e - e_lo
    mine = (le >= 0) & (le < E_local)
    le = jnp.where(mine, le, E_local)                  # trash bucket
    order = jnp.argsort(le, stable=True)
    se, sg, stok = le[order], flat_g[order], flat_t[order]
    counts = jnp.bincount(se, length=E_local + 1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k) - starts[se]
    keep = (pos_in_e < cap) & (se < E_local)
    slot = jnp.where(keep, se * cap + pos_in_e, E_local * cap)

    buf = jnp.zeros((E_local * cap + 1, d), dt).at[slot].set(
        xt[stok].astype(dt))
    ye = _expert_compute(p, buf[:E_local * cap].reshape(E_local, cap, d), dt)
    yflat = ye.reshape(E_local * cap, d)
    yslot = jnp.where(keep[:, None],
                      yflat[jnp.minimum(slot, E_local * cap - 1)], 0.0)
    return jnp.zeros((T, d), dt).at[stok].add(yslot * sg[:, None].astype(dt))


def _moe_dense(p, x, cfg):
    dt = x.dtype
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    cap = int((T * k * cfg.capacity_factor) // E + 1)
    xt = x.reshape(T, d)
    gates, eidx = _route(p["router"], xt, k)
    out = _dispatch_compute_combine(p, xt, gates, eidx, 0, E, cap, dt)
    return out.reshape(B, S, d)


def _moe_shardmap(p, x, cfg, mesh, rules):
    from jax.experimental.shard_map import shard_map

    dt = x.dtype
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    exp_ax = rules["expert"]
    ep = mesh.shape[exp_ax]
    assert E % ep == 0, (E, ep)
    E_local = E // ep
    batch_ax = rules.get("batch")

    in_specs = (
        {"router": P(), "wi": P(exp_ax), "wo": P(exp_ax)},
        P(batch_ax, None, None),
    )
    out_specs = P(batch_ax, None, None)

    def body(pp, xs):
        Bl, Sl, _ = xs.shape
        T = Bl * Sl
        cap = int((T * k * cfg.capacity_factor) // E + 1)
        xt = xs.reshape(T, d)
        gates, eidx = _route(pp["router"], xt, k)
        e_lo = jax.lax.axis_index(exp_ax) * E_local
        out = _dispatch_compute_combine(pp, xt, gates, eidx, e_lo, E_local,
                                        cap, dt)
        out = jax.lax.psum(out, exp_ax)
        return out.reshape(Bl, Sl, d)

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return fn(p, x)


def moe_mlp(p, x, cfg):
    """x: (B, S, d) → (B, S, d)."""
    rules = get_logical_rules()
    if rules.get("expert"):
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty and rules["expert"] in mesh.shape:
            out = _moe_shardmap(p, x, cfg, mesh, rules)
            return shard(out, "batch", None, "act_embed")
    return shard(_moe_dense(p, x, cfg), "batch", None, "act_embed")


def moe_aux_loss(p, x, cfg):
    """Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    T = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32)).reshape(T, -1)
    probs = jax.nn.softmax(logits, axis=-1)
    _, eidx = jax.lax.top_k(logits, cfg.experts_per_token)
    f = jnp.mean(jax.nn.one_hot(eidx, cfg.n_experts).sum(1), axis=0)
    pbar = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f * pbar)
