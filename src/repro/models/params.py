"""Minimal parameter-definition system (no flax): each leaf carries a shape,
logical axis names, and an init scale. Supports three materializations:

- `abstract(defs)`  → ShapeDtypeStruct pytree (dry-run lowering, no memory)
- `init(key, defs)` → real arrays, per-leaf deterministic keys
- `pspecs(defs, rules)` → jax.sharding.PartitionSpec pytree
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim (None = replicated)
    init: str = "normal"                 # "normal" | "zeros" | "ones" | "ssm_a"
    scale: float = 1.0                   # stddev multiplier (normal), fan-in applied

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def abstract(defs, dtype=jnp.float32):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
        is_leaf=_is_def)


def init(key, defs, dtype=jnp.float32):
    """Deterministic per-leaf init: key folded with the leaf path hash."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(defs, is_leaf=_is_def)
    leaves = []
    for path, d in flat:
        tag = jax.tree_util.keystr(path)
        h = int.from_bytes(hashlib.md5(tag.encode()).digest()[:4], "little")
        k = jax.random.fold_in(key, h)
        if d.init == "zeros":
            leaves.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            leaves.append(jnp.ones(d.shape, dtype))
        elif d.init == "ssm_a":
            # mamba A init: -log-spaced over state dim (last axis)
            n = d.shape[-1]
            a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=dtype), d.shape)
            leaves.append(jnp.log(a))    # stored as log(-A) ; A = -exp(.)
        else:
            fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[0], 1)
            if len(d.shape) >= 3:        # stacked (group) leading dim
                fan_in = d.shape[1]
            std = d.scale / np.sqrt(max(fan_in, 1))
            leaves.append(jax.random.normal(k, d.shape, dtype) * std)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def pspecs(defs, rules: dict):
    def spec(d: ParamDef):
        return P(*[rules.get(a, None) if a is not None else None
                   for a in d.axes])
    return jax.tree.map(spec, defs, is_leaf=_is_def)


def logical_shapes(defs):
    return jax.tree.map(lambda d: d.shape, defs, is_leaf=_is_def)
