"""Recurrent mixers: Mamba (S6 selective SSM), mLSTM and sLSTM (xLSTM).

All three expose the same interface as attention_block:
    out, new_state = <block>(params, x, cfg, state=None)
state=None → sequence mode (train/prefill), scanning over time with a
carried recurrent state; returns the final state for decode handoff.
state given + S==1 → single decode step.

Sharding: mamba's inner width carries the "mlp" logical axis; mLSTM's value
dim carries "head" (xLSTM's 4 heads don't divide a 16-way model axis, so the
256-wide value dim is the sharded one — see configs/xlstm_350m.py rules).
sLSTM is tiny and replicated (batch-sharded only).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import shard
from repro.models.params import ParamDef

TIME_CHUNK = 64


def chunked_scan(step, init, xs, length: int):
    """Two-level time scan with per-chunk gradient checkpointing.

    A flat S-step scan inside a remat'd block makes AD save O(S) per-step
    residuals (measured: 4.3 GB/layer for jamba's mamba at S=4096); chunking
    with jax.checkpoint saves the carry only every TIME_CHUNK steps and
    recomputes inside the chunk — O(S/64) memory for ~1.3x recompute.
    """
    if length <= TIME_CHUNK or length % TIME_CHUNK != 0:
        return jax.lax.scan(step, init, xs)
    nch = length // TIME_CHUNK

    def chunk_step(carry, xs_chunk):
        return jax.lax.scan(step, carry, xs_chunk)

    chunk_step = jax.checkpoint(chunk_step)
    xs_c = jax.tree.map(
        lambda a: a.reshape((nch, TIME_CHUNK) + a.shape[1:]), xs)
    carry, ys = jax.lax.scan(chunk_step, init, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((length,) + a.shape[2:]), ys)
    return carry, ys


# ------------------------------------------------------------------- Mamba

class MambaState(NamedTuple):
    conv: jax.Array   # (B, W-1, di) last conv inputs
    h: jax.Array      # (B, di, N) SSM state


def mamba_def(cfg) -> dict:
    d, di, N, W = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": ParamDef((d, 2, di), ("embed", None, "mlp")),
        "conv_w": ParamDef((W, di), (None, "mlp"), scale=1.0),
        "conv_b": ParamDef((di,), ("mlp",), init="zeros"),
        "x_proj": ParamDef((di, dt_rank + 2 * N), ("mlp", None)),
        "dt_w": ParamDef((dt_rank, di), (None, "mlp")),
        "dt_b": ParamDef((di,), ("mlp",), init="zeros"),
        "A_log": ParamDef((di, N), ("mlp", None), init="ssm_a"),
        "D": ParamDef((di,), ("mlp",), init="ones"),
        "out_proj": ParamDef((di, d), ("mlp", "embed")),
    }


def mamba_block(p, x, cfg, state: Optional[MambaState] = None):
    dt_ = x.dtype
    B, S, d = x.shape
    di, N, W = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    dt_rank = max(d // 16, 1)

    xz = jnp.einsum("bsd,dgi->bsgi", x, p["in_proj"].astype(dt_))
    xin, z = xz[:, :, 0, :], xz[:, :, 1, :]                     # (B, S, di)
    xin = shard(xin, "batch", None, "mlp")

    # causal depthwise conv over time
    if state is None:
        pad = jnp.zeros((B, W - 1, di), dt_)
    else:
        pad = state.conv.astype(dt_)
    xpad = jnp.concatenate([pad, xin], axis=1)                  # (B, S+W-1, di)
    conv = sum(xpad[:, i:i + S, :] * p["conv_w"][i].astype(dt_)
               for i in range(W))
    xin_c = jax.nn.silu(conv + p["conv_b"].astype(dt_))
    new_conv = xpad[:, S:, :]                                   # last W-1 inputs

    proj = jnp.einsum("bsi,ik->bsk", xin_c, p["x_proj"].astype(dt_))
    dt_raw = jnp.einsum("bsr,ri->bsi", proj[..., :dt_rank],
                        p["dt_w"].astype(dt_)) + p["dt_b"].astype(dt_)
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32))         # (B, S, di)
    Bm = proj[..., dt_rank:dt_rank + N].astype(jnp.float32)     # (B, S, N)
    Cm = proj[..., dt_rank + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (di, N)

    h0 = (jnp.zeros((B, di, N), jnp.float32) if state is None
          else state.h.astype(jnp.float32))

    def step(h, inp):
        xt, dt_t, bt, ct = inp                                  # (B,di),(B,di),(B,N),(B,N)
        decay = jnp.exp(dt_t[:, :, None] * A[None])             # (B, di, N)
        h = h * decay + (dt_t * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bin,bn->bi", h, ct)
        return h, y

    xs = (xin_c.transpose(1, 0, 2).astype(jnp.float32),
          delta.transpose(1, 0, 2), Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    hT, ys = chunked_scan(step, h0, xs, S)
    y = ys.transpose(1, 0, 2).astype(dt_)                        # (B, S, di)
    y = y + xin_c * p["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(dt_))
    cdt = jnp.dtype(cfg.cache_dtype)
    return shard(out, "batch", None, "act_embed"), MambaState(
        new_conv.astype(cdt), hT.astype(cdt))


def mamba_state_def(cfg, batch: int):
    cdt = jnp.dtype(cfg.cache_dtype)
    return MambaState(
        jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, cfg.d_inner),
                             cdt),
        jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.ssm_state_dim),
                             cdt))


# ------------------------------------------------------------------- mLSTM

class MLSTMState(NamedTuple):
    C: jax.Array      # (B, H, dv, dk) matrix memory
    n: jax.Array      # (B, H, dk) normalizer
    m: jax.Array      # (B, H) log-space stabilizer


def mlstm_def(cfg) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "wq": ParamDef((d, H, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, H, hd), ("embed", "heads", None)),
        "wv": ParamDef((d, H, hd), ("embed", "heads", "head")),
        "wi": ParamDef((d, H), ("embed", "heads")),
        "wf": ParamDef((d, H), ("embed", "heads")),
        "wog": ParamDef((d, H, hd), ("embed", "heads", "head")),
        "wo": ParamDef((H, hd, d), ("heads", "head", "embed")),
    }


MLSTM_CHUNK = 64


def _mlstm_sequential(q, k, v, ig, fg, C0, n0, m0, S):
    """Reference per-step recurrence (used for decode and as the oracle for
    the chunkwise form — tests/test_mlstm_chunkwise.py)."""

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        qt, kt, vt = (t.astype(jnp.float32) for t in (qt, kt, vt))
        logf = jax.nn.log_sigmoid(ft)                           # (B, H)
        m_new = jnp.maximum(logf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(logf + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * \
            jnp.einsum("bhv,bhk->bhvk", vt, kt)
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = tuple(t.transpose(1, 0, 2, 3) if t.ndim == 4 else t.transpose(1, 0, 2)
               for t in (q, k, v, ig, fg))
    (CT, nT, mT), hs = chunked_scan(step, (C0, n0, m0), xs, S)
    return hs.transpose(1, 0, 2, 3), (CT, nT, mT)


def _mlstm_chunkwise(q, k, v, ig, fg, C0, n0, m0, S, L: int = MLSTM_CHUNK):
    """Chunkwise-parallel mLSTM — EXACT log-space reformulation of the
    sequential recurrence (§Perf H1): intra-chunk terms become (L×L) MXU
    matmuls; the (dv×dk) matrix state is materialized once per chunk instead
    of once per step (64× less state traffic, the dominant HBM term of
    xlstm train_4k at baseline).

    Derivation: with A_t = Σ_{u≤t} log σ(f_u) (within chunk) and b_t = ĩ_t,
    the sequential stabilizer recursion m_t = max(logσ(f_t)+m_{t-1}, b_t)
    unrolls to m_t = max(m_prev + A_t, A_t + cummax_s≤t(b_s − A_s)), so all
    per-step quantities are cumsums/cummaxes — no sequential dependency.
    """
    B, _, H, hd = q.shape
    nch = S // L

    def to_chunks(t):
        if t.ndim == 4:   # (B,S,H,hd) → (nch, B, H, L, hd)
            return t.reshape(B, nch, L, H, hd).transpose(1, 0, 3, 2, 4)
        return t.reshape(B, nch, L, H).transpose(1, 0, 3, 2)    # (nch,B,H,L)

    qc, kc, vc = (to_chunks(t.astype(jnp.float32)) for t in (q, k, v))
    ac = to_chunks(jax.nn.log_sigmoid(fg))
    bc = to_chunks(ig)
    tril = jnp.tril(jnp.ones((L, L), bool))

    def chunk(carry, inp):
        Cp, np_, mp = carry                                     # prev state
        qb, kb, vb, a, b = inp                                  # (B,H,L,*)
        A = jnp.cumsum(a, axis=-1)                              # (B,H,L)
        m = jnp.maximum(mp[..., None] + A,
                        A + jax.lax.cummax(b - A, axis=b.ndim - 1))  # (B,H,L)
        E = A + mp[..., None] - m                               # ≤ 0
        D = (A[..., :, None] - A[..., None, :]
             + b[..., None, :] - m[..., :, None])               # (B,H,L,L)
        W = jnp.where(tril, jnp.exp(D), 0.0)
        qk = jnp.einsum("bhtk,bhsk->bhts", qb, kb)
        num = (jnp.einsum("bhts,bhsv->bhtv", W * qk, vb)
               + jnp.exp(E)[..., None]
               * jnp.einsum("bhvk,bhtk->bhtv", Cp, qb))
        nvec = (jnp.einsum("bhts,bhsk->bhtk", W, kb)
                + jnp.exp(E)[..., None] * np_[..., None, :])
        den = jnp.maximum(jnp.abs(jnp.einsum("bhtk,bhtk->bht", nvec, qb)),
                          jnp.exp(-m))
        h = num / den[..., None]                                # (B,H,L,dv)
        # chunk-end state
        mL = m[..., -1]
        AL = A[..., -1:]
        w_end = jnp.exp(AL - A + b - mL[..., None])             # (B,H,L)
        decay = jnp.exp(AL[..., 0] + mp - mL)                   # (B,H)
        Cn = (jnp.einsum("bhs,bhsv,bhsk->bhvk", w_end, vb, kb)
              + decay[..., None, None] * Cp)
        nn = (jnp.einsum("bhs,bhsk->bhk", w_end, kb)
              + decay[..., None] * np_)
        return (Cn, nn, mL), h

    (CT, nT, mT), hs = jax.lax.scan(chunk, (C0, n0, m0),
                                    (qc, kc, vc, ac, bc))
    # (nch, B, H, L, dv) → (B, S, H, dv)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return h, (CT, nT, mT)


def mlstm_block(p, x, cfg, state: Optional[MLSTMState] = None):
    dt_ = x.dtype
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt_)) * hd ** -0.5
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt_)) * hd ** -0.5
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt_))
    v = shard(v, "batch", None, "heads", "head")
    ig = jnp.einsum("bsd,dh->bsh", x, p["wi"].astype(dt_)).astype(jnp.float32)
    fg = jnp.einsum("bsd,dh->bsh", x, p["wf"].astype(dt_)).astype(jnp.float32)
    og = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["wog"].astype(dt_)))

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = (state.C.astype(jnp.float32),
                      state.n.astype(jnp.float32),
                      state.m.astype(jnp.float32))

    if S % MLSTM_CHUNK == 0 and S >= 2 * MLSTM_CHUNK:
        hs, (CT, nT, mT) = _mlstm_chunkwise(q, k, v, ig, fg, C0, n0, m0, S)
        h = hs.astype(dt_) * og
    else:
        hs, (CT, nT, mT) = _mlstm_sequential(q, k, v, ig, fg, C0, n0, m0, S)
        h = hs.astype(dt_) * og
    out = jnp.einsum("bshk,hkd->bsd", h, p["wo"].astype(dt_))
    cdt = jnp.dtype(cfg.cache_dtype)
    return shard(out, "batch", None, "act_embed"), MLSTMState(
        CT.astype(cdt), nT.astype(cdt), mT.astype(jnp.float32))


def mlstm_state_def(cfg, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    cdt = jnp.dtype(cfg.cache_dtype)
    return MLSTMState(jax.ShapeDtypeStruct((batch, H, hd, hd), cdt),
                      jax.ShapeDtypeStruct((batch, H, hd), cdt),
                      jax.ShapeDtypeStruct((batch, H), jnp.float32))


# ------------------------------------------------------------------- sLSTM

class SLSTMState(NamedTuple):
    c: jax.Array      # (B, H, du)
    n: jax.Array
    h: jax.Array
    m: jax.Array


SLSTM_BLOCKS = 4     # block-diagonal recurrence, 4 blocks/head (xLSTM paper)


def _slstm_dims(cfg):
    """Effective (sub-)heads: H × SLSTM_BLOCKS independent recurrences.

    The block-diagonal R makes each (head, block) a self-contained scalar
    LSTM over bs units — and H·nb = 16 sub-heads shard exactly over the
    16-way model axis ("shead"), so the per-timestep recurrence is a LOCAL
    (bs × bs) matmul with zero collectives (§Perf H1b: the dense
    full-head R cost 1.24 TB/step of HBM + a per-step grad all-reduce).
    """
    H = cfg.n_heads
    du = cfg.d_model // H
    nb = SLSTM_BLOCKS if du % SLSTM_BLOCKS == 0 else 1
    return H * nb, du // nb


def slstm_def(cfg) -> dict:
    d = cfg.d_model
    He, bs = _slstm_dims(cfg)
    return {
        "wx": ParamDef((d, 4, He, bs), ("embed", None, "shead", None)),
        "r": ParamDef((4, He, bs, bs), (None, "shead", None, None), scale=0.5),
        "b": ParamDef((4, He, bs), (None, "shead", None), init="zeros"),
        "wo": ParamDef((He, bs, d), ("shead", None, "embed")),
    }


def slstm_block(p, x, cfg, state: Optional[SLSTMState] = None):
    dt_ = x.dtype
    B, S, d = x.shape
    He, bs = _slstm_dims(cfg)
    zx = jnp.einsum("bsd,dghu->bsghu", x, p["wx"].astype(dt_)
                    ).astype(jnp.float32)                       # (B,S,4,He,bs)
    zx = shard(zx, "batch", None, None, "shead", None)
    R = p["r"].astype(jnp.float32)
    bias = p["b"].astype(jnp.float32)

    if state is None:
        z0 = jnp.zeros((B, He, bs), jnp.float32)
        st0 = (z0, z0, z0, jnp.full((B, He, bs), -1e30, jnp.float32))
    else:
        st0 = tuple(s.astype(jnp.float32) for s in state)

    def step(carry, zt):
        c, n, h, m = carry
        rec = jnp.einsum("bhu,ghuv->bghv", h, R)                # (B,4,He,bs)
        pre = zt + rec + bias[None]
        it, ft, zt_, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * jnp.tanh(zt_)
        n = f_p * n + i_p
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    (cT, nT, hT, mT), hs = chunked_scan(
        step, st0, zx.transpose(1, 0, 2, 3, 4), S)
    hseq = hs.transpose(1, 0, 2, 3).astype(dt_)                 # (B, S, He, bs)
    out = jnp.einsum("bshu,hud->bsd", hseq, p["wo"].astype(dt_))
    return shard(out, "batch", None, "act_embed"), SLSTMState(
        cT.astype(jnp.float32), nT.astype(jnp.float32),
        hT.astype(jnp.float32), mT.astype(jnp.float32))


def slstm_state_def(cfg, batch: int):
    He, bs = _slstm_dims(cfg)
    s = jax.ShapeDtypeStruct((batch, He, bs), jnp.float32)
    return SLSTMState(s, s, s, s)
