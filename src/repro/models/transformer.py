"""Model assembly: pattern-grouped blocks, scanned over the group axis.

Params layout: {"embed": ..., "head": ..., "final_norm": ...,
                "groups": {pos{i}_{name}: leaf_stacked_over_groups}}
HLO size is O(len(block_pattern)), independent of depth — an 88-layer model
lowers as fast as a 2-layer one (see DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import params as prm
from repro.models.attention import (attention_block, attn_def, init_cache_def,
                                    KVCache)
from repro.models.config import ModelConfig
from repro.models.layers import (embed, embed_def, head_def, mlp, mlp_def,
                                 rmsnorm, rmsnorm_def, shard, softmax_xent)
from repro.models.moe import moe_def, moe_mlp
from repro.models.ssm import (mamba_block, mamba_def, mamba_state_def,
                              mlstm_block, mlstm_def, mlstm_state_def,
                              slstm_block, slstm_def, slstm_state_def)

MIXER_DEFS = {"attn": attn_def, "mamba": mamba_def,
              "mlstm": mlstm_def, "slstm": slstm_def}
STATE_DEFS = {"attn": init_cache_def, "mamba": lambda c, b: mamba_state_def(c, b),
              "mlstm": lambda c, b: mlstm_state_def(c, b),
              "slstm": lambda c, b: slstm_state_def(c, b)}


def _has_mlp(cfg: ModelConfig, pos: int) -> bool:
    return cfg.mlp != "none" and (cfg.d_ff > 0 or pos in cfg.moe_positions)


def group_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """Param defs for ONE group (one pass of block_pattern)."""
    defs: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.block_pattern):
        defs[f"pos{i}_norm1"] = rmsnorm_def(cfg.d_model)
        defs[f"pos{i}_{kind}"] = MIXER_DEFS[kind](cfg)
        if _has_mlp(cfg, i):
            defs[f"pos{i}_norm2"] = rmsnorm_def(cfg.d_model)
            if i in cfg.moe_positions:
                defs[f"pos{i}_moe"] = moe_def(cfg)
            else:
                defs[f"pos{i}_mlp"] = mlp_def(cfg, cfg.d_ff)
    return defs


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    g = group_defs(cfg)
    stacked = jax.tree.map(
        lambda d: prm.ParamDef((cfg.n_groups,) + d.shape, (None,) + d.axes,
                               d.init, d.scale),
        g, is_leaf=lambda x: isinstance(x, prm.ParamDef))
    defs = {"groups": stacked, "final_norm": rmsnorm_def(cfg.d_model)}
    if cfg.frontend != "audio":
        defs["embed"] = embed_def(cfg)
    defs["head"] = head_def(cfg)
    if cfg.frontend == "audio":
        defs["in_proj"] = {"w": prm.ParamDef(
            (cfg.d_model, cfg.d_model), ("embed", None))}
    return defs


def abstract_params(cfg: ModelConfig):
    return prm.abstract(model_defs(cfg), dtype=jnp.dtype(cfg.param_dtype))


def init_params(key, cfg: ModelConfig):
    return prm.init(key, model_defs(cfg), dtype=jnp.dtype(cfg.param_dtype))


def param_pspecs(cfg: ModelConfig, rules: dict):
    return prm.pspecs(model_defs(cfg), rules)


# ----------------------------------------------------------------- caches

def cache_defs(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode-state ShapeDtypeStructs, stacked over groups."""
    out = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            st = init_cache_def(cfg, batch, max_seq)
        else:
            st = STATE_DEFS[kind](cfg, batch)
        out[f"pos{i}_{kind}"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_groups,) + s.shape, s.dtype), st)
    return out


def cache_pspecs(cfg: ModelConfig, batch: int, max_seq: int, rules: dict):
    """PartitionSpecs for the decode cache (KV seq-sharded; states sharded
    on their wide dim)."""
    from jax.sharding import PartitionSpec as P

    def kv_spec(_):
        return P(None, rules.get("batch"), rules.get("kv_seq"), None, None)

    out = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            st = init_cache_def(cfg, batch, max_seq)
            out[f"pos{i}_{kind}"] = jax.tree.map(kv_spec, st)
        elif kind == "mamba":
            st = mamba_state_def(cfg, batch)
            out[f"pos{i}_{kind}"] = type(st)(
                P(None, rules.get("batch"), None, rules.get("mlp")),
                P(None, rules.get("batch"), rules.get("mlp"), None))
        elif kind == "mlstm":
            st = mlstm_state_def(cfg, batch)
            out[f"pos{i}_{kind}"] = type(st)(
                P(None, rules.get("batch"), rules.get("heads"), rules.get("head"), None),
                P(None, rules.get("batch"), rules.get("heads"), None),
                P(None, rules.get("batch"), rules.get("heads")))
        else:  # slstm — (head × block) sub-heads sharded over "shead"
            st = slstm_state_def(cfg, batch)
            out[f"pos{i}_{kind}"] = jax.tree.map(
                lambda s: P(None, rules.get("batch"), rules.get("shead"),
                            None), st)
    return out


# ---------------------------------------------------------------- forward

@jax.custom_jvp
def _barrier(tree):
    return jax.lax.optimization_barrier(tree)


@_barrier.defjvp
def _barrier_jvp(primals, tangents):
    # identity gradient: optimization_barrier has no differentiation rule on
    # jax<0.5, and the barrier is a pure scheduling hint
    (tree,), (dtree,) = primals, tangents
    return _barrier(tree), dtree


def _cast_big_params(groups, cfg: ModelConfig):
    """Cast large stacked weight tensors to the compute dtype BEFORE the
    group scan (§Perf H-cast): otherwise the per-iteration FSDP all-gather /
    HBM read moves fp32 master weights — measured 2× the necessary weight
    traffic on mistral-123b train and xlstm train. Small leaves (norm
    scales, gates, SSM A/conv) stay fp32 for precision."""
    dt = jnp.dtype(cfg.compute_dtype)
    if dt == jnp.float32:
        return groups
    out = jax.tree.map(
        lambda a: a.astype(dt)
        if (a.dtype == jnp.float32 and a.ndim >= 3 and a.size > 1_000_000)
        else a, groups)
    # Without the barrier XLA undoes the optimization: it keeps the fp32
    # buffer and rematerializes the (cheap) convert inside the scan body,
    # re-reading fp32 every iteration (measured: no traffic change).
    return _barrier(out)


def _apply_group(gp, x, positions, cfg, mask_mode, states, cache_index):
    """One pass of block_pattern. states: dict pos{i}_{kind} → state or None."""
    new_states = {}
    for i, kind in enumerate(cfg.block_pattern):
        h = rmsnorm(gp[f"pos{i}_norm1"], x, cfg.norm_eps)
        key = f"pos{i}_{kind}"
        st = states.get(key) if states else None
        if kind == "attn":
            mix, new_st = attention_block(gp[key], h, positions, cfg,
                                          mask_mode, st, cache_index)
        elif kind == "mamba":
            mix, new_st = mamba_block(gp[key], h, cfg, st)
        elif kind == "mlstm":
            mix, new_st = mlstm_block(gp[key], h, cfg, st)
        else:
            mix, new_st = slstm_block(gp[key], h, cfg, st)
        x = x + mix
        new_states[key] = new_st
        if _has_mlp(cfg, i):
            h2 = rmsnorm(gp[f"pos{i}_norm2"], x, cfg.norm_eps)
            if i in cfg.moe_positions:
                x = x + moe_mlp(gp[f"pos{i}_moe"], h2, cfg)
            else:
                x = x + mlp(gp[f"pos{i}_mlp"], h2, cfg)
        x = shard(x, "batch", None, "act_embed")
    return x, new_states


def _embed_inputs(params, inputs, cfg: ModelConfig):
    """Returns (x (B,S,d), mask_mode)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "audio":
        x = jnp.einsum("bsd,de->bse", inputs["frames"].astype(dt),
                       params["in_proj"]["w"].astype(dt))
        return shard(x, "batch", None, "act_embed"), "full"
    tok_emb = embed(params["embed"], inputs["tokens"], cfg)
    if cfg.frontend == "vision":
        x = jnp.concatenate([inputs["patches"].astype(dt), tok_emb], axis=1)
        return shard(x, "batch", None, "act_embed"), "prefix"
    return tok_emb, "causal" if cfg.causal else "full"


def forward(params, inputs, cfg: ModelConfig, caches=None, cache_index=None,
            collect_states: bool = False):
    """Sequence-mode forward. Returns (hidden (B,S,d), states-per-group)."""
    x, mask_mode = _embed_inputs(params, inputs, cfg)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(xc, gp):
        xc, states = _apply_group(gp, xc, positions, cfg, mask_mode,
                                  None, None)
        return xc, (states if collect_states else 0)

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, states = jax.lax.scan(body, x, _cast_big_params(params["groups"], cfg))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, states


def logits_from_hidden(params, x, cfg: ModelConfig):
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"].astype(x.dtype))
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return shard(logits, "batch", None, "vocab")


def loss_fn(params, batch, cfg: ModelConfig):
    """Next-token (causal) or frame-classification (encoder) loss."""
    x, _ = forward(params, batch, cfg)
    logits = logits_from_hidden(params, x, cfg)
    labels = batch["labels"]
    if cfg.frontend == "vision":                # loss over text positions only
        logits = logits[:, cfg.n_prefix_embeds:, :]
    losses = softmax_xent(logits, labels, cfg.vocab_size)
    return jnp.mean(losses)


# ------------------------------------------------------------------ serve

def prefill(params, inputs, cfg: ModelConfig, max_seq: int):
    """Run the full prompt; returns (last-token logits, decode caches).

    For attention layers the per-segment K/V (already computed by the
    forward) are placed into max_seq-sized cache buffers.
    """
    x, mask_mode = _embed_inputs(params, inputs, cfg)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(xc, gp):
        xc, states = _apply_group(gp, xc, positions, cfg, mask_mode,
                                  None, None)
        return xc, states

    x, states = jax.lax.scan(body, x, _cast_big_params(params["groups"], cfg))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, x[:, -1:, :], cfg)

    # expand attention segment-caches to max_seq buffers
    caches = {}
    for key, st in states.items():
        if isinstance(st, KVCache):
            pad = max_seq - st.k.shape[2]
            cdt = jnp.dtype(cfg.cache_dtype)
            caches[key] = KVCache(
                shard(jnp.pad(st.k.astype(cdt),
                              ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                      None, "batch", "kv_seq", "kv_heads", None),
                shard(jnp.pad(st.v.astype(cdt),
                              ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                      None, "batch", "kv_seq", "kv_heads", None))
        else:
            caches[key] = st
    return logits, caches


def decode_step(params, token, caches, index, cfg: ModelConfig):
    """One decode step. token (B, 1) int32; index: scalar int32 position.

    caches: dict pos{i}_{kind} → state stacked over groups (leading G).
    Returns (logits (B, 1, vocab), new caches).

    The cache stack travels as the scan CARRY (not xs/ys): while-loop
    carries are buffer-aliased in place by XLA, so with donated inputs the
    multi-GB KV cache is updated without a second copy. The per-group
    slice/update runs on the UNSHARDED group dim with the loop counter —
    the SPMD-safe pattern (the seq-dim write inside uses a one-hot select,
    see attention.py).
    """
    x = embed(params["embed"], token, cfg) if cfg.frontend != "audio" else None
    B = token.shape[0]
    positions = jnp.broadcast_to(index, (B, 1)).astype(jnp.int32)

    def body(carry, gp):
        xc, stack, gidx = carry
        st = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, gidx, 0, keepdims=False),
            stack)
        xc, new_st = _apply_group(gp, xc, positions, cfg, "causal", st, index)
        stack = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), gidx, 0),
            stack, new_st)
        return (xc, stack, gidx + 1), None

    (x, new_caches, _), _ = jax.lax.scan(
        body, (x, caches, jnp.asarray(0, jnp.int32)),
        _cast_big_params(params["groups"], cfg))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_from_hidden(params, x, cfg), new_caches
