from repro.quant import pq, int8, anisotropic  # noqa: F401
