"""Anisotropic (score-aware) quantization loss — ScaNN, Guo et al. [8].

The paper trains its VQ and PQ with the anisotropic loss: residual error
parallel to the datapoint costs more than orthogonal error, because parallel
error perturbs large inner products most. For weight w(t)=I(t>=T):

    loss(x, c) = h_par ||P_x (x-c)||^2 + h_perp ||(I - P_x)(x-c)||^2

with eta = h_par/h_perp = ((d-1) T^2) / (1 - T^2) (Theorem 3.3 of [8] shape).
The paper's own Appendix A.1 notes SOAR's Theorem 3.1 "is very similar to the
analysis behind Theorem 3.3 of [8]" — both are E over hypersphere queries.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import chunked_map


def eta_from_threshold(T: float, d: int) -> float:
    return float((d - 1) * T * T / max(1.0 - T * T, 1e-9))


@functools.partial(jax.jit, static_argnames=("chunk",))
def anisotropic_assign(X, C, eta: float, chunk: int = 8192):
    """argmin_j of the anisotropic loss.

    loss_ij = ||x-c||^2 + (eta-1) <x_hat, x-c>^2   (h_perp normalized to 1)
    Same two-GEMM structure as the SOAR loss with r_hat -> x_hat.
    """
    xn = jnp.maximum(jnp.linalg.norm(X, axis=-1, keepdims=True), 1e-12)
    xhat = X / xn
    Cn = jnp.sum(C * C, axis=-1)
    d = X.shape[-1]
    packed = jnp.concatenate([X, xhat], axis=-1)

    def f(blk):
        xb, hb = blk[:, :d], blk[:, d:]
        xc = xb @ C.T
        hc = hb @ C.T
        hx = jnp.sum(hb * xb, axis=-1)
        loss = Cn[None, :] - 2.0 * xc + (eta - 1.0) * (hx[:, None] - hc) ** 2
        return jnp.argmin(loss, axis=-1).astype(jnp.int32)

    return chunked_map(f, packed, chunk)


class AnisoStats(NamedTuple):
    A: jax.Array   # (c, d, d) accumulated weighting matrices
    b: jax.Array   # (c, d) accumulated rhs


@functools.partial(jax.jit, static_argnames=("c",))
def _accumulate(X, assign, eta: float, c: int) -> AnisoStats:
    xn2 = jnp.maximum(jnp.sum(X * X, axis=-1, keepdims=True), 1e-12)
    # W_i = I + (eta-1) x_hat x_hat^T ;  b_i = W_i x_i = x_i + (eta-1) x_i = eta x_i
    # (since x_hat x_hat^T x = x). Accumulate A_j = sum W_i, b_j = sum eta x_i.
    outer = jnp.einsum("ni,nj->nij", X, X) / xn2[:, :, None]
    W = jnp.eye(X.shape[-1])[None] + (eta - 1.0) * outer
    A = jax.ops.segment_sum(W, assign, num_segments=c)
    b = jax.ops.segment_sum(eta * X, assign, num_segments=c)
    return AnisoStats(A, b)


def anisotropic_kmeans(key, X, c: int, eta: float, iters: int = 10,
                       chunk: int = 8192, accum_chunk: int = 4096):
    """Anisotropic-loss VQ: score-aware assignment + exact per-centroid solve.

    Memory: c*d^2 for the normal matrices; intended for the benchmark scale
    (c<=4096, d<=128). For larger problems use Euclidean training +
    anisotropic assignment.
    """
    from repro.core.kmeans import train_kmeans  # init from Euclidean solution
    km = train_kmeans(key, X, c, iters=3, chunk=chunk)
    C = km.centroids
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    assign = None
    for _ in range(iters):
        assign = anisotropic_assign(X, C, eta, chunk=chunk)
        # accumulate normal equations in chunks (bounded by accum_chunk*d^2)
        A = jnp.zeros((c, X.shape[-1], X.shape[-1]))
        b = jnp.zeros((c, X.shape[-1]))
        for s in range(0, n, accum_chunk):
            st = _accumulate(X[s:s + accum_chunk], assign[s:s + accum_chunk], eta, c)
            A = A + st.A
            b = b + st.b
        counts = jax.ops.segment_sum(jnp.ones((n,)), assign, num_segments=c)
        reg = 1e-6 * jnp.eye(X.shape[-1])[None]
        C_new = jnp.linalg.solve(A + reg, b[..., None])[..., 0]
        C = jnp.where(counts[:, None] > 0, C_new, C)
    assign = anisotropic_assign(X, C, eta, chunk=chunk)
    return C, assign


def anisotropic_loss_values(X, C, assign, eta: float):
    """Per-point anisotropic loss (for tests)."""
    r = X - C[assign]
    xn = jnp.maximum(jnp.linalg.norm(X, axis=-1), 1e-12)
    rpar = jnp.sum(r * X, axis=-1) / xn
    return jnp.sum(r * r, axis=-1) + (eta - 1.0) * rpar ** 2
