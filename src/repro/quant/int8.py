"""Scalar int8 quantization for the highest-bitrate reranking representation."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Int8Data(NamedTuple):
    q: jax.Array       # (n, d) int8
    scale: jax.Array   # (n,) float32 per-row scale


@jax.jit
def int8_quantize(X) -> Int8Data:
    amax = jnp.maximum(jnp.max(jnp.abs(X), axis=-1), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(X / scale[:, None]), -127, 127).astype(jnp.int8)
    return Int8Data(q, scale.astype(jnp.float32))


@jax.jit
def int8_dequantize(data: Int8Data) -> jax.Array:
    return data.q.astype(jnp.float32) * data.scale[:, None]


@jax.jit
def int8_score(q, data: Int8Data, ids) -> jax.Array:
    """MIPS scores of query against selected int8 rows (rerank path)."""
    rows = data.q[ids].astype(jnp.float32) * data.scale[ids][:, None]
    return rows @ q
