"""Product quantization (Jegou et al. [9]) — 16 centers per subspace.

16 centers/subspace is the paper's choice ("usually chosen for amenability to
SIMD"); on TPU the same codebook shape is chosen for VMEM-residency + one-hot
MXU contraction (see kernels/pq_score.py). Codes are uint8 (one code < 16 per
subspace; we keep one byte per subspace for simplicity of layout — the memory
MODEL in benchmarks uses the paper's 4-bit accounting).

Training runs all m subspaces JOINTLY: one vmapped k-means++ init and one
batched fused Lloyd sweep per iteration over the (m, sample, s) tensor,
instead of m sequential host-looped `train_kmeans` calls — same keys, same
per-iteration early-stop decisions, bitwise-identical codebooks (the
sequential reference is kept as `train_pq_sequential` and pinned in
tests/test_build_perf.py). Scope of the bitwise claim: it holds on the
scan sweep route (CPU/GPU); on TPU `train_kmeans` dispatches the Pallas
one-hot-MXU accumulate whose f32 accumulation grouping differs from the
batched scan, so there the two trainers agree to rounding, not bits.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lloyd import _grouped_argmin, lloyd_sweep_batched
from repro.utils import chunked_map

# NOTE: repro.core.kmeans is imported lazily inside the training functions —
# core/ivf.py (pulled in by the repro.core package init) imports this module,
# so a top-level import here would be circular when repro.quant loads first.

# max chunk of the per-subspace Lloyd sweeps; divides the default training
# sample evenly (chunking changes only f32 accumulation grouping — both the
# batched and the sequential-reference paths use the same `_sweep_chunk`)
PQ_KMEANS_CHUNK = 16_384


def _sweep_chunk(n: int) -> int:
    """Even sweep tiling for n rows: smallest chunk <= PQ_KMEANS_CHUNK with
    the same tile count, rounded to 256 — a lopsided last tile is computed
    in full (padding is masked but not free), so e.g. 18k rows tile as
    2x9216 instead of 2x16384 (45% wasted lanes)."""
    nch = -(-n // PQ_KMEANS_CHUNK)
    return min(PQ_KMEANS_CHUNK, -(-(-(-n // nch)) // 256) * 256)
_INIT_SAMPLE = 50_000

# Default PQ training sample. 16 centers in a d/m-dim subspace saturate far
# below this (2k points/center at m=25, d=100); recall-after-build is
# unchanged vs the former 100k default (gated at Δ<=0.005 by the CI
# regression check) while the batched training sweep runs ~3x faster.
PQ_TRAIN_SAMPLE = 32_768


class PQCodebook(NamedTuple):
    centers: jax.Array   # (m, 16, s) float32 — m subspaces, 16 centers, s dims


@functools.partial(jax.jit, static_argnames=("n_centers",))
def _pp_init_batched(keys, Xm, n_centers: int):
    """vmapped k-means++ over the m subspaces (same keys as sequential)."""
    from repro.core.kmeans import kmeans_pp_init
    return jax.vmap(lambda k, x: kmeans_pp_init(k, x, n_centers))(keys, Xm)


def _subspace_keys(key, m: int):
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(m))


def train_pq(key, X, n_subspaces: int, n_centers: int = 16, iters: int = 8,
             sample: int = PQ_TRAIN_SAMPLE, tol: float = 1e-5,
             init_sample: int = _INIT_SAMPLE) -> PQCodebook:
    """Train per-subspace k-means codebooks on (a sample of) X — batched.

    All m subspaces advance together: one (m, n, s) batched sweep per
    iteration, with a host-side per-subspace active mask replicating the
    sequential early-stop schedule exactly (a converged subspace's
    centroids freeze while the rest keep iterating).
    """
    from repro.core.kmeans import _stopped
    n, d = X.shape
    assert d % n_subspaces == 0, (d, n_subspaces)
    m, s = n_subspaces, d // n_subspaces
    X = jnp.asarray(X, jnp.float32)
    if n > sample:
        sel = jax.random.choice(key, n, (sample,), replace=False)
        X = X[sel]
        n = sample
    Xm = jnp.transpose(X.reshape(n, m, s), (1, 0, 2))      # (m, n, s)

    keys = _subspace_keys(key, m)
    kinits = jax.vmap(lambda k: jax.random.split(k)[0])(keys)
    if n > init_sample:
        isel = jax.vmap(lambda k: jax.random.choice(
            k, n, (init_sample,), replace=False))(kinits)
        Xi = jax.vmap(lambda x, i: x[i])(Xm, isel)
    else:
        Xi = Xm
    C = _pp_init_batched(kinits, Xi, n_centers)

    active = np.ones(m, bool)
    prev = np.full(m, np.inf)
    chunk = _sweep_chunk(n)
    for _ in range(iters):
        newC, _, dist = lloyd_sweep_batched(Xm, C, n_centers, chunk=chunk)
        act = jnp.asarray(active)
        C = jnp.where(act[:, None, None], newC, C)
        dvals = np.asarray(dist)
        for j in np.nonzero(active)[0]:
            dj = float(dvals[j])
            if _stopped(prev[j], dj, tol):
                active[j] = False
            else:
                prev[j] = dj
        if not active.any():
            break
    return PQCodebook(C)


def train_pq_sequential(key, X, n_subspaces: int, n_centers: int = 16,
                        iters: int = 8, sample: int = PQ_TRAIN_SAMPLE,
                        init_sample: int = _INIT_SAMPLE) -> PQCodebook:
    """Reference: m host-looped `train_kmeans` calls (the pre-batching
    implementation). Kept for the bitwise-equality pin against the batched
    `train_pq` — both must produce identical codebooks at the same keys."""
    from repro.core.kmeans import train_kmeans
    n, d = X.shape
    assert d % n_subspaces == 0, (d, n_subspaces)
    s = d // n_subspaces
    if n > sample:
        sel = jax.random.choice(key, n, (sample,), replace=False)
        X = jnp.asarray(X, jnp.float32)[sel]
    Xs = jnp.asarray(X, jnp.float32).reshape(-1, n_subspaces, s)
    cents = []
    for m in range(n_subspaces):
        km = train_kmeans(jax.random.fold_in(key, m), Xs[:, m, :], n_centers,
                          iters=iters, chunk=_sweep_chunk(Xs.shape[0]),
                          init_sample=init_sample)
        cents.append(km.centroids)
    return PQCodebook(jnp.stack(cents))


def _encode_block(centers, xb):
    """(chunk, m, s) residual tile → (chunk, m) uint8 codes.

    Shared by `pq_encode` and the fused finalize encoder so every encode
    path resolves distances (and argmin ties) identically. The per-point
    ||x||^2 term is constant per (row, subspace) and dropped — it cannot
    change the argmin, including ties (both paths drop it). Small subspace
    dims contract as an unrolled multiply-add chain (one fused elementwise
    pass, no batch-transposed tiny-k GEMM dispatches — see
    kernels/lloyd.py::SMALL_D)."""
    from repro.kernels.lloyd import ARGMIN_GROUP, SMALL_D
    m, k, s = centers.shape
    cn = jnp.sum(centers * centers, axis=-1)
    if s <= SMALL_D:
        ip = xb[:, :, 0, None] * centers[None, :, :, 0]
        for j in range(1, s):
            ip = ip + xb[:, :, j, None] * centers[None, :, :, j]
    else:
        ip = jnp.einsum("bms,mks->bmk", xb, centers)
    dm = cn[None] - 2.0 * ip
    if k % ARGMIN_GROUP:           # pad center axis with never-chosen +inf
        dm = jnp.pad(dm, ((0, 0), (0, 0), (0, (-k) % ARGMIN_GROUP)),
                     constant_values=jnp.inf)
    idx, _ = _grouped_argmin(dm)
    return idx.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("chunk",))
def pq_encode(cb: PQCodebook, X, chunk: int = 16384) -> jax.Array:
    """Encode rows of X → (n, m) uint8 codes.

    `chunk` sizes the streamed tile; small online-mutation batches pass a
    small chunk so a 64-row insert doesn't pay for a 16k-row padded tile.
    """
    n, d = X.shape
    m, k, s = cb.centers.shape
    Xs = X.reshape(n, m, s)
    return chunked_map(lambda xb: _encode_block(cb.centers, xb), Xs, chunk)


@jax.jit
def pq_decode(cb: PQCodebook, codes) -> jax.Array:
    """(n, m) codes → (n, d) reconstruction."""
    n, m = codes.shape
    recon = jnp.take_along_axis(
        cb.centers[None], codes[:, :, None, None].astype(jnp.int32), axis=2)
    return recon[:, :, 0, :].reshape(n, -1)


@jax.jit
def pq_lut(cb: PQCodebook, q) -> jax.Array:
    """Per-query inner-product lookup table: (m, 16) for a (d,) query.

    score(q, decode(code)) == sum_m lut[m, code[m]].
    """
    m, k, s = cb.centers.shape
    qs = q.reshape(m, s)
    return jnp.einsum("ms,mks->mk", qs, cb.centers)


@jax.jit
def pq_score(lut, codes) -> jax.Array:
    """Asymmetric PQ scores: (m,16) lut × (n,m) codes → (n,) scores."""
    return jnp.sum(
        jnp.take_along_axis(lut[None], codes[:, :, None].astype(jnp.int32),
                            axis=2)[:, :, 0], axis=-1)


@functools.partial(jax.jit, static_argnames=())
def pq_score_batch(luts, codes) -> jax.Array:
    """(nq, m, 16) luts × (n, m) codes → (nq, n) scores (one-hot MXU form).

    This is the TPU-native formulation: expand codes to one-hot and contract
    on the MXU rather than per-element gathers (see DESIGN.md §3).
    """
    onehot = jax.nn.one_hot(codes.astype(jnp.int32), luts.shape[-1],
                            dtype=luts.dtype)          # (n, m, 16)
    return jnp.einsum("qmk,nmk->qn", luts, onehot)
