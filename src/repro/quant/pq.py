"""Product quantization (Jegou et al. [9]) — 16 centers per subspace.

16 centers/subspace is the paper's choice ("usually chosen for amenability to
SIMD"); on TPU the same codebook shape is chosen for VMEM-residency + one-hot
MXU contraction (see kernels/pq_score.py). Codes are uint8 (one code < 16 per
subspace; we keep one byte per subspace for simplicity of layout — the memory
MODEL in benchmarks uses the paper's 4-bit accounting).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import train_kmeans
from repro.utils import chunked_map


class PQCodebook(NamedTuple):
    centers: jax.Array   # (m, 16, s) float32 — m subspaces, 16 centers, s dims


def train_pq(key, X, n_subspaces: int, n_centers: int = 16, iters: int = 8,
             sample: int = 100_000) -> PQCodebook:
    """Train per-subspace k-means codebooks on (a sample of) X."""
    n, d = X.shape
    assert d % n_subspaces == 0, (d, n_subspaces)
    s = d // n_subspaces
    if n > sample:
        sel = jax.random.choice(key, n, (sample,), replace=False)
        X = X[sel]
    Xs = X.reshape(-1, n_subspaces, s)
    cents = []
    for m in range(n_subspaces):
        km = train_kmeans(jax.random.fold_in(key, m), Xs[:, m, :], n_centers,
                          iters=iters, chunk=32768)
        cents.append(km.centroids)
    return PQCodebook(jnp.stack(cents))


@functools.partial(jax.jit, static_argnames=("chunk",))
def pq_encode(cb: PQCodebook, X, chunk: int = 16384) -> jax.Array:
    """Encode rows of X → (n, m) uint8 codes.

    `chunk` sizes the streamed tile; small online-mutation batches pass a
    small chunk so a 64-row insert doesn't pay for a 16k-row padded tile.
    """
    n, d = X.shape
    m, k, s = cb.centers.shape
    Xs = X.reshape(n, m, s)

    def f(xb):
        # (chunk, m, s) vs (m, k, s) → distances (chunk, m, k)
        d2 = (jnp.sum(xb * xb, -1)[..., None]
              - 2.0 * jnp.einsum("bms,mks->bmk", xb, cb.centers)
              + jnp.sum(cb.centers * cb.centers, -1)[None])
        return jnp.argmin(d2, axis=-1).astype(jnp.uint8)

    return chunked_map(f, Xs, chunk)


@jax.jit
def pq_decode(cb: PQCodebook, codes) -> jax.Array:
    """(n, m) codes → (n, d) reconstruction."""
    n, m = codes.shape
    recon = jnp.take_along_axis(
        cb.centers[None], codes[:, :, None, None].astype(jnp.int32), axis=2)
    return recon[:, :, 0, :].reshape(n, -1)


@jax.jit
def pq_lut(cb: PQCodebook, q) -> jax.Array:
    """Per-query inner-product lookup table: (m, 16) for a (d,) query.

    score(q, decode(code)) == sum_m lut[m, code[m]].
    """
    m, k, s = cb.centers.shape
    qs = q.reshape(m, s)
    return jnp.einsum("ms,mks->mk", qs, cb.centers)


@jax.jit
def pq_score(lut, codes) -> jax.Array:
    """Asymmetric PQ scores: (m,16) lut × (n,m) codes → (n,) scores."""
    return jnp.sum(
        jnp.take_along_axis(lut[None], codes[:, :, None].astype(jnp.int32),
                            axis=2)[:, :, 0], axis=-1)


@functools.partial(jax.jit, static_argnames=())
def pq_score_batch(luts, codes) -> jax.Array:
    """(nq, m, 16) luts × (n, m) codes → (nq, n) scores (one-hot MXU form).

    This is the TPU-native formulation: expand codes to one-hot and contract
    on the MXU rather than per-element gathers (see DESIGN.md §3).
    """
    onehot = jax.nn.one_hot(codes.astype(jnp.int32), luts.shape[-1],
                            dtype=luts.dtype)          # (n, m, 16)
    return jnp.einsum("qmk,nmk->qn", luts, onehot)
