"""Unified serving request API (DESIGN.md §3.12).

One request/response vocabulary for every serving edge — `AnnEngine.search`,
`KNNMemory.retrieve/attend`, the distributed search makers, and the async
front-end (serve/frontend.py):

- `SearchParams`: everything a caller can ask of a search (k, probe budget,
  rerank budget, subset filters, escalation/sanitize policy, a latency
  deadline for the front-end batcher, and a tenant handle for standing
  per-tenant filters). Immutable, hashable where it matters (the batcher's
  coalescing key derives from it), and the ONE place serving defaults and
  argument validation live — the legacy kwarg signatures on the engines are
  thin shims that build a `SearchParams`, with bitwise-identical results
  (pinned by tests/test_serve_api.py).

- `SearchResult`: ids/scores plus the serving metadata a production caller
  needs (engine time, queue wait, coalesced-batch size, escalation flag,
  index epoch served, degraded/shards_ok/retries resilience flags).
  Unpacks like the legacy `(ids, scores)` tuple.

- The serving **error taxonomy** (DESIGN.md §3.13): `ServingError` and its
  subclasses `OverloadedError` (admission rejected / load shed),
  `DeadlineExceededError` (budget expired while queued), and
  `FrontendClosedError` (orderly close or fatal dispatcher failure) — all
  carrying `queued_us`/`engine_us` so failed requests are SLO-accountable
  too. `is_retryable` classifies any exception for the front-end's bounded
  retry and for client backoff policy.

Default sources of truth (previously drifting between the engines —
KNNMemory.retrieve hardcoded `top_t=4` against AnnEngine's configured 8):

    DEFAULT_K              final neighbors returned
    DEFAULT_TOP_T          partitions probed (both AnnEngine and KNNMemory)
    DEFAULT_RERANK_BUDGET  candidates exactly reranked after PQ scoring
    DEFAULT_BQ             serving jit tile / max coalesced batch
    DEFAULT_DEADLINE_MS    front-end batching deadline when a request
                           carries none
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

DEFAULT_K = 10
DEFAULT_TOP_T = 8
DEFAULT_RERANK_BUDGET = 256
DEFAULT_BQ = 128
DEFAULT_DEADLINE_MS = 50.0
# deadline_ms bounds (§3.13): a request whose budget is under the floor
# cannot complete even on an idle engine (one padded jit dispatch costs
# more), so it is unsatisfiable AT SUBMIT and rejected there instead of
# being admitted, queued, and shed at dispatch; above the cap "deadline"
# stops meaning anything — pass deadline_ms=None (best-effort, never
# shed) instead of a number nothing will ever exceed.
MIN_DEADLINE_MS = 0.05
MAX_DEADLINE_MS = 600_000.0


class ServingError(RuntimeError):
    """Base of the serving error taxonomy (DESIGN.md §3.13).

    Every subclass records whether a client retry can help (`retryable`)
    and carries the same timing metadata a successful SearchResult would
    (`queued_us`/`engine_us`) — a shed or expired request still tells
    the caller how long it sat and how much engine time it consumed
    (always 0 for requests rejected before dispatch), so SLO accounting
    covers failures, not just successes.

    The taxonomy is also the front-end's retry policy: `is_retryable`
    drives its bounded retry + exponential backoff for engine failures
    (DESIGN.md §3.13), and tells clients of OverloadedError to back off
    and resubmit vs. clients of DeadlineExceededError that resubmitting
    the same budget will fail the same way.
    """
    retryable = False

    def __init__(self, msg: str, *, queued_us: float = 0.0,
                 engine_us: float = 0.0):
        super().__init__(msg)
        self.queued_us = float(queued_us)
        self.engine_us = float(engine_us)


class OverloadedError(ServingError):
    """Admission control rejected (or load shedding evicted) the request:
    the front-end's bounded queue is full. Retryable — by the CLIENT,
    after backoff; the front-end itself never retries shed work (that
    would re-add the load being shed)."""
    retryable = True


class DeadlineExceededError(ServingError):
    """The request's deadline expired while it was still queued: it was
    dropped at dispatch time instead of consuming engine capacity on an
    answer nobody is waiting for. Not retryable — the budget is spent;
    resubmitting with the same deadline under the same load fails the
    same way."""
    retryable = False


class FrontendClosedError(ServingError):
    """The front-end is closed — either an orderly `close()` or a fatal
    dispatcher failure (the original failure is `__cause__`). Pending
    Futures are failed with this instead of hanging; `submit` after
    close raises it synchronously."""
    retryable = False


def is_retryable(exc: BaseException) -> bool:
    """Transient-failure classification for the front-end's bounded
    retry (DESIGN.md §3.13). An error is retryable iff it says so: the
    ServingError taxonomy and the fault injectors carry a `retryable`
    attribute, and a few stdlib transport-ish types (TimeoutError,
    ConnectionError, InterruptedError) are transient by nature.
    Everything else — ValueError from bad inputs, engine invariant
    failures, InjectedCrash — is fatal for the request: retrying a
    deterministic failure just triples its latency."""
    r = getattr(exc, "retryable", None)
    if r is not None:
        return bool(r)
    return isinstance(exc, (TimeoutError, ConnectionError,
                            InterruptedError))


def _positive_int(name: str, v) -> int:
    """Serving-edge bounds check: k/top_t/rerank_budget/bq must be
    positive integers — an explicit 0 (or a float, or a bool) is a caller
    bug and gets a clear error instead of silently searching nothing or
    falling back to a default."""
    if isinstance(v, bool) or not isinstance(v, (int, np.integer)) or v < 1:
        raise ValueError(f"{name} must be a positive integer, got {v!r}")
    return int(v)


def validate_queries(Q, d: int, *, sanitize: bool = False) -> np.ndarray:
    """Query hygiene for serving entry points (DESIGN.md §3.11): returns
    a (nq, d) float32 batch or raises a clear ValueError. Rejects
    non-numeric dtypes and wrong rank; non-finite values (NaN/Inf —
    including float64 magnitudes that overflow the float32 cast) raise
    unless `sanitize`, which zeroes them. Without this, one NaN query
    poisons its whole jit tile's scores with no error anywhere."""
    Q = np.asarray(Q)
    if (Q.dtype == object or not np.issubdtype(Q.dtype, np.number)
            or np.issubdtype(Q.dtype, np.complexfloating)):
        raise ValueError(
            f"queries must be real-numeric, got dtype {Q.dtype}")
    Q = np.atleast_2d(Q)
    if Q.ndim != 2:
        raise ValueError(
            f"queries must be (nq, d) or (d,), got shape {tuple(Q.shape)}")
    from repro.core.router import check_query_dim
    check_query_dim(Q, d)
    with np.errstate(over="ignore"):   # cast overflow → inf, caught below
        Q = Q.astype(np.float32, copy=False)
    if Q.size and not np.isfinite(Q).all():
        if sanitize:
            Q = np.nan_to_num(Q, nan=0.0, posinf=0.0, neginf=0.0)
        else:
            bad = int((~np.isfinite(Q)).sum())
            raise ValueError(
                f"queries contain {bad} non-finite value(s) (NaN/Inf); "
                f"pass sanitize=True to zero them")
    return Q


@dataclass(frozen=True)
class SearchParams:
    """Everything a serving caller can ask of one search request.

    `top_t`/`rerank_budget` of None resolve to the serving object's
    configured values (AnnEngine's constructor args, KNNMemory's `top_t`
    field) — `validate()` performs that resolution plus the hardened-edge
    bounds checks, and is the ONE validation path shared by every edge.

    Subset filters (`filter_ids`/`filter_mask`, and the kNN-memory-shaped
    `recency`/`segment`) compose with the index's standing tombstone
    filter exactly as the legacy kwargs did. `tenant` names a standing
    per-tenant filter registered with the front-end's TenantFilterBank —
    resolution happens at dispatch, against a device-cached bitmap.

    `deadline_ms` is the front-end batching budget: the micro-batcher
    flushes a pending batch no later than half the oldest request's
    deadline (DESIGN.md §3.12). Direct engine calls ignore it.
    """
    k: int = DEFAULT_K
    top_t: Optional[int] = None
    rerank_budget: Optional[int] = None
    filter_ids: Optional[Sequence[int]] = None
    filter_mask: Optional[np.ndarray] = None
    recency: Optional[int] = None
    segment: Optional[int] = None
    escalate: bool = True
    sanitize: bool = False
    deadline_ms: Optional[float] = None
    tenant: Optional[str] = None

    # -------------------------------------------------------- validation
    def validate(self, *, default_top_t: Optional[int] = None,
                 default_rerank: Optional[int] = None) -> "SearchParams":
        """Resolve None fields against the serving object's defaults and
        bounds-check everything; returns a fully-resolved copy. This is
        the deduplicated hardened path both AnnEngine and KNNMemory route
        through (an explicit top_t=0 raises here, never silently falls
        back to a default)."""
        k = _positive_int("k", self.k)
        top_t = self.top_t if self.top_t is not None else default_top_t
        if top_t is not None:
            top_t = _positive_int("top_t", top_t)
        rb = (self.rerank_budget if self.rerank_budget is not None
              else default_rerank)
        if rb is not None:
            rb = _positive_int("rerank_budget", rb)
        dl = self.deadline_ms
        if dl is not None:
            if isinstance(dl, bool) or not isinstance(
                    dl, (int, float, np.integer, np.floating)) \
                    or not np.isfinite(dl) or dl <= 0:
                raise ValueError(
                    f"deadline_ms must be a positive finite number, "
                    f"got {dl!r}")
            dl = float(dl)
            # Deadline semantics (DESIGN.md §3.13): the budget runs from
            # submit() admission to Future completion. The front-end
            # flushes a pending batch by half the oldest deadline and
            # SHEDS any still-queued request at dispatch once its budget
            # is spent (DeadlineExceededError). A budget below the floor
            # is unsatisfiable at submit (one engine dispatch already
            # exceeds it) and is rejected HERE — admitting it would just
            # convert a caller bug into queue churn and a guaranteed
            # shed. deadline_ms=None means best-effort: paced by the
            # front-end's default_deadline_ms for batching, never shed.
            if not MIN_DEADLINE_MS <= dl <= MAX_DEADLINE_MS:
                raise ValueError(
                    f"deadline_ms={dl!r} is outside "
                    f"[{MIN_DEADLINE_MS}, {MAX_DEADLINE_MS}] — budgets "
                    f"under the floor are unsatisfiable at submit time; "
                    f"pass deadline_ms=None for best-effort (no-shed) "
                    f"serving instead of an unbounded number")
        if self.recency is not None and (
                isinstance(self.recency, bool)
                or not isinstance(self.recency, (int, np.integer))
                or self.recency < 0):
            raise ValueError(
                f"recency must be a non-negative integer, "
                f"got {self.recency!r}")
        return dataclasses.replace(self, k=k, top_t=top_t, rerank_budget=rb,
                                   deadline_ms=dl)

    # ------------------------------------------------------- batching key
    @property
    def has_inline_filter(self) -> bool:
        """An ad-hoc (non-tenant) subset rides this request: a raw
        bitmap/allowlist or a kNN-memory recency/segment window."""
        return (self.filter_ids is not None or self.filter_mask is not None
                or self.recency is not None or self.segment is not None)

    def batch_key(self) -> Optional[Tuple]:
        """Coalescing identity for the front-end micro-batcher: requests
        sharing a key run in ONE padded jit call (the filter bitmap and
        the static search shape are per-call, so they must agree).
        Returns None for requests carrying an ad-hoc inline filter —
        those dispatch solo rather than comparing bitmaps by value."""
        if self.has_inline_filter:
            return None
        return (self.k, self.top_t, self.rerank_budget, self.escalate,
                self.tenant)


@dataclass
class SearchResult:
    """Structured search response: results plus serving metadata.

    `ids`/`scores` are the legacy (nq, k) arrays (`scores` is None on the
    host-engine KNNMemory path, which never computed them). Metadata:

    - engine_us:  device-complete wall time of the jit call that served
                  this request (shared across a coalesced batch)
    - queued_us:  time spent waiting in the front-end queue (0 direct)
    - batch_size: total queries in the coalesced dispatch (== nq direct)
    - escalated:  the selectivity-escalation second pass was armed
    - epoch:      index mutation epoch served (MutableIVF._alive_epoch) —
                  two results at the same epoch are comparable bitwise
    - tenant:     standing filter the request was served under
    - degraded:   served with reduced coverage (§3.13): one or more
                  fan-out targets were down and the result is top-k over
                  the HEALTHY remainder (or a replica dispatch fell back
                  to the local path). False on every healthy-path result,
                  whose ids/scores stay bitwise-identical to pre-§3.13
                  behavior.
    - shards_ok:  when a shard fan-out served this request, the shard
                  indexes that contributed (all of them ⇒ not degraded);
                  None on single-target paths.
    - retries:    transient engine failures absorbed by the front-end's
                  bounded retry before this result was produced.

    Iterates/unpacks as (ids, scores) so structured callers and legacy
    tuple callers share the engines' return value.
    """
    ids: np.ndarray
    scores: Optional[np.ndarray]
    engine_us: float = 0.0
    queued_us: float = 0.0
    batch_size: int = 0
    escalated: bool = False
    epoch: int = -1
    tenant: Optional[str] = None
    deadline_ms: Optional[float] = None
    degraded: bool = False
    shards_ok: Optional[Tuple[int, ...]] = None
    retries: int = 0

    @property
    def nq(self) -> int:
        return int(self.ids.shape[0])

    @property
    def k(self) -> int:
        return int(self.ids.shape[1])

    @property
    def total_us(self) -> float:
        return self.engine_us + self.queued_us

    def deadline_met(self) -> Optional[bool]:
        if self.deadline_ms is None:
            return None
        return self.total_us <= self.deadline_ms * 1e3

    def __iter__(self):
        yield self.ids
        yield self.scores
