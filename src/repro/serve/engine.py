"""Serving engine: batched prefill + greedy decode over the model zoo.

`serve_step` (single decode step over a full KV cache) is the function the
decode_32k / long_500k dry-run cells lower; `generate` is the CPU-runnable
driver used by examples and tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


def make_serve_step(cfg: ModelConfig):
    """fn(params, token (B,1), caches, index) → (next_token (B,1), caches)."""

    def serve_step(params, token, caches, index):
        logits, caches = T.decode_step(params, token, caches, index, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], caches

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, inputs):
        return T.prefill(params, inputs, cfg, max_seq=max_seq)
    return prefill_step


class ServeEngine:
    """Minimal batched greedy-decoding engine (CPU-runnable at smoke scale)."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    def generate(self, inputs: dict, n_new: int):
        """inputs: {"tokens": (B, S)} (+ patches for vlm). Greedy decode."""
        logits, caches = self._prefill(self.params, inputs)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        prefix = (self.cfg.n_prefix_embeds
                  if self.cfg.frontend == "vision" else 0)
        start = inputs["tokens"].shape[1] + prefix
        out = [tok]
        for i in range(n_new - 1):
            tok, caches = self._step(self.params, tok, caches,
                                     jnp.asarray(start + i, jnp.int32))
            out.append(tok)
        return jnp.concatenate(out, axis=1)
