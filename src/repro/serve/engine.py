"""Serving engines: LM decode (prefill + greedy decode over the model zoo)
and online ANN serving over a mutable SOAR index.

`serve_step` (single decode step over a full KV cache) is the function the
decode_32k / long_500k dry-run cells lower; `generate` is the CPU-runnable
driver used by examples and tests. `AnnEngine` is the vector-search
counterpart: add/remove/search against a live index (DESIGN.md §3.7).
"""
from __future__ import annotations

import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.models import transformer as T
from repro.models.config import ModelConfig
# validation + defaults live on the unified request API (serve/api.py,
# DESIGN.md §3.12); re-exported here because this module was their
# historical home and external callers import them from the engine edge
from repro.serve.api import (_positive_int, validate_queries,  # noqa: F401
                             SearchParams, SearchResult,
                             DEFAULT_TOP_T, DEFAULT_RERANK_BUDGET,
                             DEFAULT_BQ)


def make_serve_step(cfg: ModelConfig):
    """fn(params, token (B,1), caches, index) → (next_token (B,1), caches)."""

    def serve_step(params, token, caches, index):
        logits, caches = T.decode_step(params, token, caches, index, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], caches

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, inputs):
        return T.prefill(params, inputs, cfg, max_seq=max_seq)
    return prefill_step


class ServeEngine:
    """Minimal batched greedy-decoding engine (CPU-runnable at smoke scale)."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    def generate(self, inputs: dict, n_new: int):
        """inputs: {"tokens": (B, S)} (+ patches for vlm). Greedy decode."""
        logits, caches = self._prefill(self.params, inputs)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        prefix = (self.cfg.n_prefix_embeds
                  if self.cfg.frontend == "vision" else 0)
        start = inputs["tokens"].shape[1] + prefix
        out = [tok]
        for i in range(n_new - 1):
            tok, caches = self._step(self.params, tok, caches,
                                     jnp.asarray(start + i, jnp.int32))
            out.append(tok)
        return jnp.concatenate(out, axis=1)


class AnnEngine:
    """Online ANN serving engine over a mutable SOAR index.

    Wraps core/mutable.MutableIVF with the candidate-local jit search
    pipeline (DESIGN.md §3.6): `search` serves from the index's cached
    packed snapshot, which mutation (`add`/`remove`) invalidates — the
    snapshot cost is amortized across the mutation batch, and the
    tombstone/compaction policy (§3.7) bounds how stale the padded layout
    can get. Point ids returned by `add` are stable handles for `remove`
    and for joining search results back to caller-side payloads.
    """

    def __init__(self, index, *, top_t: int = DEFAULT_TOP_T,
                 rerank_budget: int = DEFAULT_RERANK_BUDGET,
                 bq: int = DEFAULT_BQ):
        self.index = index
        self.top_t = _positive_int("top_t", top_t)
        self.rerank_budget = _positive_int("rerank_budget", rerank_budget)
        self.bq = _positive_int("bq", bq)

    @classmethod
    def build(cls, key, X, n_partitions: int, *, spill_mode: str = "soar",
              lam: float = 1.0, pq_subspaces: int = 0,
              top_t: int = DEFAULT_TOP_T,
              rerank_budget: int = DEFAULT_RERANK_BUDGET,
              bq: int = DEFAULT_BQ, router=None,
              router_kw=None, **build_kw):
        """Sharded build (core/build.py) → serving engine.

        router: probe-stage router spec plumbed to the build ("tree"
        trains a TreeRouter over the centroids and every search then
        probes through it; None keeps the flat probe — DESIGN.md §3.10).
        """
        from repro.core.mutable import MutableIVF
        idx = MutableIVF.build(key, X, n_partitions, spill_mode=spill_mode,
                               lam=lam, pq_subspaces=pq_subspaces,
                               router=router, router_kw=router_kw, **build_kw)
        return cls(idx, top_t=top_t, rerank_budget=rerank_budget, bq=bq)

    @property
    def n_alive(self) -> int:
        return self.index.n_alive

    def add(self, X) -> np.ndarray:
        faults.serve_point("engine:add")
        return self.index.add(X)

    def remove(self, ids, hard: bool = True) -> int:
        """Delete points. hard=False leaves slots in place and serves the
        tombstones through the standing filter bitmap (zero data movement,
        no snapshot invalidation) — see MutableIVF.remove."""
        faults.serve_point("engine:remove")
        return self.index.remove(ids, hard=hard)

    def search(self, Q, k: int = 10, top_t: Optional[int] = None,
               filter_ids=None, filter_mask=None, escalate: bool = True,
               sanitize: bool = False):
        """(nq, d) queries → (ids (nq, k) int32, scores (nq, k)).

        Thin shim over the unified request API (DESIGN.md §3.12): builds
        a SearchParams and routes through `search_request` — results are
        bitwise identical to constructing the params directly (pinned by
        tests/test_serve_api.py). See SearchParams for the full contract;
        the engine remains the hardened serving edge (dtype/finiteness
        validation, explicit top_t=0 raises, nq=0 returns empties).

        filter_ids / filter_mask restrict the search to a subset of live
        points; both compose with the index's standing soft-tombstone
        filter. The filtered path runs the selectivity-escalating jit
        pipeline (§3.9) — pass escalate=False when the filter is known to
        be fat. Unfiltered serving with no soft tombstones stays on the
        exact PR 4 trace.
        """
        r = self.search_request(Q, SearchParams(
            k=k, top_t=top_t, filter_ids=filter_ids,
            filter_mask=filter_mask, escalate=escalate, sanitize=sanitize))
        return r.ids, r.scores

    def search_request(self, Q, params: Optional[SearchParams] = None, *,
                       _filter_dev=None) -> SearchResult:
        """Structured serving entry point: (nq, d) queries + SearchParams
        → SearchResult (DESIGN.md §3.12).

        Validation (query hygiene + k/top_t/rerank_budget bounds) runs
        through `SearchParams.validate()` — the single hardened path
        shared with KNNMemory. `_filter_dev` is the front-end's seam: a
        pre-composed DEVICE filter bitmap (tenant ∧ alive, cached by the
        TenantFilterBank) that skips the per-call host composition and
        upload `serving_filter` would pay for a user subset.
        """
        from repro.core.router import clamp_top_t
        from repro.core.search import pad_queries, search_jit_batched
        p = (params or SearchParams()).validate(
            default_top_t=self.top_t, default_rerank=self.rerank_budget)
        Q = validate_queries(Q, self.index.centroids.shape[1],
                             sanitize=p.sanitize)
        epoch = getattr(self.index, "_alive_epoch", -1)
        if Q.shape[0] == 0:
            return SearchResult(np.empty((0, p.k), np.int32),
                                np.empty((0, p.k), np.float32),
                                epoch=epoch, tenant=p.tenant,
                                deadline_ms=p.deadline_ms)
        faults.serve_point("engine:search")
        if _filter_dev is not None:
            filt, escalate = _filter_dev, p.escalate
        else:
            filt, escalate = self.index.serving_filter(
                mask=p.filter_mask, ids=p.filter_ids, escalate=p.escalate)
        t0 = time.perf_counter()
        Qp, nq, bq = pad_queries(Q, self.bq)
        ids, vals = search_jit_batched(
            self.index.pack(), jnp.asarray(Qp),
            top_t=clamp_top_t(p.top_t, self.index.centroids.shape[0]),
            final_k=p.k, rerank_budget=max(p.rerank_budget, p.k),
            bq=bq, multiplicity=1 + max(self.index.n_spills, 1),
            filter=filt, escalate=escalate)
        ids, vals = np.asarray(ids)[:nq], np.asarray(vals)[:nq]
        return SearchResult(
            ids, vals, engine_us=(time.perf_counter() - t0) * 1e6,
            batch_size=nq, escalated=bool(escalate and filt is not None),
            epoch=epoch, tenant=p.tenant, deadline_ms=p.deadline_ms)

    # ---------------------------------------------------------- durability
    def save(self, path: str, *, extra: Optional[dict] = None,
             extra_arrays: Optional[dict] = None):
        """Atomic, versioned snapshot of the full serving state — index
        (codebooks, router, partitions, tombstones, wal_seq) + engine
        config — under `path` (DESIGN.md §3.11). If a WAL is attached,
        the log is rotated afterwards: every record is covered by the
        snapshot's wal_seq, and sequence numbers continue monotonically,
        so a crash between snapshot commit and rotation is benign.

        `extra` (JSON-able) and `extra_arrays` (name → ndarray) ride the
        snapshot for layers above the engine — the serving front-end
        stores its batching config and per-tenant filter bitmaps here
        (§3.12) so a reopened index serves the same tenants."""
        from repro.ckpt.index_store import save_snapshot
        os.makedirs(path, exist_ok=True)
        meta = {"engine": {"top_t": self.top_t,
                           "rerank_budget": self.rerank_budget,
                           "bq": self.bq}}
        meta.update(extra or {})
        save_snapshot(os.path.join(path, "index"), self.index,
                      extra=meta, extra_arrays=extra_arrays)
        wal = getattr(self.index, "_wal", None)
        if wal is not None:
            wal.rotate(self.index.wal_seq)

    @classmethod
    def open(cls, path: str, *, wal: bool = False, fsync: str = "always"):
        """Reopen a saved engine: load the latest valid snapshot (the
        atomic-swap `.old` fallback included) and replay any committed
        WAL records past its wal_seq — recovery lands bitwise on the last
        committed state, never a torn hybrid. `wal=True` (or a log
        already on disk) leaves a WAL attached so every subsequent
        mutation is logged transparently; `fsync` is its durability
        policy ("always" | "never")."""
        from repro.ckpt.index_store import load_snapshot
        from repro.ckpt.wal import MutationWAL
        idx, extra = load_snapshot(os.path.join(path, "index"),
                                   expect_kind="MutableIVF")
        cfg = dict(extra.get("engine", {}))
        eng = cls(idx, top_t=int(cfg.get("top_t", DEFAULT_TOP_T)),
                  rerank_budget=int(cfg.get("rerank_budget",
                                            DEFAULT_RERANK_BUDGET)),
                  bq=int(cfg.get("bq", DEFAULT_BQ)))
        wal_path = os.path.join(path, "wal.log")
        if wal or os.path.exists(wal_path):
            idx.attach_wal(MutationWAL(wal_path, fsync=fsync,
                                       start_seq=idx.wal_seq))
        return eng
