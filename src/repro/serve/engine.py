"""Serving engines: LM decode (prefill + greedy decode over the model zoo)
and online ANN serving over a mutable SOAR index.

`serve_step` (single decode step over a full KV cache) is the function the
decode_32k / long_500k dry-run cells lower; `generate` is the CPU-runnable
driver used by examples and tests. `AnnEngine` is the vector-search
counterpart: add/remove/search against a live index (DESIGN.md §3.7).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


def make_serve_step(cfg: ModelConfig):
    """fn(params, token (B,1), caches, index) → (next_token (B,1), caches)."""

    def serve_step(params, token, caches, index):
        logits, caches = T.decode_step(params, token, caches, index, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], caches

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, inputs):
        return T.prefill(params, inputs, cfg, max_seq=max_seq)
    return prefill_step


class ServeEngine:
    """Minimal batched greedy-decoding engine (CPU-runnable at smoke scale)."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    def generate(self, inputs: dict, n_new: int):
        """inputs: {"tokens": (B, S)} (+ patches for vlm). Greedy decode."""
        logits, caches = self._prefill(self.params, inputs)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        prefix = (self.cfg.n_prefix_embeds
                  if self.cfg.frontend == "vision" else 0)
        start = inputs["tokens"].shape[1] + prefix
        out = [tok]
        for i in range(n_new - 1):
            tok, caches = self._step(self.params, tok, caches,
                                     jnp.asarray(start + i, jnp.int32))
            out.append(tok)
        return jnp.concatenate(out, axis=1)


class AnnEngine:
    """Online ANN serving engine over a mutable SOAR index.

    Wraps core/mutable.MutableIVF with the candidate-local jit search
    pipeline (DESIGN.md §3.6): `search` serves from the index's cached
    packed snapshot, which mutation (`add`/`remove`) invalidates — the
    snapshot cost is amortized across the mutation batch, and the
    tombstone/compaction policy (§3.7) bounds how stale the padded layout
    can get. Point ids returned by `add` are stable handles for `remove`
    and for joining search results back to caller-side payloads.
    """

    def __init__(self, index, *, top_t: int = 8, rerank_budget: int = 256,
                 bq: int = 128):
        self.index = index
        self.top_t = top_t
        self.rerank_budget = rerank_budget
        self.bq = bq

    @classmethod
    def build(cls, key, X, n_partitions: int, *, spill_mode: str = "soar",
              lam: float = 1.0, pq_subspaces: int = 0, top_t: int = 8,
              rerank_budget: int = 256, bq: int = 128, router=None,
              router_kw=None, **build_kw):
        """Sharded build (core/build.py) → serving engine.

        router: probe-stage router spec plumbed to the build ("tree"
        trains a TreeRouter over the centroids and every search then
        probes through it; None keeps the flat probe — DESIGN.md §3.10).
        """
        from repro.core.mutable import MutableIVF
        idx = MutableIVF.build(key, X, n_partitions, spill_mode=spill_mode,
                               lam=lam, pq_subspaces=pq_subspaces,
                               router=router, router_kw=router_kw, **build_kw)
        return cls(idx, top_t=top_t, rerank_budget=rerank_budget, bq=bq)

    @property
    def n_alive(self) -> int:
        return self.index.n_alive

    def add(self, X) -> np.ndarray:
        return self.index.add(X)

    def remove(self, ids, hard: bool = True) -> int:
        """Delete points. hard=False leaves slots in place and serves the
        tombstones through the standing filter bitmap (zero data movement,
        no snapshot invalidation) — see MutableIVF.remove."""
        return self.index.remove(ids, hard=hard)

    def search(self, Q, k: int = 10, top_t: Optional[int] = None,
               filter_ids=None, filter_mask=None, escalate: bool = True):
        """(nq, d) queries → (ids (nq, k) int32, scores (nq, k)).

        filter_ids / filter_mask restrict the search to a subset of live
        points (an explicit id allowlist and/or a bitmap over point ids);
        both compose with the index's standing soft-tombstone filter. The
        filtered path runs the selectivity-escalating jit pipeline
        (DESIGN.md §3.9) — pass escalate=False when the filter is known to
        be fat (e.g. a handful of soft tombstones) to skip the fixed
        second probe pass. Unfiltered serving with no soft tombstones
        stays on the exact PR 4 trace.
        """
        from repro.core.router import clamp_top_t
        from repro.core.search import pad_queries, search_jit_batched
        filt, escalate = self.index.serving_filter(
            mask=filter_mask, ids=filter_ids, escalate=escalate)
        Qp, nq, bq = pad_queries(Q, self.bq)
        ids, vals = search_jit_batched(
            self.index.pack(), jnp.asarray(Qp),
            top_t=clamp_top_t(top_t or self.top_t,
                              self.index.centroids.shape[0]),
            final_k=k, rerank_budget=max(self.rerank_budget, k),
            bq=bq, multiplicity=1 + max(self.index.n_spills, 1),
            filter=filt, escalate=escalate)
        return np.asarray(ids)[:nq], np.asarray(vals)[:nq]
