"""Serving engines: LM decode (prefill + greedy decode over the model zoo)
and online ANN serving over a mutable SOAR index.

`serve_step` (single decode step over a full KV cache) is the function the
decode_32k / long_500k dry-run cells lower; `generate` is the CPU-runnable
driver used by examples and tests. `AnnEngine` is the vector-search
counterpart: add/remove/search against a live index (DESIGN.md §3.7).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


def _positive_int(name: str, v) -> int:
    """Serving-edge bounds check: k/top_t/rerank_budget/bq must be
    positive integers — an explicit 0 (or a float, or a bool) is a caller
    bug and gets a clear error instead of silently searching nothing or
    falling back to a default."""
    if isinstance(v, bool) or not isinstance(v, (int, np.integer)) or v < 1:
        raise ValueError(f"{name} must be a positive integer, got {v!r}")
    return int(v)


def validate_queries(Q, d: int, *, sanitize: bool = False) -> np.ndarray:
    """Query hygiene for serving entry points (DESIGN.md §3.11): returns
    a (nq, d) float32 batch or raises a clear ValueError. Rejects
    non-numeric dtypes and wrong rank; non-finite values (NaN/Inf —
    including float64 magnitudes that overflow the float32 cast) raise
    unless `sanitize`, which zeroes them. Without this, one NaN query
    poisons its whole jit tile's scores with no error anywhere."""
    Q = np.asarray(Q)
    if (Q.dtype == object or not np.issubdtype(Q.dtype, np.number)
            or np.issubdtype(Q.dtype, np.complexfloating)):
        raise ValueError(
            f"queries must be real-numeric, got dtype {Q.dtype}")
    Q = np.atleast_2d(Q)
    if Q.ndim != 2:
        raise ValueError(
            f"queries must be (nq, d) or (d,), got shape {tuple(Q.shape)}")
    from repro.core.router import check_query_dim
    check_query_dim(Q, d)
    with np.errstate(over="ignore"):   # cast overflow → inf, caught below
        Q = Q.astype(np.float32, copy=False)
    if Q.size and not np.isfinite(Q).all():
        if sanitize:
            Q = np.nan_to_num(Q, nan=0.0, posinf=0.0, neginf=0.0)
        else:
            bad = int((~np.isfinite(Q)).sum())
            raise ValueError(
                f"queries contain {bad} non-finite value(s) (NaN/Inf); "
                f"pass sanitize=True to zero them")
    return Q


def make_serve_step(cfg: ModelConfig):
    """fn(params, token (B,1), caches, index) → (next_token (B,1), caches)."""

    def serve_step(params, token, caches, index):
        logits, caches = T.decode_step(params, token, caches, index, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], caches

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, inputs):
        return T.prefill(params, inputs, cfg, max_seq=max_seq)
    return prefill_step


class ServeEngine:
    """Minimal batched greedy-decoding engine (CPU-runnable at smoke scale)."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    def generate(self, inputs: dict, n_new: int):
        """inputs: {"tokens": (B, S)} (+ patches for vlm). Greedy decode."""
        logits, caches = self._prefill(self.params, inputs)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        prefix = (self.cfg.n_prefix_embeds
                  if self.cfg.frontend == "vision" else 0)
        start = inputs["tokens"].shape[1] + prefix
        out = [tok]
        for i in range(n_new - 1):
            tok, caches = self._step(self.params, tok, caches,
                                     jnp.asarray(start + i, jnp.int32))
            out.append(tok)
        return jnp.concatenate(out, axis=1)


class AnnEngine:
    """Online ANN serving engine over a mutable SOAR index.

    Wraps core/mutable.MutableIVF with the candidate-local jit search
    pipeline (DESIGN.md §3.6): `search` serves from the index's cached
    packed snapshot, which mutation (`add`/`remove`) invalidates — the
    snapshot cost is amortized across the mutation batch, and the
    tombstone/compaction policy (§3.7) bounds how stale the padded layout
    can get. Point ids returned by `add` are stable handles for `remove`
    and for joining search results back to caller-side payloads.
    """

    def __init__(self, index, *, top_t: int = 8, rerank_budget: int = 256,
                 bq: int = 128):
        self.index = index
        self.top_t = _positive_int("top_t", top_t)
        self.rerank_budget = _positive_int("rerank_budget", rerank_budget)
        self.bq = _positive_int("bq", bq)

    @classmethod
    def build(cls, key, X, n_partitions: int, *, spill_mode: str = "soar",
              lam: float = 1.0, pq_subspaces: int = 0, top_t: int = 8,
              rerank_budget: int = 256, bq: int = 128, router=None,
              router_kw=None, **build_kw):
        """Sharded build (core/build.py) → serving engine.

        router: probe-stage router spec plumbed to the build ("tree"
        trains a TreeRouter over the centroids and every search then
        probes through it; None keeps the flat probe — DESIGN.md §3.10).
        """
        from repro.core.mutable import MutableIVF
        idx = MutableIVF.build(key, X, n_partitions, spill_mode=spill_mode,
                               lam=lam, pq_subspaces=pq_subspaces,
                               router=router, router_kw=router_kw, **build_kw)
        return cls(idx, top_t=top_t, rerank_budget=rerank_budget, bq=bq)

    @property
    def n_alive(self) -> int:
        return self.index.n_alive

    def add(self, X) -> np.ndarray:
        return self.index.add(X)

    def remove(self, ids, hard: bool = True) -> int:
        """Delete points. hard=False leaves slots in place and serves the
        tombstones through the standing filter bitmap (zero data movement,
        no snapshot invalidation) — see MutableIVF.remove."""
        return self.index.remove(ids, hard=hard)

    def search(self, Q, k: int = 10, top_t: Optional[int] = None,
               filter_ids=None, filter_mask=None, escalate: bool = True,
               sanitize: bool = False):
        """(nq, d) queries → (ids (nq, k) int32, scores (nq, k)).

        The engine is the hardened serving edge (DESIGN.md §3.11): Q is
        dtype/shape/finiteness-validated (`sanitize=True` zeroes NaN/Inf
        instead of raising), k/top_t are bounds-checked — an explicit
        top_t=0 raises rather than silently falling back to the default —
        and an empty batch returns empty (0, k) results without touching
        the jit pipeline.

        filter_ids / filter_mask restrict the search to a subset of live
        points (an explicit id allowlist and/or a bitmap over point ids);
        both compose with the index's standing soft-tombstone filter. The
        filtered path runs the selectivity-escalating jit pipeline
        (DESIGN.md §3.9) — pass escalate=False when the filter is known to
        be fat (e.g. a handful of soft tombstones) to skip the fixed
        second probe pass. Unfiltered serving with no soft tombstones
        stays on the exact PR 4 trace.
        """
        from repro.core.router import clamp_top_t
        from repro.core.search import pad_queries, search_jit_batched
        k = _positive_int("k", k)
        top_t = (self.top_t if top_t is None
                 else _positive_int("top_t", top_t))
        Q = validate_queries(Q, self.index.centroids.shape[1],
                             sanitize=sanitize)
        if Q.shape[0] == 0:
            return np.empty((0, k), np.int32), np.empty((0, k), np.float32)
        filt, escalate = self.index.serving_filter(
            mask=filter_mask, ids=filter_ids, escalate=escalate)
        Qp, nq, bq = pad_queries(Q, self.bq)
        ids, vals = search_jit_batched(
            self.index.pack(), jnp.asarray(Qp),
            top_t=clamp_top_t(top_t, self.index.centroids.shape[0]),
            final_k=k, rerank_budget=max(self.rerank_budget, k),
            bq=bq, multiplicity=1 + max(self.index.n_spills, 1),
            filter=filt, escalate=escalate)
        return np.asarray(ids)[:nq], np.asarray(vals)[:nq]

    # ---------------------------------------------------------- durability
    def save(self, path: str):
        """Atomic, versioned snapshot of the full serving state — index
        (codebooks, router, partitions, tombstones, wal_seq) + engine
        config — under `path` (DESIGN.md §3.11). If a WAL is attached,
        the log is rotated afterwards: every record is covered by the
        snapshot's wal_seq, and sequence numbers continue monotonically,
        so a crash between snapshot commit and rotation is benign."""
        from repro.ckpt.index_store import save_snapshot
        os.makedirs(path, exist_ok=True)
        save_snapshot(os.path.join(path, "index"), self.index,
                      extra={"engine": {"top_t": self.top_t,
                                        "rerank_budget": self.rerank_budget,
                                        "bq": self.bq}})
        wal = getattr(self.index, "_wal", None)
        if wal is not None:
            wal.rotate(self.index.wal_seq)

    @classmethod
    def open(cls, path: str, *, wal: bool = False, fsync: str = "always"):
        """Reopen a saved engine: load the latest valid snapshot (the
        atomic-swap `.old` fallback included) and replay any committed
        WAL records past its wal_seq — recovery lands bitwise on the last
        committed state, never a torn hybrid. `wal=True` (or a log
        already on disk) leaves a WAL attached so every subsequent
        mutation is logged transparently; `fsync` is its durability
        policy ("always" | "never")."""
        from repro.ckpt.index_store import load_snapshot
        from repro.ckpt.wal import MutationWAL
        idx, extra = load_snapshot(os.path.join(path, "index"),
                                   expect_kind="MutableIVF")
        cfg = dict(extra.get("engine", {}))
        eng = cls(idx, top_t=int(cfg.get("top_t", 8)),
                  rerank_budget=int(cfg.get("rerank_budget", 256)),
                  bq=int(cfg.get("bq", 128)))
        wal_path = os.path.join(path, "wal.log")
        if wal or os.path.exists(wal_path):
            idx.attach_wal(MutationWAL(wal_path, fsync=fsync,
                                       start_seq=idx.wal_seq))
        return eng
