"""Production serving front-end (DESIGN.md §3.12): deadline-aware dynamic
batching, standing multi-tenant filters, and replica fan-out in front of
AnnEngine.

The engines (serve/engine.py, serve/knn_memory.py) are synchronous,
single-caller edges: every `search` pays its own padded jit dispatch, and
concurrent callers must serialize around the mutable index themselves. This
module adds the missing production layer:

- **ServingFrontend** — an async request loop. Callers `submit` a
  (queries, SearchParams) request and get a Future (or `await asearch`);
  a single dispatcher thread owns the engine and coalesces compatible
  pending requests into ONE padded `search_jit_batched` call. Because the
  engine pads every batch to a power-of-two bucket anyway (pad_queries),
  eight concurrent single-query callers cost ~one bucket-8 call instead of
  eight — and coalescing reuses exactly the buckets solo calls would
  compile, so it NEVER adds a compile (pinned by
  tests/test_frontend.py::test_no_recompilation).

- **Deadline-aware flushing** — a batch dispatches when it reaches
  `max_batch` queries OR when the oldest compatible request has spent half
  its `deadline_ms` budget waiting (clamped by `max_delay_ms`, so
  steady-state trickle traffic still coalesces without stalling a
  half-deadline on every 50 ms-budget request). `max_delay_ms=None` gives
  the pure half-deadline policy.

- **Determinism** — coalescing is result-invariant: every stage of the jit
  pipeline is query-local, so a request served inside a coalesced batch is
  BITWISE identical to the same request served solo at the same index
  epoch (pinned by tests/test_frontend.py::test_coalesced_equals_solo).
  Requests carrying an ad-hoc inline filter (raw bitmap/allowlist) have
  per-request device state and dispatch solo; requests sharing a
  registered `tenant` coalesce, since their filter is the same standing
  bitmap.

- **TenantFilterBank** — standing per-tenant subset filters. A tenant's
  id-set is registered once; at dispatch the front-end serves from an
  epoch-keyed LRU of DEVICE bitmaps (tenant ∧ alive), so per-request cost
  is a dict hit, not an O(n) host compose + upload. Mutations bump the
  index epoch and invalidate every cached bitmap at once (the
  generalization of the capacity-1 standing-filter cache inside
  MutableIVF — same EpochLRU).

- **Mutations as barriers** — `add`/`remove` enqueue through the same
  queue and dispatch only from the queue head, after every
  earlier-submitted search; no search submitted after a mutation is
  served before it. Epoch-tagged SearchResults make the ordering
  observable.

- **Replica fan-out** — with >1 device and `policy="replica"` (or
  "auto"), coalesced batches are sharded row-wise over a device mesh via
  make_replicated_search (index replicated, queries split): the
  data-parallel dual of the shard-parallel distributed layer, bitwise
  identical to local execution because replicas run the same query-local
  pipeline with no collectives.

Durability rides the engine snapshot: `save` stores the front-end config
and every tenant bitmap as `extra`/`extra_arrays` alongside the index, and
`open` restores a front-end serving the same tenants.

Resilience layer (ISSUE 9, DESIGN.md §3.13) — the front-end learns to say
"no" and "partially" instead of hanging:

- **Admission control / load shedding** — `max_queue` bounds the pending
  cost (queries queued; a mutation counts `mutation_cost`, default one
  full batch). At the bound, `overload="reject"` refuses the new request
  with `OverloadedError`; `overload="shed-oldest"` evicts the queued
  search with the LEAST deadline slack (the one most likely to miss
  anyway) to admit the newcomer. Mutations are never shed (their Futures
  represent writes) and never evict searches — a mutation flood hits
  admission itself, so barriers can't starve the search share of the
  queue.

- **Deadline enforcement** — a request carrying an explicit `deadline_ms`
  whose budget expires while still queued is dropped AT DISPATCH with
  `DeadlineExceededError` (carrying its `queued_us`) instead of spending
  engine time on an answer nobody is waiting for. `deadline_ms=None`
  requests are best-effort: paced by `default_deadline_ms` for batching,
  never shed.

- **Failure containment** — an engine `Exception` fails ONLY the
  offending dispatch group's Futures; the dispatcher keeps serving.
  Failures classified retryable (`serve/api.is_retryable`) get a bounded
  retry with exponential backoff (`max_retries`/`retry_backoff_ms`);
  mutations are never retried (a partially-applied add must not
  double-apply). A `BaseException` (an injected crash, interpreter
  shutdown) is fatal: the in-flight group gets the original error, every
  queued Future fails with `FrontendClosedError` (cause attached), and
  subsequent `submit` raises it — callers NEVER hang on a dead
  dispatcher. `close(drain=False)` fails pending Futures deterministically
  instead of draining.

- **Degraded replica fan-out** — replica dispatch runs behind a
  per-target circuit breaker (serve/health.py): a failed replica batch
  trips the breaker and falls back to the local single-device path (same
  data, full coverage) with `SearchResult.degraded=True`; while the
  breaker is open, traffic stays local (still flagged degraded) until the
  half-open probe heals it. The shard-parallel degraded path (partial
  top-k from healthy shards) lives in core/distributed.py
  `with_health=True`.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.core.mutable import EpochLRU
from repro.serve.api import (DEFAULT_DEADLINE_MS, DeadlineExceededError,
                             FrontendClosedError, OverloadedError,
                             SearchParams, SearchResult, _positive_int,
                             is_retryable)
from repro.serve.engine import AnnEngine
from repro.serve.health import HealthTracker


class UnknownTenantError(KeyError):
    """A request named a tenant never registered with the front-end."""


class TenantFilterBank:
    """Standing per-tenant filters over a mutable index (DESIGN.md §3.12).

    A tenant is a named id-subset (e.g. one customer's vectors in a shared
    index). `register` stores the subset as a host bool mask over point
    ids; `get` returns the DEVICE uint8 bitmap (tenant ∧ alive) the jit
    filter path consumes, served from an EpochLRU keyed on
    (index alive-epoch, capacity width, tenant version):

    - index mutation (add/remove) bumps `_alive_epoch` → every tenant's
      cached bitmap is stale and rebuilds on next use (tombstoned ids
      drop out of the tenant's serving set immediately);
    - `register`/`extend` bump the tenant's own version → only that
      tenant rebuilds;
    - unchanged tenants hit the cache: steady-state per-request filter
      cost is a dict lookup, zero host compose, zero upload.

    `capacity` bounds device memory: at most that many tenant bitmaps
    stay resident, LRU-evicted (an evicted tenant re-uploads on next
    use — correctness is unaffected). The underlying EpochLRU is the same
    cache class MutableIVF uses at capacity 1 for its standing
    tombstone filter.
    """

    def __init__(self, index, capacity: int = 32):
        self.index = index
        self._cache = EpochLRU(capacity=_positive_int("capacity", capacity))
        self._masks: dict = {}      # tenant -> host bool mask over ids
        self._versions: dict = {}   # tenant -> int, bumped on (re)register
        self._lock = threading.Lock()

    # ------------------------------------------------------------ registry
    def register(self, tenant: str, ids: Optional[Sequence[int]] = None,
                 mask: Optional[np.ndarray] = None) -> None:
        """(Re)define a tenant's id-set from an allowlist or a bool mask.
        Replaces any previous definition and invalidates its cached
        bitmap."""
        if (ids is None) == (mask is None):
            raise ValueError("register needs exactly one of ids= or mask=")
        if mask is not None:
            m = np.asarray(mask).astype(bool).ravel().copy()
        else:
            ii = np.asarray(ids, np.int64).ravel()
            if ii.size and ii.min() < 0:
                raise ValueError("tenant ids must be non-negative")
            m = np.zeros(int(ii.max()) + 1 if ii.size else 0, bool)
            m[ii] = True
        with self._lock:
            self._masks[tenant] = m
            self._versions[tenant] = self._versions.get(tenant, 0) + 1
            self._cache.drop(tenant)

    def extend(self, tenant: str, ids: Sequence[int]) -> None:
        """Grow a tenant's id-set (e.g. after `add` returned fresh ids for
        that tenant's vectors)."""
        ii = np.asarray(ids, np.int64).ravel()
        with self._lock:
            if tenant not in self._masks:
                raise UnknownTenantError(tenant)
            m = self._masks[tenant]
            need = int(ii.max()) + 1 if ii.size else 0
            if need > m.shape[0]:
                m = np.concatenate([m, np.zeros(need - m.shape[0], bool)])
            m[ii] = True
            self._masks[tenant] = m
            self._versions[tenant] += 1
            self._cache.drop(tenant)

    @property
    def tenants(self):
        with self._lock:
            return sorted(self._masks)

    @property
    def fills(self) -> int:
        """Device bitmap (re)builds so far — the observable for cache
        efficiency tests (steady state: one fill per tenant per index
        epoch)."""
        return self._cache.fills

    def __contains__(self, tenant) -> bool:
        with self._lock:
            return tenant in self._masks

    def __len__(self) -> int:
        with self._lock:
            return len(self._masks)

    # ------------------------------------------------------------- serving
    def get(self, tenant: str) -> jax.Array:
        """DEVICE uint8 bitmap (tenant ∧ alive) at capacity width, cached
        per (alive-epoch, capacity, tenant-version)."""
        with self._lock:
            if tenant not in self._masks:
                raise UnknownTenantError(tenant)
            idx, m = self.index, self._masks[tenant]
            epoch = (getattr(idx, "_alive_epoch", -1), idx.alive.shape[0],
                     self._versions[tenant])
            return self._cache.get(
                tenant, epoch,
                lambda: jnp.asarray(idx.filter_bitmap(mask=m)))

    # ---------------------------------------------------------- durability
    def state(self):
        """(meta, arrays) for riding an engine snapshot."""
        with self._lock:
            meta = {"tenants": sorted(self._masks)}
            arrays = {f"tenant.{t}": self._masks[t].astype(np.uint8)
                      for t in self._masks}
            return meta, arrays


@dataclass
class _Request:
    """One queued front-end operation. kind: "search" | "add" | "remove"."""
    kind: str
    future: Future
    Q: Optional[np.ndarray] = None
    params: Optional[SearchParams] = None     # validated at submit
    key: Optional[tuple] = None               # coalescing key (None = solo)
    t_admit: float = 0.0                      # perf_counter at submit
    flush_at: float = field(default=float("inf"))
    payload: Optional[tuple] = None           # mutation args
    deadline_at: Optional[float] = None       # absolute expiry (explicit
    #                                           deadline_ms only; None =
    #                                           best-effort, never shed)
    cost: int = 1                             # admission units (queries)
    retries: int = 0                          # dispatch retries so far

    @property
    def nq(self) -> int:
        return int(self.Q.shape[0]) if self.Q is not None else 0

    @property
    def slack(self) -> float:
        """Deadline slack for shed-oldest ordering (None = infinite —
        best-effort requests are shed last)."""
        return (float("inf") if self.deadline_at is None
                else self.deadline_at - time.perf_counter())


class ServingFrontend:
    """Async serving loop in front of AnnEngine (DESIGN.md §3.12).

    One dispatcher thread owns the engine: searches AND mutations flow
    through its queue, so callers never take a lock around the mutable
    index. Compatible searches (same SearchParams.batch_key) coalesce
    into one padded jit call; mutations are strict barriers.

    Flush policy: a pending group dispatches when

    - its total queries reach `max_batch` (default: the engine's jit tile
      `bq` — one full tile), or
    - the oldest request in it has waited `min(max_delay_ms,
      deadline_ms / 2)` — half the request's latency budget, clamped so a
      generous deadline doesn't stall the queue (`max_delay_ms=None`
      removes the clamp → pure half-deadline policy), or
    - the front-end is closing / `flush()` was called.

    `policy` selects execution: "local" always runs the single-device
    engine path; "replica" shards each coalesced batch row-wise over all
    visible devices via make_replicated_search (index replicated — the
    query-bound regime's scaling axis); "auto" picks replica iff more
    than one device is visible. Both paths are bitwise identical per
    query, so the policy is purely a throughput decision.
    """

    def __init__(self, engine: AnnEngine, *,
                 max_batch: Optional[int] = None,
                 max_delay_ms: Optional[float] = 2.0,
                 default_deadline_ms: float = DEFAULT_DEADLINE_MS,
                 policy: str = "auto",
                 tenant_capacity: int = 32,
                 max_queue: Optional[int] = None,
                 overload: str = "reject",
                 mutation_cost: Optional[int] = None,
                 max_retries: int = 2,
                 retry_backoff_ms: float = 1.0,
                 breaker_threshold: int = 3,
                 breaker_reset_s: float = 5.0):
        if policy not in ("local", "replica", "auto"):
            raise ValueError(f"policy must be local|replica|auto, "
                             f"got {policy!r}")
        if overload not in ("reject", "shed-oldest"):
            raise ValueError(f"overload must be reject|shed-oldest, "
                             f"got {overload!r}")
        self.engine = engine
        self.max_batch = _positive_int(
            "max_batch", max_batch if max_batch is not None else engine.bq)
        if max_delay_ms is not None and not max_delay_ms > 0:
            raise ValueError("max_delay_ms must be positive or None")
        self.max_delay_ms = max_delay_ms
        self.default_deadline_ms = float(default_deadline_ms)
        self.policy = policy
        self.max_queue = (None if max_queue is None
                          else _positive_int("max_queue", max_queue))
        self.overload = overload
        self.mutation_cost = _positive_int(
            "mutation_cost",
            mutation_cost if mutation_cost is not None else self.max_batch)
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.max_retries = int(max_retries)
        if retry_backoff_ms < 0:
            raise ValueError("retry_backoff_ms must be >= 0")
        self.retry_backoff_ms = float(retry_backoff_ms)
        self.health = HealthTracker(fail_threshold=breaker_threshold,
                                    reset_after_s=breaker_reset_s)
        self.tenants = TenantFilterBank(engine.index,
                                        capacity=tenant_capacity)
        self.stats = {"dispatches": 0, "coalesced": 0, "requests": 0,
                      "mutations": 0, "replica_dispatches": 0,
                      "rejected": 0, "shed": 0, "expired": 0,
                      "retries": 0, "failures": 0, "degraded": 0}
        self._q: deque = deque()
        self._cost = 0                  # admission units currently queued
        self._fatal: Optional[BaseException] = None
        self._cond = threading.Condition()
        self._closed = False
        self._draining = False
        self._rep_cache: dict = {}      # static-shape key -> jitted replica fn
        self._mesh = None
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-frontend", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- clients
    def submit(self, Q, params: Optional[SearchParams] = None) -> Future:
        """Enqueue a search; returns a Future[SearchResult]. Validation
        (param bounds + query hygiene) runs HERE, in the caller's thread —
        a malformed request fails fast and never reaches the batcher."""
        from repro.serve.api import validate_queries
        p = (params or SearchParams()).validate(
            default_top_t=self.engine.top_t,
            default_rerank=self.engine.rerank_budget)
        Q = validate_queries(Q, self.engine.index.centroids.shape[1],
                             sanitize=p.sanitize)
        if p.tenant is not None and p.tenant not in self.tenants:
            raise UnknownTenantError(p.tenant)
        fut: Future = Future()
        now = time.perf_counter()
        deadline = (p.deadline_ms if p.deadline_ms is not None
                    else self.default_deadline_ms)
        wait_ms = deadline / 2.0
        if self.max_delay_ms is not None:
            wait_ms = min(wait_ms, self.max_delay_ms)
        req = _Request("search", fut, Q=Q, params=p, key=p.batch_key(),
                       t_admit=now, flush_at=now + wait_ms * 1e-3,
                       deadline_at=(now + p.deadline_ms * 1e-3
                                    if p.deadline_ms is not None else None),
                       cost=max(int(Q.shape[0]), 1))
        self._enqueue(req)
        return fut

    def search(self, Q, params: Optional[SearchParams] = None,
               **kw) -> SearchResult:
        """Blocking search through the front-end loop. Legacy kwargs
        (k=, top_t=, tenant=, deadline_ms=, ...) accepted as a
        SearchParams shim."""
        if kw:
            if params is not None:
                raise TypeError("pass params= or kwargs, not both")
            params = SearchParams(**kw)
        return self.submit(Q, params).result()

    async def asearch(self, Q, params: Optional[SearchParams] = None
                      ) -> SearchResult:
        """Awaitable search for asyncio servers."""
        import asyncio
        return await asyncio.wrap_future(self.submit(Q, params))

    def add(self, X, tenant: Optional[str] = None) -> np.ndarray:
        """Mutation barrier: append points through the queue (after every
        earlier search, before every later one). With `tenant`, the fresh
        ids also extend that tenant's standing filter atomically with the
        insert (no window where the points are live but unfindable by
        their tenant)."""
        fut: Future = Future()
        self._enqueue(_Request("add", fut, payload=(X, tenant),
                               t_admit=time.perf_counter(),
                               cost=self.mutation_cost))
        return fut.result()

    def remove(self, ids, hard: bool = True) -> int:
        """Mutation barrier: tombstone points through the queue."""
        fut: Future = Future()
        self._enqueue(_Request("remove", fut, payload=(ids, hard),
                               t_admit=time.perf_counter(),
                               cost=self.mutation_cost))
        return fut.result()

    def register_tenant(self, tenant: str,
                        ids: Optional[Sequence[int]] = None,
                        mask: Optional[np.ndarray] = None) -> None:
        self.tenants.register(tenant, ids=ids, mask=mask)

    def flush(self) -> None:
        """Block until every currently queued request has dispatched
        (pending deadline timers are overridden — the queue drains now)."""
        with self._cond:
            self._draining = True
            self._cond.notify_all()
            self._cond.wait_for(
                lambda: not self._q or self._closed
                or self._fatal is not None)
            self._draining = False

    def close(self, drain: bool = True) -> None:
        """Stop the dispatcher. Idempotent, and deterministic about every
        pending Future: `drain=True` (default) serves the queue first;
        `drain=False` fails queued Futures with FrontendClosedError
        immediately. If the dispatcher already died, pending Futures were
        failed at death — close() just reaps the thread."""
        with self._cond:
            if not self._closed:
                if drain and self._fatal is None:
                    self._draining = True
                    self._cond.notify_all()
                    self._cond.wait_for(
                        lambda: not self._q or self._fatal is not None)
                    self._draining = False
                self._fail_pending_locked(FrontendClosedError(
                    "front-end is closed (closed before dispatch)"))
                self._closed = True
                self._cond.notify_all()
        self._thread.join(timeout=10.0)

    def _fail_pending_locked(self, exc: BaseException) -> None:
        """Lock held: fail every queued Future with `exc` and empty the
        queue — nobody blocks on a Future the dispatcher will never
        serve."""
        for r in self._q:
            if not r.future.done():
                r.future.set_exception(exc)
        self._q.clear()
        self._cost = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ---------------------------------------------------------- dispatcher
    def _enqueue(self, req: _Request) -> None:
        with self._cond:
            if self._closed or self._fatal is not None:
                err = FrontendClosedError("front-end is closed")
                err.__cause__ = self._fatal
                raise err
            if (self.max_queue is not None
                    and self._cost + req.cost > self.max_queue):
                self._admit_locked(req)   # sheds or raises OverloadedError
            self._q.append(req)
            self._cost += req.cost
            self._cond.notify_all()

    def _admit_locked(self, req: _Request) -> None:
        """Lock held, queue over budget: make room for `req` or refuse it.

        Mutations never shed (a write's Future is a promise) and never
        evict queued searches — an over-budget mutation is rejected under
        BOTH policies, so a mutation flood backpressures its producer
        instead of starving the search share of the queue. Under
        "shed-oldest", queued searches are evicted least-deadline-slack
        first (the requests most likely to miss anyway); best-effort
        requests (no explicit deadline → infinite slack) go last."""
        if self.overload == "reject" or req.kind != "search":
            self.stats["rejected"] += 1
            raise OverloadedError(
                f"queue full ({self._cost}/{self.max_queue} units pending)")
        victims = sorted((r for r in self._q if r.kind == "search"),
                         key=lambda r: (r.slack, r.t_admit))
        now = time.perf_counter()
        shed = set()
        for v in victims:
            if self._cost + req.cost <= self.max_queue:
                break
            shed.add(id(v))
            self._cost -= v.cost
            self.stats["shed"] += 1
            if not v.future.done():
                v.future.set_exception(OverloadedError(
                    "shed under overload (least deadline slack)",
                    queued_us=(now - v.t_admit) * 1e6))
        if shed:
            self._q = deque(r for r in self._q if id(r) not in shed)
        if self._cost + req.cost > self.max_queue:
            self.stats["rejected"] += 1
            raise OverloadedError(
                f"queue full ({self._cost}/{self.max_queue} units pending, "
                f"nothing sheddable)")

    def _loop(self) -> None:
        try:
            while True:
                with self._cond:
                    group, timeout = self._collect_locked()
                    if group is None:
                        if self._closed and not self._q:
                            return
                        self._cond.wait(timeout=timeout)
                        continue
                    if not self._q:
                        self._cond.notify_all()   # wake flush()/close()
                try:
                    self._dispatch(group)
                except Exception as e:       # contained: group-local
                    self._contain(group, e)
                except BaseException as e:   # fatal: crash the dispatcher
                    for r in group:
                        if not r.future.done():
                            r.future.set_exception(e)
                    raise
                with self._cond:
                    if not self._q:
                        self._cond.notify_all()
        except BaseException as e:  # noqa: BLE001 — recorded as _fatal
            self._dispatcher_died(e)

    def _dispatcher_died(self, exc: BaseException) -> None:
        """The dispatcher thread is exiting on a fatal error. Fail every
        queued Future (nobody should block forever on a dead loop) and
        poison `submit` — pinned by the stranded-Future regression test."""
        with self._cond:
            self._fatal = exc
            err = FrontendClosedError(
                f"dispatcher thread died: {exc!r}")
            err.__cause__ = exc
            self._fail_pending_locked(err)
            self._cond.notify_all()

    def _contain(self, group, exc: Exception) -> None:
        """An engine Exception during dispatch: fail THIS group only; the
        dispatcher keeps serving. Retryable search failures get a bounded
        exponential backoff and re-queue at the head (still before any
        queued mutation — searches at one epoch commute, so head re-entry
        preserves the barrier order). Mutations never retry: the engine
        may have partially applied the write, and replaying it could
        double-apply."""
        r0 = group[0]
        if (r0.kind == "search" and is_retryable(exc)
                and r0.retries < self.max_retries):
            time.sleep(self.retry_backoff_ms * (2 ** r0.retries) * 1e-3)
            with self._cond:
                if self._closed or self._fatal is not None:
                    err = FrontendClosedError(
                        "front-end closed during retry")
                    err.__cause__ = exc
                    for r in group:
                        if not r.future.done():
                            r.future.set_exception(err)
                    return
                for r in group:
                    r.retries += 1
                self.stats["retries"] += 1
                self._q.extendleft(reversed(group))
                self._cost += sum(r.cost for r in group)
                self._cond.notify_all()
            return
        self.stats["failures"] += 1
        for r in group:
            if not r.future.done():
                r.future.set_exception(exc)

    def _collect_locked(self):
        """With the lock held: pick the next dispatch group, or
        (None, timeout) to sleep. Mutations dispatch only from the queue
        head (strict barrier); searches group by coalescing key across the
        pre-mutation prefix (searches at one epoch commute, so grouping
        past a different-keyed search is safe — past a mutation is not).

        Deadline enforcement happens HERE, at collection time: a queued
        search whose explicit deadline already passed is dropped with
        DeadlineExceededError instead of consuming engine time. Requests
        already handed to the engine are never clawed back."""
        self._expire_locked()
        q = self._q
        if not q:
            return None, None
        head = q[0]
        if head.kind != "search":
            q.popleft()
            self._cost -= head.cost
            return [head], None
        pre = []                    # searches before the first mutation
        for r in q:
            if r.kind != "search":
                break
            pre.append(r)
        groups: dict = {}
        for r in pre:
            groups.setdefault(r.key if r.key is not None else id(r),
                              []).append(r)
        now = time.perf_counter()
        target = None
        for g in groups.values():   # a full batch dispatches immediately
            if sum(r.nq for r in g) >= self.max_batch:
                target = g
                break
        if target is None:
            ripe = ([min(pre, key=lambda r: r.flush_at)] if self._draining
                    else [r for r in pre if now >= r.flush_at])
            if not ripe:
                return None, max(min(r.flush_at for r in pre) - now, 1e-4)
            first = min(ripe, key=lambda r: r.flush_at)
            target = groups[first.key if first.key is not None
                            else id(first)]
        chosen, total = [], 0
        for r in target:            # cap the coalesced batch at max_batch:
            if chosen and total + r.nq > self.max_batch:
                break               # never overflow into a LARGER padding
            chosen.append(r)        # bucket than solo serving would use
            total += r.nq
            if total >= self.max_batch:
                break
        taken = set(map(id, chosen))
        self._q = deque(r for r in q if id(r) not in taken)
        self._cost -= sum(r.cost for r in chosen)
        return chosen, None

    def _expire_locked(self) -> None:
        """Lock held: shed queued searches whose explicit deadline has
        already passed (their caller has given up; an answer now is pure
        waste). Best-effort requests (deadline_at=None) never expire."""
        now = time.perf_counter()
        dead = [r for r in self._q
                if r.kind == "search" and r.deadline_at is not None
                and now >= r.deadline_at]
        if not dead:
            return
        gone = set(map(id, dead))
        self._q = deque(r for r in self._q if id(r) not in gone)
        self._cost -= sum(r.cost for r in dead)
        self.stats["expired"] += len(dead)
        for r in dead:
            qd = (now - r.t_admit) * 1e6
            if not r.future.done():
                r.future.set_exception(DeadlineExceededError(
                    f"deadline_ms={r.params.deadline_ms} expired after "
                    f"{qd / 1e3:.1f}ms queued", queued_us=qd))

    def _dispatch(self, group) -> None:
        req = group[0]
        if req.kind == "add":
            X, tenant = req.payload
            ids = self.engine.add(X)
            if tenant is not None:
                if tenant in self.tenants:
                    self.tenants.extend(tenant, ids)
                else:
                    self.tenants.register(tenant, ids=ids)
            self.stats["mutations"] += 1
            req.future.set_result(ids)
            return
        if req.kind == "remove":
            ids, hard = req.payload
            n = self.engine.remove(ids, hard=hard)
            self.stats["mutations"] += 1
            req.future.set_result(n)
            return
        self._dispatch_search(group)

    def _dispatch_search(self, group) -> None:
        p = group[0].params          # key-equal across the group
        Qcat = (np.concatenate([r.Q for r in group])
                if len(group) > 1 else group[0].Q)
        filt_dev = (self.tenants.get(p.tenant)
                    if p.tenant is not None else None)
        t0 = time.perf_counter()
        degraded = False
        want_replica = self._use_replica(p)
        use_replica = want_replica and self.health.allow("replica")
        if want_replica and not use_replica:
            degraded = True     # breaker open: full-coverage local serve,
            #                     but the fan-out capacity is reduced
        ids = None
        if use_replica:
            try:
                ids, vals, escalated = self._replica_search(Qcat, p,
                                                            filt_dev)
                self.health.success("replica")
                self.stats["replica_dispatches"] += 1
            except Exception:   # replica target failed: trip + fall back
                self.health.failure("replica")
                degraded = True
        if ids is None:         # local path (policy, breaker, or fallback)
            r = self.engine.search_request(
                Qcat, p, **({"_filter_dev": filt_dev}
                            if filt_dev is not None else {}))
            ids, vals, escalated = r.ids, r.scores, r.escalated
        if degraded:
            self.stats["degraded"] += len(group)
        engine_us = (time.perf_counter() - t0) * 1e6
        t_done = time.perf_counter()
        epoch = getattr(self.engine.index, "_alive_epoch", -1)
        self.stats["dispatches"] += 1
        self.stats["requests"] += len(group)
        self.stats["coalesced"] += len(group) - 1
        total = int(ids.shape[0])
        off = 0
        for r in group:
            sl = slice(off, off + r.nq)
            off += r.nq
            r.future.set_result(SearchResult(
                ids[sl], vals[sl] if vals is not None else None,
                engine_us=engine_us,
                queued_us=(t_done - r.t_admit) * 1e6 - engine_us,
                batch_size=total, escalated=escalated, epoch=epoch,
                tenant=p.tenant, deadline_ms=r.params.deadline_ms,
                degraded=degraded, retries=r.retries))

    # ------------------------------------------------------ replica fan-out
    def _use_replica(self, p: SearchParams) -> bool:
        if self.policy == "local":
            return False
        n_dev = len(jax.devices())
        if self.policy == "replica" and n_dev < 2:
            return False
        if self.policy == "auto" and n_dev < 2:
            return False
        # inline host filters stay on the engine path (it owns their
        # compose-and-upload); tenant filters are already device-resident
        return not p.has_inline_filter

    def _replica_search(self, Q: np.ndarray, p: SearchParams, filt_dev):
        """Shard a coalesced batch row-wise over all devices. Mirrors the
        engine path's filter/escalation plan exactly (serving_filter) so
        results stay bitwise identical to local execution."""
        from repro.core.router import clamp_top_t
        from repro.core.search import pad_queries
        faults.serve_point("replica:dispatch")
        if filt_dev is None:
            filt, escalate = self.engine.index.serving_filter(
                escalate=p.escalate)
        else:
            filt, escalate = filt_dev, p.escalate
        devs = jax.devices()
        R = len(devs)
        top_t = clamp_top_t(p.top_t, self.engine.index.centroids.shape[0])
        mult = 1 + max(self.engine.index.n_spills, 1)
        key = (top_t, p.k, max(p.rerank_budget, p.k), mult,
               bool(escalate), filt is not None, R)
        fn = self._rep_cache.get(key)
        if fn is None:
            from jax.sharding import Mesh
            from repro.core.distributed import make_replicated_search
            if self._mesh is None:
                self._mesh = Mesh(np.array(devs), ("r",))
            fn = jax.jit(make_replicated_search(
                self._mesh, ("r",), top_t=top_t, final_k=p.k,
                rerank_budget=max(p.rerank_budget, p.k), multiplicity=mult,
                with_filter=filt is not None, escalate=bool(escalate)))
            self._rep_cache[key] = fn
        Qp, nq, _ = pad_queries(Q, self.engine.bq, multiple=R)
        packed = self.engine.index.pack()
        args = (packed, jnp.asarray(Qp)) + ((filt,) if filt is not None
                                            else ())
        ids, vals = fn(*args)
        return (np.asarray(ids)[:nq], np.asarray(vals)[:nq],
                bool(escalate and filt is not None))

    # ---------------------------------------------------------- durability
    def save(self, path: str) -> None:
        """Snapshot engine + front-end: the index snapshot carries the
        batching config in its manifest and every tenant mask as an
        `extra.` array (same atomicity/CRC guarantees)."""
        self.flush()
        tmeta, tarrays = self.tenants.state()
        cfg = {"max_batch": self.max_batch,
               "max_delay_ms": self.max_delay_ms,
               "default_deadline_ms": self.default_deadline_ms,
               "policy": self.policy,
               "tenant_capacity": self.tenants._cache.capacity,
               "max_queue": self.max_queue,
               "overload": self.overload,
               "mutation_cost": self.mutation_cost,
               "max_retries": self.max_retries,
               "retry_backoff_ms": self.retry_backoff_ms}
        self.engine.save(path, extra={"frontend": cfg, **tmeta},
                         extra_arrays=tarrays)

    @classmethod
    def open(cls, path: str, *, wal: bool = False, fsync: str = "always",
             **overrides) -> "ServingFrontend":
        """Reopen a saved front-end: engine snapshot (+ WAL replay) plus
        the saved batching config and tenant registry. `overrides` replace
        saved config fields (e.g. policy="local")."""
        from repro.ckpt.index_store import load_extra_arrays, read_manifest
        eng = AnnEngine.open(path, wal=wal, fsync=fsync)
        ipath = os.path.join(path, "index")
        extra = read_manifest(ipath)["meta"].get("extra", {})
        cfg = dict(extra.get("frontend", {}))
        cfg.update(overrides)
        fe = cls(eng, **cfg)
        arrays = load_extra_arrays(ipath)
        for t in extra.get("tenants", []):
            m = arrays.get(f"tenant.{t}")
            if m is not None:
                fe.tenants.register(t, mask=m.astype(bool))
        return fe
