"""Fan-out target health: per-target circuit breakers (DESIGN.md §3.13).

A production ANN serving tier fans requests out — to replicas (the
data-parallel axis, serve/frontend.py) or to database shards (the
shard-parallel axis, core/distributed.py). Either kind of target can go
bad, and the two failure-handling mistakes are symmetric: keep sending
to a dead target (every request eats a timeout) or drop a target forever
on one blip (capacity never comes back). The classic answer is a
**circuit breaker** per target:

    CLOSED ──(fail_threshold consecutive failures)──▶ OPEN
    OPEN   ──(reset_after_s elapsed)──▶ HALF_OPEN (admit ONE probe)
    HALF_OPEN ──success──▶ CLOSED          ──failure──▶ OPEN (re-arm)

`CircuitBreaker` is the single-target state machine; `HealthTracker`
holds one per named target and renders the healthy set as the `(D,)`
uint8 mask the degraded distributed search paths consume
(`make_distributed_search(..., with_health=True)`) and as the
allow/deny gate the front-end's replica fan-out consults before
dispatching.

Determinism: the clock is injectable (`clock=`), so the chaos tests
(tests/test_resilience.py) walk the state machine with a fake clock
instead of sleeping — the same discipline as the byte-exact crash
matrix of §3.11. Thread safety: the front-end records outcomes from its
dispatcher thread while stats readers poll from others; all state flips
happen under a lock.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Iterable, Optional, Tuple

import numpy as np

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


class CircuitBreaker:
    """Per-target circuit breaker (state machine above).

    `allow()` is the dispatch gate: True in CLOSED, False in OPEN until
    `reset_after_s` has elapsed since the trip, and True exactly ONCE
    per reset window in HALF_OPEN (the probe request — concurrent
    callers during a probe are denied, so a struggling target sees one
    request, not a thundering herd). Callers report the outcome of every
    allowed dispatch via `record_success` / `record_failure`.
    """

    def __init__(self, *, fail_threshold: int = 3,
                 reset_after_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        if reset_after_s <= 0:
            raise ValueError("reset_after_s must be positive")
        self.fail_threshold = int(fail_threshold)
        self.reset_after_s = float(reset_after_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = CLOSED
        self._consecutive_failures = 0
        self._opened_at = 0.0
        self._probe_out = False

    @property
    def state(self) -> str:
        with self._lock:
            return self._peek_state()

    def _peek_state(self) -> str:
        """Lock held: OPEN decays to HALF_OPEN once the window elapses."""
        if (self._state == OPEN
                and self._clock() - self._opened_at >= self.reset_after_s):
            self._state = HALF_OPEN
            self._probe_out = False
        return self._state

    def allow(self) -> bool:
        with self._lock:
            s = self._peek_state()
            if s == CLOSED:
                return True
            if s == HALF_OPEN and not self._probe_out:
                self._probe_out = True     # exactly one probe per window
                return True
            return False

    def record_success(self):
        with self._lock:
            self._state = CLOSED
            self._consecutive_failures = 0
            self._probe_out = False

    def record_failure(self):
        with self._lock:
            s = self._peek_state()
            if s == HALF_OPEN:
                self._trip()               # failed probe re-arms the window
                return
            self._consecutive_failures += 1
            if self._consecutive_failures >= self.fail_threshold:
                self._trip()

    def _trip(self):
        self._state = OPEN
        self._opened_at = self._clock()
        self._consecutive_failures = 0
        self._probe_out = False


class HealthTracker:
    """Named-target health registry: one lazily-created CircuitBreaker
    per target (shard index, "replica", ...), plus the mask/shards_ok
    renderings the degraded fan-out paths consume."""

    def __init__(self, *, fail_threshold: int = 3,
                 reset_after_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.fail_threshold = fail_threshold
        self.reset_after_s = reset_after_s
        self._clock = clock
        self._lock = threading.Lock()
        self._breakers: Dict = {}

    def _breaker(self, target) -> CircuitBreaker:
        with self._lock:
            b = self._breakers.get(target)
            if b is None:
                b = self._breakers[target] = CircuitBreaker(
                    fail_threshold=self.fail_threshold,
                    reset_after_s=self.reset_after_s, clock=self._clock)
            return b

    def allow(self, target) -> bool:
        return self._breaker(target).allow()

    def success(self, target):
        self._breaker(target).record_success()

    def failure(self, target):
        self._breaker(target).record_failure()

    def state(self, target) -> str:
        return self._breaker(target).state

    def healthy(self, targets: Iterable) -> Tuple:
        """The subset of `targets` currently allowed (consumes the
        half-open probe slot of any target it admits)."""
        return tuple(t for t in targets if self.allow(t))

    def mask(self, n_targets: int,
             ok: Optional[Iterable[int]] = None) -> np.ndarray:
        """(n_targets,) uint8 health bitmap over integer targets 0..n-1
        for the `with_health=True` distributed search paths. `ok`
        overrides the breaker query (e.g. a precomputed healthy set, so
        one mask serves a whole batch without consuming extra half-open
        probe slots)."""
        ok = self.healthy(range(n_targets)) if ok is None else ok
        m = np.zeros(n_targets, np.uint8)
        for t in ok:
            m[int(t)] = 1
        return m

    def snapshot(self) -> Dict:
        """target -> state, for stats/debugging."""
        with self._lock:
            items = list(self._breakers.items())
        return {t: b.state for t, b in items}


def shards_ok_from_mask(mask) -> Tuple[int, ...]:
    """The SearchResult.shards_ok rendering of a health mask."""
    return tuple(int(i) for i in np.flatnonzero(np.asarray(mask) > 0))
