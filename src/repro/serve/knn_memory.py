"""SOAR-backed kNN attention memory — the paper's technique as a first-class
LM-serving feature (the paper itself cites memorizing transformers [17] as a
driving application).

For very long contexts, instead of attending densely over the whole KV
cache, each query retrieves its top-k keys from a SOAR IVF index built over
the cached keys and attends only to those (+ a local window). Attention is
MIPS over keys — exactly the workload SOAR accelerates — and the spilled
assignment rescues the high-<q,r> keys a single-partition index misses,
which for attention are precisely the high-score (most important) keys.

The index is MUTABLE (core/mutable.py): decode appends fresh KV pairs with
`add` (incremental SOAR assignment against the frozen codebook — no
retrain), and cache eviction tombstones them with `remove`. Retrieval
serves from cached snapshots invalidated by mutation; snapshot rebuild is
O(index), so batch mutations between retrievals (append a decode window at
a time) — per-step add+retrieve pays a full repack each step (incremental
delta packing is a ROADMAP item).

This module is the serving-side integration; examples/knn_memory_decode.py
demonstrates it end-to-end and tests/test_knn_memory.py validates retrieval
quality (attention-output error vs exact attention).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import build_ivf
from repro.core.mutable import MutableIVF, _grow_rows
from repro.core.search import search_jit_batched, search_numpy
from repro.serve.api import (DEFAULT_TOP_T, SearchParams, SearchResult,
                             validate_queries)


@dataclass
class KNNMemory:
    """Per-(layer, head) SOAR index over cached keys.

    `engine` picks the retrieval path: "numpy" (host-orchestrated ragged
    engine over the CSR snapshot) or "jit" (the candidate-local
    fixed-budget pipeline over the packed snapshot, streamed in bq-tiles —
    the TPU-target path; see DESIGN.md §3.6). Both dedup spilled candidates
    window-locally, so retrieval cost never scales with the number of
    cached keys beyond the probed partitions.

    `values` is a capacity buffer grown geometrically in lockstep with the
    index's id space (decode appends one position per step — appends must
    be amortized O(batch), not O(n_total)); rows at or beyond
    `index.n_total` are unused capacity.

    Retrieval takes kNN-attention-shaped subset filters (DESIGN.md §3.9):
    a `recency` window (ids are append-ordered, so the last W positions are
    exactly the id range [n_total - W, n_total)), a per-sequence `segment`
    label recorded at `add` time (multi-sequence batches sharing one
    memory must not attend across sequences), and a raw `filter_mask`.
    All compose with each other and with the index's standing tombstone
    filter, on both engines.
    """
    index: MutableIVF
    values: np.ndarray    # (>= n_total, hd) capacity buffer, see above
    engine: str = "numpy"
    segments: Optional[np.ndarray] = None   # (>= n_total,) i32 label per id
    # probe budget when a retrieve passes none: the shared serving default
    # (serve/api.py) — retrieve() historically hardcoded 4 against
    # AnnEngine's 8, a silent quality divergence between the two edges
    top_t: int = DEFAULT_TOP_T

    @classmethod
    def build(cls, keys: np.ndarray, values: np.ndarray,
              n_partitions: Optional[int] = None, lam: float = 1.0,
              spill_mode: str = "soar", seed: int = 0,
              engine: str = "numpy", segment: int = 0,
              router=None, router_kw=None):
        """router: probe-stage router spec (core/router.py) — "tree"
        trains a two-level centroid router at build; every retrieve on
        both engines then probes through it (the snapshots carry it)."""
        n = keys.shape[0]
        c = max(4, n // 256) if n_partitions is None else int(n_partitions)
        idx = build_ivf(jax.random.PRNGKey(seed), keys, c,
                        spill_mode=spill_mode, lam=lam, train_iters=6,
                        router=router, router_kw=router_kw)
        return cls(MutableIVF.from_index(idx),
                   np.array(values, np.float32), engine=engine,
                   segments=np.full(n, segment, np.int32))

    @property
    def keys(self) -> np.ndarray:
        """Cached keys by id — the index's rerank array IS the key store."""
        return self.index.rerank[:self.index.n_total]

    def add(self, keys: np.ndarray, values: np.ndarray,
            segment: int = 0) -> np.ndarray:
        """Append fresh KV pairs (e.g. newly decoded positions); returns
        their stable ids. Assignment is incremental — the codebook trained
        at build time stays frozen (DESIGN.md §3.7). `segment` labels the
        batch for per-sequence retrieval filtering."""
        keys = np.atleast_2d(np.asarray(keys, np.float32))
        values = np.atleast_2d(np.asarray(values, np.float32))
        assert keys.shape[0] == values.shape[0]
        ids = self.index.add(keys)
        self.values = _grow_rows(self.values, self.index.n_total, 0.0)
        self.values[ids] = values
        if self.segments is None:
            self.segments = np.zeros(self.index.n_total, np.int32)
        self.segments = _grow_rows(self.segments, self.index.n_total, -1)
        self.segments[ids] = segment
        return ids

    def remove(self, ids, hard: bool = True) -> int:
        """Evict cached positions (tombstone; ids stay stable). hard=False
        defers slot reclamation to the standing filter bitmap — the cheap
        choice for per-step eviction inside a decode loop."""
        return self.index.remove(ids, hard=hard)

    def _serving_filter(self, recency, segment, filter_mask):
        """Compose recency window / segment label / user bitmap with the
        index's standing tombstone filter; None when retrieval can stay on
        the unfiltered fast path."""
        if (recency is None and segment is None and filter_mask is None
                and not self.index.n_soft_deleted):
            return None
        out = self.index.filter_bitmap(mask=filter_mask)
        nt = self.index.n_total
        if recency is not None:
            out[:max(0, nt - int(recency))] = 0
        if segment is not None:
            seg = np.full(out.shape[0], -1, np.int32)
            if self.segments is not None:
                w = min(self.segments.shape[0], out.shape[0])
                seg[:w] = self.segments[:w]
            out &= (seg == segment)
        return out

    def retrieve(self, q: np.ndarray, k: int = 32,
                 top_t: Optional[int] = None,
                 recency: Optional[int] = None,
                 segment: Optional[int] = None,
                 filter_mask: Optional[np.ndarray] = None,
                 escalate: bool = True):
        """q: (nq, hd) queries → (ids (nq,k), keys, values).

        Thin shim over the unified request API (serve/api.py, DESIGN.md
        §3.12): builds a SearchParams and routes through
        `retrieve_request` — bitwise identical either way (pinned by
        tests/test_serve_api.py). top_t=None resolves to `self.top_t`
        (the shared serving default; the historical hardcoded 4 diverged
        from AnnEngine's 8).

        recency: only attend over the last `recency` cached positions;
        segment: only over positions added with that segment label;
        filter_mask: arbitrary (n_total,)-prefix bitmap. Any combination;
        escalate=False skips the thin-window re-probe (search.py §3.9).
        """
        r, K, V = self.retrieve_request(q, SearchParams(
            k=k, top_t=top_t, recency=recency, segment=segment,
            filter_mask=filter_mask, escalate=escalate))
        return r.ids, K, V

    def retrieve_request(self, q: np.ndarray,
                         params: Optional[SearchParams] = None):
        """Structured retrieval: (SearchResult, keys, values).

        Hardened serving edge (DESIGN.md §3.11), same contract as
        AnnEngine.search_request and the same shared validation path
        (SearchParams.validate + validate_queries): k/top_t must be
        positive ints (an explicit top_t=0 raises instead of silently
        retrieving nothing), queries are dtype/shape/finiteness-checked.
        `scores` on the result is None for the numpy engine (the host
        path never computes final scores).
        """
        p = (params or SearchParams()).validate(default_top_t=self.top_t)
        k, top_t = p.k, p.top_t
        recency, segment = p.recency, p.segment
        filter_mask, escalate = p.filter_mask, p.escalate
        q = validate_queries(q, self.index.centroids.shape[1],
                             sanitize=p.sanitize)
        vals = None
        if self.engine == "jit":
            from repro.core.search import pad_queries
            if (recency is None and segment is None and filter_mask is None):
                # standing soft-tombstone filter only: cached device
                # bitmap, and no escalation pass unless it is actually thin
                f, escalate = self.index.serving_filter(escalate=escalate)
            else:
                f = jnp.asarray(self._serving_filter(recency, segment,
                                                     filter_mask))
            # pad to the bucket before the jit boundary (a per-decode-step
            # ragged nq must not compile one executable per batch size)
            qp, nq, bq = pad_queries(q, 128)
            jids, jvals = search_jit_batched(
                self.index.pack(), jnp.asarray(qp), top_t=top_t,
                final_k=k, rerank_budget=max(4 * k, 64), bq=bq,
                multiplicity=1 + max(self.index.n_spills, 1),
                filter=f, escalate=escalate)
            ids = np.asarray(jids)[:nq]
            vals = np.asarray(jvals)[:nq]
        else:
            filt = self._serving_filter(recency, segment, filter_mask)
            ids, _ = search_numpy(
                self.index.to_ivf_index(), q, top_t=top_t, final_k=k,
                filter_mask=(filt[:self.index.n_total]
                             if filt is not None else None),
                escalate=escalate)
        safe = np.maximum(ids, 0)
        result = SearchResult(
            ids, vals, batch_size=int(ids.shape[0]),
            escalated=bool(escalate),
            epoch=getattr(self.index, "_alive_epoch", -1))
        return result, self.keys[safe], self.values[safe]

    # ---------------------------------------------------------- durability
    def save(self, path: str):
        """Atomic versioned snapshot of the whole memory — index (with
        tombstone state + router), value buffer, per-id segment labels,
        engine choice (DESIGN.md §3.11)."""
        from repro.ckpt.index_store import save_snapshot
        save_snapshot(path, self)

    @classmethod
    def open(cls, path: str) -> "KNNMemory":
        """Reload a saved memory; retrieval over the reopened object is
        bitwise identical to the saved one (integrity-checked load —
        CorruptSnapshotError on any torn/flipped byte)."""
        from repro.ckpt.index_store import load_snapshot
        mem, _ = load_snapshot(path, expect_kind="KNNMemory")
        return mem

    def attend(self, q: np.ndarray, k: int = 32,
               top_t: Optional[int] = None,
               recency: Optional[int] = None, segment: Optional[int] = None,
               filter_mask: Optional[np.ndarray] = None,
               escalate: bool = True):
        """Approximate attention output for each query over retrieved keys.

        Returns (out (nq, hd), ids). Softmax over the retrieved set only —
        the memorizing-transformer approximation. Filter kwargs as in
        `retrieve` (top_t=None → the shared serving default, see
        `retrieve`), e.g. recency-window kNN attention.
        """
        ids, K, V = self.retrieve(q, k=k, top_t=top_t, recency=recency,
                                  segment=segment, filter_mask=filter_mask,
                                  escalate=escalate)
        logits = np.einsum("qd,qkd->qk", q, K) / np.sqrt(q.shape[-1])
        logits[ids < 0] = -1e30
        w = np.exp(logits - logits.max(axis=1, keepdims=True))
        # hard-mask padding so a query with NO retrieved keys (e.g. after
        # full eviction) yields a zero output, not a uniform mix of row 0
        w *= ids >= 0
        w /= np.maximum(w.sum(axis=1, keepdims=True), 1e-30)
        return np.einsum("qk,qkd->qd", w, V), ids


def exact_topk_attention(q, keys, values, k: int):
    """Oracle: attention over the true top-k keys (for quality evaluation)."""
    logits = q @ keys.T / np.sqrt(q.shape[-1])
    idx = np.argpartition(-logits, k - 1, axis=1)[:, :k]
    sel = np.take_along_axis(logits, idx, axis=1)
    w = np.exp(sel - sel.max(axis=1, keepdims=True))
    w /= w.sum(axis=1, keepdims=True)
    return np.einsum("qk,qkd->qd", w, values[idx]), idx
