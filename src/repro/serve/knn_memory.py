"""SOAR-backed kNN attention memory — the paper's technique as a first-class
LM-serving feature (the paper itself cites memorizing transformers [17] as a
driving application).

For very long contexts, instead of attending densely over the whole KV
cache, each query retrieves its top-k keys from a SOAR IVF index built over
the cached keys and attends only to those (+ a local window). Attention is
MIPS over keys — exactly the workload SOAR accelerates — and the spilled
assignment rescues the high-<q,r> keys a single-partition index misses,
which for attention are precisely the high-score (most important) keys.

The index is MUTABLE (core/mutable.py): decode appends fresh KV pairs with
`add` (incremental SOAR assignment against the frozen codebook — no
retrain), and cache eviction tombstones them with `remove`. Retrieval
serves from cached snapshots invalidated by mutation; snapshot rebuild is
O(index), so batch mutations between retrievals (append a decode window at
a time) — per-step add+retrieve pays a full repack each step (incremental
delta packing is a ROADMAP item).

This module is the serving-side integration; examples/knn_memory_decode.py
demonstrates it end-to-end and tests/test_knn_memory.py validates retrieval
quality (attention-output error vs exact attention).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import build_ivf
from repro.core.mutable import MutableIVF, _grow_rows
from repro.core.search import search_jit_batched, search_numpy


@dataclass
class KNNMemory:
    """Per-(layer, head) SOAR index over cached keys.

    `engine` picks the retrieval path: "numpy" (host-orchestrated ragged
    engine over the CSR snapshot) or "jit" (the candidate-local
    fixed-budget pipeline over the packed snapshot, streamed in bq-tiles —
    the TPU-target path; see DESIGN.md §3.6). Both dedup spilled candidates
    window-locally, so retrieval cost never scales with the number of
    cached keys beyond the probed partitions.

    `values` is a capacity buffer grown geometrically in lockstep with the
    index's id space (decode appends one position per step — appends must
    be amortized O(batch), not O(n_total)); rows at or beyond
    `index.n_total` are unused capacity.
    """
    index: MutableIVF
    values: np.ndarray    # (>= n_total, hd) capacity buffer, see above
    engine: str = "numpy"

    @classmethod
    def build(cls, keys: np.ndarray, values: np.ndarray,
              n_partitions: Optional[int] = None, lam: float = 1.0,
              spill_mode: str = "soar", seed: int = 0,
              engine: str = "numpy"):
        n = keys.shape[0]
        c = n_partitions or max(4, n // 256)
        idx = build_ivf(jax.random.PRNGKey(seed), keys, c,
                        spill_mode=spill_mode, lam=lam, train_iters=6)
        return cls(MutableIVF.from_index(idx),
                   np.array(values, np.float32), engine=engine)

    @property
    def keys(self) -> np.ndarray:
        """Cached keys by id — the index's rerank array IS the key store."""
        return self.index.rerank[:self.index.n_total]

    def add(self, keys: np.ndarray, values: np.ndarray) -> np.ndarray:
        """Append fresh KV pairs (e.g. newly decoded positions); returns
        their stable ids. Assignment is incremental — the codebook trained
        at build time stays frozen (DESIGN.md §3.7)."""
        keys = np.atleast_2d(np.asarray(keys, np.float32))
        values = np.atleast_2d(np.asarray(values, np.float32))
        assert keys.shape[0] == values.shape[0]
        ids = self.index.add(keys)
        self.values = _grow_rows(self.values, self.index.n_total, 0.0)
        self.values[ids] = values
        return ids

    def remove(self, ids) -> int:
        """Evict cached positions (tombstone; ids stay stable)."""
        return self.index.remove(ids)

    def retrieve(self, q: np.ndarray, k: int = 32, top_t: int = 4):
        """q: (nq, hd) queries → (ids (nq,k), keys, values)."""
        if self.engine == "jit":
            jids, _ = search_jit_batched(
                self.index.pack(), jnp.asarray(q, jnp.float32), top_t=top_t,
                final_k=k, rerank_budget=max(4 * k, 64),
                bq=min(128, max(1, q.shape[0])),
                multiplicity=1 + max(self.index.n_spills, 1))
            ids = np.asarray(jids)
        else:
            ids, _ = search_numpy(self.index.to_ivf_index(), q, top_t=top_t,
                                  final_k=k)
        safe = np.maximum(ids, 0)
        return ids, self.keys[safe], self.values[safe]

    def attend(self, q: np.ndarray, k: int = 32, top_t: int = 4):
        """Approximate attention output for each query over retrieved keys.

        Returns (out (nq, hd), ids). Softmax over the retrieved set only —
        the memorizing-transformer approximation.
        """
        ids, K, V = self.retrieve(q, k=k, top_t=top_t)
        logits = np.einsum("qd,qkd->qk", q, K) / np.sqrt(q.shape[-1])
        logits[ids < 0] = -1e30
        w = np.exp(logits - logits.max(axis=1, keepdims=True))
        # hard-mask padding so a query with NO retrieved keys (e.g. after
        # full eviction) yields a zero output, not a uniform mix of row 0
        w *= ids >= 0
        w /= np.maximum(w.sum(axis=1, keepdims=True), 1e-30)
        return np.einsum("qk,qkd->qd", w, V), ids


def exact_topk_attention(q, keys, values, k: int):
    """Oracle: attention over the true top-k keys (for quality evaluation)."""
    logits = q @ keys.T / np.sqrt(q.shape[-1])
    idx = np.argpartition(-logits, k - 1, axis=1)[:, :k]
    sel = np.take_along_axis(logits, idx, axis=1)
    w = np.exp(sel - sel.max(axis=1, keepdims=True))
    w /= w.sum(axis=1, keepdims=True)
    return np.einsum("qk,qkd->qd", w, values[idx]), idx
