"""SOAR-backed kNN attention memory — the paper's technique as a first-class
LM-serving feature (the paper itself cites memorizing transformers [17] as a
driving application).

For very long contexts, instead of attending densely over the whole KV
cache, each query retrieves its top-k keys from a SOAR IVF index built over
the cached keys and attends only to those (+ a local window). Attention is
MIPS over keys — exactly the workload SOAR accelerates — and the spilled
assignment rescues the high-<q,r> keys a single-partition index misses,
which for attention are precisely the high-score (most important) keys.

This module is the serving-side integration; examples/knn_memory_decode.py
demonstrates it end-to-end and tests/test_knn_memory.py validates retrieval
quality (attention-output error vs exact attention).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import build_ivf, IVFIndex
from repro.core.search import (PackedIVF, pack_ivf, search_jit_batched,
                               search_numpy)


@dataclass
class KNNMemory:
    """Per-(layer, head) SOAR index over cached keys.

    `engine` picks the retrieval path: "numpy" (host-orchestrated ragged
    engine) or "jit" (the candidate-local fixed-budget pipeline, streamed in
    bq-tiles — the TPU-target path; see DESIGN.md §3.6). Both dedup spilled
    candidates window-locally, so retrieval cost never scales with the
    number of cached keys beyond the probed partitions.
    """
    index: IVFIndex
    keys: np.ndarray      # (n, hd)
    values: np.ndarray    # (n, hd)
    engine: str = "numpy"
    _packed: Optional[PackedIVF] = field(default=None, repr=False)

    @classmethod
    def build(cls, keys: np.ndarray, values: np.ndarray,
              n_partitions: Optional[int] = None, lam: float = 1.0,
              spill_mode: str = "soar", seed: int = 0,
              engine: str = "numpy"):
        n = keys.shape[0]
        c = n_partitions or max(4, n // 256)
        idx = build_ivf(jax.random.PRNGKey(seed), keys, c,
                        spill_mode=spill_mode, lam=lam, train_iters=6)
        return cls(idx, np.asarray(keys, np.float32),
                   np.asarray(values, np.float32), engine=engine)

    def retrieve(self, q: np.ndarray, k: int = 32, top_t: int = 4):
        """q: (nq, hd) queries → (ids (nq,k), keys, values)."""
        if self.engine == "jit":
            if self._packed is None:
                self._packed = pack_ivf(self.index)
            jids, _ = search_jit_batched(
                self._packed, jnp.asarray(q, jnp.float32), top_t=top_t,
                final_k=k, rerank_budget=max(4 * k, 64),
                bq=min(128, max(1, q.shape[0])))
            ids = np.asarray(jids)
        else:
            ids, _ = search_numpy(self.index, q, top_t=top_t, final_k=k)
        return ids, self.keys[ids], self.values[ids]

    def attend(self, q: np.ndarray, k: int = 32, top_t: int = 4):
        """Approximate attention output for each query over retrieved keys.

        Returns (out (nq, hd), ids). Softmax over the retrieved set only —
        the memorizing-transformer approximation.
        """
        ids, K, V = self.retrieve(q, k=k, top_t=top_t)
        logits = np.einsum("qd,qkd->qk", q, K) / np.sqrt(q.shape[-1])
        logits[ids < 0] = -1e30
        w = np.exp(logits - logits.max(axis=1, keepdims=True))
        w /= w.sum(axis=1, keepdims=True)
        return np.einsum("qk,qkd->qd", w, V), ids


def exact_topk_attention(q, keys, values, k: int):
    """Oracle: attention over the true top-k keys (for quality evaluation)."""
    logits = q @ keys.T / np.sqrt(q.shape[-1])
    idx = np.argpartition(-logits, k - 1, axis=1)[:, :k]
    sel = np.take_along_axis(logits, idx, axis=1)
    w = np.exp(sel - sel.max(axis=1, keepdims=True))
    w /= w.sum(axis=1, keepdims=True)
    return np.einsum("qk,qkd->qd", w, values[idx]), idx
