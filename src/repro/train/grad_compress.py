"""int8-compressed gradient all-reduce with error feedback.

Cross-pod data-parallel gradient reduction is the dominant inter-pod
collective at scale; int8 quantization cuts its bytes 4x (vs f32) at the
cost of quantization noise, which error feedback (residual carried between
steps) removes in expectation (Karimireddy et al., 2019 — "EF-SGD").

`compressed_psum(x, axis)` runs inside shard_map: a two-phase reduce —
shared-scale max (tiny f32 psum) then int32 psum of the quantized values.
Used by launch/train.py --compress-grads for the "pod" axis; validated in
tests/test_grad_compress.py against exact psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compressed_psum(x, axis_name: str):
    """int8 psum over `axis_name` (must run inside shard_map)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = quantize(x, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(x.dtype) * scale


def compressed_psum_with_feedback(x, err, axis_name: str):
    """Error-feedback variant: returns (reduced, new_err).

    new_err is THIS shard's local quantization residual; adding it to the
    next step's local gradient makes the long-run average unbiased.
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(x + err)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    corrected = x + err
    q = quantize(corrected, scale)
    local_deq = q.astype(x.dtype) * scale
    new_err = corrected - local_deq
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(x.dtype) * scale, new_err
