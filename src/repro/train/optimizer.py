"""AdamW + global-norm clipping + warmup-cosine schedule (no optax dep).

Optimizer state mirrors the param pytree → it inherits the params' sharding
(FSDP'd optimizer state for free — ZeRO-style, see DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), z,
                      jax.tree.map(jnp.zeros_like, params))


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * (step + 1) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, lr_fn, *, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    """Returns (new_params, new_state, metrics)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vo, g: b2 * vo + (1 - b2) * jnp.square(g),
                     state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_fn(state.step)

    def upd(p, mo, vo):
        mhat = mo / bc1
        vhat = vo / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), {
        "grad_norm": gn, "lr": lr}
