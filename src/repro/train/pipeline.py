"""Pipeline parallelism over the "pod" axis — GPipe-style microbatch
pipelining expressed with shard_map + lax.ppermute.

Each pod is one stage holding half the layer groups. All stages run the
same program; activations flow stage→stage through a differentiable
ppermute (its transpose is the reverse permute, so jax.grad generates the
reverse pipeline automatically). The schedule is the classic loop-pipeline:
steps = M + n_stages − 1; stage s works on microbatch t − s at step t, with
validity masks for the fill/drain bubbles.

This is the optional `--pipeline` path (DESIGN.md §6): the cross-pod
traffic per step is one (micro_B, S, d) activation instead of the full
gradient all-reduce, which is the right trade when inter-pod bandwidth is
the binding constraint. Validated bit-for-bit against the non-pipelined
model in tests/test_pipeline.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm, softmax_xent


def stack_stage_params(params, cfg: ModelConfig, n_stages: int = 2):
    """Split the group stack into per-stage halves and stack EVERYTHING over
    a leading stage dim (each stage receives its own slice via shard_map).
    Non-group params (embed/head/final_norm) are replicated per stage; only
    stage 0 uses embed, only the last stage uses head/final_norm."""
    G = cfg.n_groups
    assert G % n_stages == 0
    per = G // n_stages

    def split_groups(a):
        return a.reshape((n_stages, per) + a.shape[1:])

    stacked = {
        "groups": jax.tree.map(split_groups, params["groups"]),
        "final_norm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_stages,) + a.shape),
            params["final_norm"]),
        "head": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_stages,) + a.shape),
            params["head"]),
        "embed": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_stages,) + a.shape),
            params["embed"]),
    }
    return stacked


def make_pipelined_loss(cfg: ModelConfig, mesh, n_stages: int = 2,
                        stage_axis: str = "pod"):
    """Returns fn(stage_params, batch) → mean loss.

    batch tokens/labels: (M, micro_B, S) — M microbatches.
    """

    def stage_forward(gp, x):
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])

        def body(xc, g):
            xc, _ = T._apply_group(g, xc, positions, cfg, "causal",
                                   None, None)
            return xc, 0

        x, _ = jax.lax.scan(body, x, gp)
        return x

    def pipelined(stage_params, tokens, labels):
        # inside shard_map: leading stage dim is 1 — squeeze it
        sp = jax.tree.map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index(stage_axis)
        M, mb, S = tokens.shape
        steps = M + n_stages - 1
        d = cfg.d_model
        dt = jnp.dtype(cfg.compute_dtype)

        def step(carry, t):
            recv, loss_sum, n_loss = carry
            # stage 0 ingests microbatch t (clamped; masked when invalid)
            tok_t = jax.lax.dynamic_index_in_dim(
                tokens, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            x0 = T.embed(sp["embed"], tok_t, cfg)
            x_in = jnp.where(stage == 0, x0.astype(dt), recv.astype(dt))
            y = stage_forward(sp["groups"], x_in)
            # last stage: loss for microbatch t-(n_stages-1)
            mb_idx = t - (n_stages - 1)
            lbl = jax.lax.dynamic_index_in_dim(
                labels, jnp.clip(mb_idx, 0, M - 1), 0, keepdims=False)
            h = rmsnorm(sp["final_norm"], y, cfg.norm_eps)
            logits = jnp.einsum("bsd,dv->bsv", h,
                                sp["head"]["w"].astype(h.dtype))
            losses = softmax_xent(logits, lbl, cfg.vocab_size)
            valid = ((stage == n_stages - 1) & (mb_idx >= 0)
                     & (mb_idx < M)).astype(jnp.float32)
            loss_sum = loss_sum + valid * jnp.mean(losses)
            n_loss = n_loss + valid
            # hand activations to the next stage (cyclic; last→0 is unused)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            sent = jax.lax.ppermute(y, stage_axis, perm)
            return (sent, loss_sum, n_loss), None

        init = (jnp.zeros((mb, S, d), dt), jnp.zeros(()), jnp.zeros(()))
        (_, loss_sum, n_loss), _ = jax.lax.scan(
            step, init, jnp.arange(steps))
        # share the last stage's mean loss with everyone
        total = jax.lax.psum(loss_sum, stage_axis)
        count = jax.lax.psum(n_loss, stage_axis)
        return total / jnp.maximum(count, 1.0)

    from jax.experimental.shard_map import shard_map
    return shard_map(
        pipelined, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(stage_axis), {"groups": 0,
                                                         "final_norm": 0,
                                                         "head": 0,
                                                         "embed": 0}),
                  P(), P()),
        out_specs=P(), check_rep=False)


def pipelined_loss_and_grad(cfg: ModelConfig, mesh, stage_params, tokens,
                            labels, n_stages: int = 2):
    fn = make_pipelined_loss(cfg, mesh, n_stages=n_stages)

    def wrapped(sp):
        return fn(sp, tokens, labels)

    return jax.value_and_grad(wrapped)(stage_params)
