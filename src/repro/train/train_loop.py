"""Training step factory + driver loop.

- Gradient accumulation: the global batch is split into `accum` microbatches
  scanned inside the jit'd step (bounds activation memory; under pjit the
  per-microbatch gradient psum overlaps the next microbatch's backward —
  the standard compute/comm overlap).
- Fault tolerance: CheckpointManager integration, preemption-safe saves
  (SIGTERM → save-and-exit), step watchdog (straggler surfacing), and
  deterministic data resume from the step counter alone.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import optimizer as opt


def make_train_step(cfg: ModelConfig, lr_fn, accum: int = 1,
                    weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns jit-able fn(params, opt_state, batch) → (params, state, metrics)."""

    def micro_loss(params, micro):
        return T.loss_fn(params, micro, cfg)

    def step_fn(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(micro_loss)(params, batch)
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
            micros = jax.tree.map(split, batch)

            def body(carry, micro):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(micro_loss)(params, micro)
                return (acc_loss + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            zero = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zero), micros)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        params, opt_state, metrics = opt.update(
            grads, opt_state, params, lr_fn,
            weight_decay=weight_decay, clip_norm=clip_norm)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step_fn


class Watchdog:
    """Surfaces straggling steps (the single-process analogue of per-host
    heartbeat monitoring): if a step exceeds `factor`× the running median,
    it is logged; the callback can trigger checkpoint+respawn at scale."""

    def __init__(self, factor: float = 3.0, warn=print):
        self.durations = []
        self.factor = factor
        self.warn = warn

    def observe(self, dt: float, step: int):
        if len(self.durations) >= 5:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > self.factor * med:
                self.warn(f"[watchdog] step {step} took {dt:.2f}s "
                          f"(median {med:.2f}s) — straggler suspected")
        self.durations.append(dt)
        if len(self.durations) > 100:
            self.durations.pop(0)


def train(cfg: ModelConfig, pipeline, steps: int, lr: float = 3e-4,
          accum: int = 1, ckpt_manager=None, ckpt_every: int = 100,
          log_every: int = 10, params=None, seed: int = 0,
          on_log: Optional[Callable] = None):
    """CPU-runnable end-to-end driver (used by examples/train_lm.py)."""
    lr_fn = opt.warmup_cosine(lr, warmup=max(steps // 20, 10), total=steps)
    step_fn = jax.jit(make_train_step(cfg, lr_fn, accum=accum),
                      donate_argnums=(0, 1))

    start_step = 0
    opt_state = None
    if ckpt_manager is not None and ckpt_manager.latest_step() is not None:
        params, opt_state, start_step = ckpt_manager.restore_train_state(cfg)
        print(f"[train] resumed from step {start_step}")
    if params is None:
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
    if opt_state is None:
        opt_state = opt.init(params)

    preempted = {"flag": False}

    def _on_term(sig, frame):
        preempted["flag"] = True
    try:
        signal.signal(signal.SIGTERM, _on_term)
    except ValueError:
        pass   # non-main thread (tests)

    wd = Watchdog()
    losses = []
    for step in range(start_step, steps):
        t0 = time.time()
        batch = pipeline.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        wd.observe(dt, step)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            msg = (f"step {step:5d} loss {loss:.4f} "
                   f"gnorm {float(metrics['grad_norm']):.3f} "
                   f"lr {float(metrics['lr']):.2e} {dt:.2f}s")
            print(msg)
            if on_log:
                on_log(step, metrics)
        should_ckpt = (ckpt_manager is not None
                       and (step % ckpt_every == 0 or step == steps - 1
                            or preempted["flag"]))
        if should_ckpt:
            ckpt_manager.save_train_state(step + 1, params, opt_state)
        if preempted["flag"]:
            print(f"[train] preemption signal → saved at step {step}, exiting")
            break
    return params, opt_state, losses
