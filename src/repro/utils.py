"""Shared helpers: chunked linear algebra, padding, pytree utilities."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0, value=0):
    """Pad `axis` of x up to a multiple; returns (padded, original_len)."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), n


def chunked_map(fn, x: jax.Array, chunk: int):
    """Apply fn over chunks of x's leading axis via lax.map (bounded memory).

    fn must be shape-polymorphic only in outputs' leading axis == chunk.
    Returns outputs with padding stripped.
    """
    xp, n = pad_to_multiple(x, chunk, axis=0)
    xc = xp.reshape((-1, chunk) + xp.shape[1:])
    out = jax.lax.map(fn, xc)
    out = jax.tree.map(lambda o: o.reshape((-1,) + o.shape[2:])[:n], out)
    return out


@functools.partial(jax.jit, static_argnames=("chunk",))
def pairwise_neg_sqdist_argmin(X, C, chunk: int = 16384):
    """argmin_j ||x_i - c_j||^2 and the min value, chunked over rows of X."""
    Cn = jnp.sum(C * C, axis=-1)

    def f(xb):
        s = xb @ C.T
        d = Cn[None, :] - 2.0 * s  # ||x||^2 dropped (const per row)
        idx = jnp.argmin(d, axis=-1)
        xn = jnp.sum(xb * xb, axis=-1)
        return idx.astype(jnp.int32), jnp.take_along_axis(d, idx[:, None], axis=-1)[:, 0] + xn

    return chunked_map(f, X, chunk)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def topk_inner_product(Q, X, k: int, chunk: int = 8192):
    """Exact MIPS top-k of each query in Q against X, chunked over X.

    Returns (values (nq,k), indices (nq,k)). Memory bounded by nq*chunk.
    """
    nq = Q.shape[0]
    n = X.shape[0]
    Xp, _ = pad_to_multiple(X, chunk, axis=0)
    nchunks = Xp.shape[0] // chunk

    def body(carry, i):
        bv, bi = carry
        xb = jax.lax.dynamic_slice_in_dim(Xp, i * chunk, chunk, axis=0)
        s = Q @ xb.T  # (nq, chunk)
        base = i * chunk
        idx = base + jnp.arange(chunk, dtype=jnp.int32)
        s = jnp.where(idx[None, :] < n, s, -jnp.inf)
        cv = jnp.concatenate([bv, s], axis=1)
        ci = jnp.concatenate([bi, jnp.broadcast_to(idx[None, :], (nq, chunk))], axis=1)
        v, pos = jax.lax.top_k(cv, k)
        return (v, jnp.take_along_axis(ci, pos, axis=1)), None

    init = (jnp.full((nq, k), -jnp.inf, Q.dtype), jnp.full((nq, k), -1, jnp.int32))
    (v, i), _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    return v, i


def tree_bytes(tree) -> int:
    return sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "shape"))


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))
