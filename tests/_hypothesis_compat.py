"""Optional-hypothesis shim: the seed suite hard-imported `hypothesis` at
module scope, so a container without it failed at COLLECTION and ran zero
tests. Importing `given`/`settings`/`st` from here keeps every non-property
test runnable; when hypothesis is missing, property tests become stubs that
call `pytest.importorskip("hypothesis")` and skip cleanly.

Install the real thing with: pip install -r requirements-dev.txt
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.importorskip("hypothesis")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stand-in: strategy constructors are only evaluated inside @given
        argument lists, whose values are never used once the test skips."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
