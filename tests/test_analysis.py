"""Static contract analyzer self-tests (ISSUE 10, DESIGN.md §3.14).

Every detector must catch its synthetic violation class AND pass the
clean equivalent: O(n) jaxpr intermediate, f64 leak, host-callback
primitive, jit-cache growth, unlocked `_locked` call, int falsy-default,
np.random global state, pickle in ckpt/, unvalidated engine edge — plus
the ratchet-baseline workflow and the CLI exit codes.
"""
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.check import main as check_main
from repro.analysis.contracts import (TraceSpec, check_contract,
                                      jaxpr_contract)
from repro.analysis.findings import (Finding, load_baseline,
                                     partition_findings, save_baseline)
from repro.analysis.jaxpr_walk import (jaxpr_primitives, jaxpr_shapes)
from repro.analysis.lint_ast import lint_source
from repro.analysis.sentinel import CacheWatch

N = 257  # prime, as in the real contracts


def _contract(build, **kw):
    """Register `build` in a throwaway registry, return its findings."""
    reg = {}
    jaxpr_contract("probe", registry=reg, **kw)(build)
    return check_contract(reg["probe"])


# ------------------------------------------------------------ jaxpr walker

def test_walker_matches_legacy_helper_semantics():
    def f(x):
        return jax.lax.scan(lambda c, xi: (c + xi.sum(), xi * 2.0),
                            0.0, x)
    closed = jax.make_jaxpr(f)(jnp.zeros((4, 3)))
    shapes = jaxpr_shapes(closed.jaxpr)
    assert (4, 3) in shapes          # scan-stacked ys, found recursively
    assert () in shapes              # carry


def test_walker_recurses_cond_branches():
    """The legacy copy-pasted helpers missed `branches` tuples — the
    shared walker must see inside lax.cond."""
    def f(x):
        return jax.lax.cond(x.sum() > 0,
                            lambda v: jnp.outer(v, v).sum(),
                            lambda v: v.sum(), x)
    closed = jax.make_jaxpr(f)(jnp.zeros(9))
    assert (9, 9) in jaxpr_shapes(closed.jaxpr)


# ------------------------------------------------------- contract checker

def test_o_n_intermediate_caught():
    def build():
        X = jnp.zeros((N, 8))
        return TraceSpec(fn=lambda x: (x @ x.T).sum(axis=0), args=(X,),
                         dims={"n": N})
    found = _contract(build, no_dims={"n"})
    assert any(f.rule == "jaxpr-dim" for f in found)


def test_candidate_local_equivalent_passes():
    def build():
        X = jnp.zeros((N, 8))
        # candidate-local: only a gathered window ever materializes
        return TraceSpec(
            fn=lambda x: x[:16].sum(axis=1), args=(X,), dims={"n": N})
    assert _contract(build, no_dims={"n"}) == []


def test_leading_n_view_allowed_but_trailing_n_flagged():
    def view(x):
        return (x * 2.0).sum()        # (n, d) elementwise view: legal
    def gram(x):
        return (x.T @ x @ x.T).sum(axis=0)   # (d, n): n trails — illegal
    X = jnp.zeros((N, 4))
    ok = _contract(lambda: TraceSpec(fn=view, args=(X,), dims={"n": N}),
                   no_dims={"n"})
    bad = _contract(lambda: TraceSpec(fn=gram, args=(X,), dims={"n": N}),
                    no_dims={"n"})
    assert ok == [] and any(f.rule == "jaxpr-dim" for f in bad)


def test_f64_leak_caught_and_f32_passes():
    X = jnp.zeros((8, 4), jnp.float32)
    with jax.experimental.enable_x64():
        bad = _contract(lambda: TraceSpec(
            fn=lambda x: x.astype(jnp.float64).sum(), args=(X,), dims={}))
    ok = _contract(lambda: TraceSpec(
        fn=lambda x: (x * 2.0).sum(), args=(X,), dims={}))
    assert any(f.rule == "jaxpr-dtype" for f in bad)
    assert ok == []


def test_host_callback_primitive_caught():
    def noisy(x):
        jax.debug.print("x={x}", x=x.sum())
        return x * 2.0
    X = jnp.zeros(4)
    found = _contract(lambda: TraceSpec(fn=noisy, args=(X,), dims={}))
    assert any(f.rule == "jaxpr-callback" for f in found)
    closed = jax.make_jaxpr(noisy)(X)
    assert "debug_callback" in jaxpr_primitives(closed.jaxpr)


def test_cache_growth_contract_caught_and_stable_passes():
    @jax.jit
    def toy(x):
        return (x * 2.0).sum()

    calls = {"n": 0}

    def storm():
        calls["n"] += 1
        toy(jnp.zeros(calls["n"]))   # fresh shape every call → recompiles

    bad = _contract(lambda: TraceSpec(
        fn=lambda x: x.sum(), args=(jnp.zeros(3),), dims={},
        jit_fn=toy, call=storm))
    ok = _contract(lambda: TraceSpec(
        fn=lambda x: x.sum(), args=(jnp.zeros(3),), dims={},
        jit_fn=toy, call=lambda: toy(jnp.zeros(7))))
    assert any(f.rule == "cache-growth" for f in bad)
    assert not any(f.rule == "cache-growth" for f in ok)


# ---------------------------------------------------- recompile sentinel

def test_cache_watch_flags_recompile_storm():
    @jax.jit
    def toy(x):
        return x + 1.0

    toy(jnp.zeros(1))
    with pytest.raises(AssertionError, match="cache grew"):
        with CacheWatch(toy):
            for nq in range(2, 6):     # per-shape traces: the storm
                toy(jnp.zeros(nq))


def test_cache_watch_passes_bucketed_traffic():
    @jax.jit
    def toy(x):
        return x + 1.0

    toy(jnp.zeros(8))                  # warm the single bucket
    with CacheWatch(toy):
        for _ in range(5):
            toy(jnp.zeros(8))


# ------------------------------------------------------------- AST lints

SERVE = "src/repro/serve/_synthetic.py"
CORE = "src/repro/core/_synthetic.py"
CKPT = "src/repro/ckpt/_synthetic.py"


def _rules(src, relpath):
    return {f.rule for f in lint_source(textwrap.dedent(src), relpath)}


def test_unlocked_call_caught_and_locked_passes():
    bad = """\
        class F:
            def poll(self):
                self._expire_locked()
    """
    ok = """\
        class F:
            def poll(self):
                with self._cond:
                    self._expire_locked()

            def _admit_locked(self):
                self._expire_locked()   # caller holds the lock
    """
    assert "lock-discipline" in _rules(bad, SERVE)
    assert "lock-discipline" not in _rules(ok, SERVE)


def test_falsy_int_default_caught_and_sentinel_passes():
    assert "falsy-int-default" in _rules(
        "def f(self, top_t=None):\n    return top_t or self.top_t\n", CORE)
    assert "falsy-int-default" in _rules(
        "def f(c=None, n=0):\n    return c or max(4, n // 256)\n", CORE)
    assert "falsy-int-default" not in _rules(
        "def f(self, top_t=None):\n"
        "    return self.top_t if top_t is None else top_t\n", CORE)
    # string coalescing is NOT the int bug class
    assert "falsy-int-default" not in _rules(
        "def f(name=None):\n    return name or 'default'\n", CORE)


def test_np_random_global_caught_and_generator_passes():
    assert "np-random-global" in _rules(
        "import numpy as np\nx = np.random.randint(0, 4)\n", CORE)
    assert "np-random-global" not in _rules(
        "import numpy as np\nrng = np.random.default_rng(0)\n", CORE)


def test_pickle_in_ckpt_caught():
    assert "pickle-ckpt" in _rules("import pickle\n", CKPT)
    assert "pickle-ckpt" in _rules(
        "import numpy as np\nx = np.load('f.npy', allow_pickle=True)\n",
        CKPT)
    # pickle outside the durability layer is some other module's business
    assert "pickle-ckpt" not in _rules("import pickle\n", CORE)


def test_validate_routing_transitive_and_missing():
    ok = """\
        class Engine:
            def search(self, Q):
                return self.search_request(Q)

            def search_request(self, Q, params=None):
                p = (params or SearchParams()).validate()
                return p
    """
    bad = """\
        class Engine:
            def search(self, Q, k=10):
                return self._go(Q, k)

            def _go(self, Q, k):
                return Q[:k]
    """
    assert "validate-routing" not in _rules(ok, SERVE)
    assert "validate-routing" in _rules(bad, SERVE)


# ------------------------------------------------------- ratchet baseline

def test_baseline_grandfathers_by_fingerprint(tmp_path):
    f_old = Finding("falsy-int-default", "src/repro/x.py", "m", line=10,
                    context="f", snippet="a or 1")
    f_new = Finding("falsy-int-default", "src/repro/x.py", "m", line=20,
                    context="g", snippet="b or 2")
    path = str(tmp_path / "baseline.json")
    save_baseline([f_old], path)
    bl = load_baseline(path)
    new, old = partition_findings([f_old, f_new], bl)
    assert old == [f_old] and new == [f_new]
    # line drift does not resurrect a grandfathered finding
    moved = Finding("falsy-int-default", "src/repro/x.py", "m", line=99,
                    context="f", snippet="a or 1")
    assert moved in bl


def test_empty_baseline_blocks_everything(tmp_path):
    bl = load_baseline(str(tmp_path / "missing.json"))
    f = Finding("lock-discipline", "src/repro/serve/x.py", "m")
    new, old = partition_findings([f], bl)
    assert new == [f] and old == []


# -------------------------------------------------------------------- CLI

def test_cli_lint_pass_clean_on_repo():
    assert check_main(["--only", "lint", "-q"]) == 0


@pytest.mark.parametrize("cls", ["o-n-intermediate", "f64-leak",
                                 "cache-growth", "unlocked-call",
                                 "falsy-default"])
def test_cli_injected_violations_exit_nonzero(cls):
    assert check_main(["--only", "lint", "--inject", cls, "-q"]) != 0


def test_cli_one_real_contract_runs_clean():
    # lloyd_sweep: the cheapest registered contract (no index build)
    from repro.analysis.contracts import REGISTRY
    assert check_contract(REGISTRY["lloyd_sweep"]) == []
