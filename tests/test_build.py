"""Sharded build driver (core/build.py) + fused assignment path.

The load-bearing property: the streamed, O(shard)-memory pipeline is
BITWISE-identical to the monolithic `build_ivf` when the codebook trains on
the full data — sharding must be a memory layout choice, never a quality
knob. (Exactness holds because per-row GEMM results are tile-shape
independent on XLA; the fused path reuses the literal loss expressions of
core/soar.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_ivf, build_ivf_sharded
from repro.core.build import assign_shards, spill_plan, train_codebook
from repro.core.kmeans import assign_euclidean
from repro.core.soar import soar_assign, soar_assign_multi
from repro.data.vectors import make_manifold
from repro.kernels.soar_assign import assign_fused


@pytest.fixture(scope="module")
def ds():
    return make_manifold(jax.random.PRNGKey(0), n=4000, d=24, nq=16,
                         intrinsic_dim=6)


@pytest.fixture(scope="module")
def codebook(ds):
    return train_codebook(jax.random.PRNGKey(3), ds.X, 32, train_iters=4)


def test_fused_assign_matches_composition(ds, codebook):
    """assign_fused == (assign_euclidean, soar_assign) exactly."""
    X, C = jnp.asarray(ds.X), jnp.asarray(codebook)
    prim = assign_euclidean(X, C, chunk=8192)
    sec = soar_assign(X, C, prim, lam=1.3, chunk=8192)
    A = np.asarray(assign_fused(X, C, lam=1.3, n_spills=1, chunk=8192))
    assert np.array_equal(A[:, 0], np.asarray(prim))
    assert np.array_equal(A[:, 1], np.asarray(sec))


def test_fused_assign_multi_matches_soar_multi(ds, codebook):
    X, C = jnp.asarray(ds.X), jnp.asarray(codebook)
    prim = assign_euclidean(X, C, chunk=8192)
    want = np.asarray(soar_assign_multi(X, C, prim, lam=1.0, n_spills=3,
                                        chunk=8192))
    got = np.asarray(assign_fused(X, C, lam=1.0, n_spills=3, chunk=8192))
    assert np.array_equal(got, want)


def test_fused_assign_no_spill(ds, codebook):
    A = np.asarray(assign_fused(ds.X, codebook, n_spills=0))
    assert A.shape == (ds.X.shape[0], 1)
    prim = np.asarray(assign_euclidean(jnp.asarray(ds.X),
                                       jnp.asarray(codebook)))
    assert np.array_equal(A[:, 0], prim)


def test_spill_plan():
    assert spill_plan("none", 1.0, 2) == (0.0, 0)
    assert spill_plan("naive", 1.0, 2) == (0.0, 1)
    assert spill_plan("soar", 1.5, 2) == (1.5, 2)
    with pytest.raises(ValueError):
        spill_plan("bogus", 1.0, 1)


def test_sharded_build_equals_monolithic(ds):
    """Full-sample sharded build is bitwise-identical to build_ivf."""
    mono = build_ivf(jax.random.PRNGKey(1), ds.X, 32, spill_mode="soar",
                     pq_subspaces=8, train_iters=4)
    shard = build_ivf_sharded(jax.random.PRNGKey(1), ds.X, 32,
                              spill_mode="soar", pq_subspaces=8,
                              train_iters=4, train_sample=None,
                              shard_size=1024)
    assert np.array_equal(mono.centroids, shard.centroids)
    assert np.array_equal(mono.assignments, shard.assignments)
    assert np.array_equal(mono.starts, shard.starts)
    assert np.array_equal(mono.point_ids, shard.point_ids)
    assert np.array_equal(mono.codes, shard.codes)
    np.testing.assert_array_equal(np.asarray(mono.pq.centers),
                                  np.asarray(shard.pq.centers))


def test_shard_size_invariance(ds, codebook):
    """Shard boundaries are invisible: any shard_size, same index."""
    a = assign_shards(ds.X, codebook, shard_size=512, chunk=256)
    b = assign_shards(ds.X, codebook, shard_size=100_000, chunk=256)
    assert np.array_equal(a, b)


def test_frozen_codebook_build(ds, codebook):
    """codebook=/pq= skip training and are used verbatim (the incremental
    contract)."""
    i1 = build_ivf_sharded(jax.random.PRNGKey(5), ds.X, 32,
                           codebook=codebook, pq_subspaces=8, train_iters=4)
    assert np.array_equal(i1.centroids, codebook)
    i2 = build_ivf_sharded(None, ds.X[:2000], 32, codebook=codebook,
                           pq=i1.pq)
    assert i2.codes is not None
    np.testing.assert_array_equal(np.asarray(i2.pq.centers),
                                  np.asarray(i1.pq.centers))


@pytest.mark.parametrize("mode,a", [("none", 1), ("naive", 2), ("soar", 2)])
def test_spill_modes_shapes(ds, mode, a):
    idx = build_ivf_sharded(jax.random.PRNGKey(2), ds.X[:1500], 16,
                            spill_mode=mode, train_iters=3,
                            train_sample=1024)
    assert idx.assignments.shape == (1500, a)
    assert idx.n_assignments == 1500 * a
    if a == 2:
        assert np.all(idx.assignments[:, 0] != idx.assignments[:, 1])


def test_sharded_assign_shard_map(ds, codebook):
    """The shard_map build path agrees with the host-streamed path."""
    from jax.sharding import Mesh
    from repro.core.distributed import make_sharded_assign

    devs = np.array(jax.devices())
    n_dev = devs.shape[0]
    n = (ds.X.shape[0] // n_dev) * n_dev
    mesh = Mesh(devs, ("data",))
    fn = make_sharded_assign(mesh, ("data",), lam=1.0, n_spills=1, chunk=512)
    got = np.asarray(fn(jnp.asarray(ds.X[:n]), jnp.asarray(codebook)))
    want = assign_shards(ds.X[:n], codebook, shard_size=n // n_dev,
                         chunk=512)
    assert np.array_equal(got, want)
