"""Build-path overhaul pins (ISSUE 4).

Exactness matrix (DESIGN.md §3.8): the fused fast paths must be
bitwise-identical to their unfused references wherever the arithmetic is
merely reassociated —

- fused `lloyd_sweep` == two-pass `lloyd_step` at matched reduction order
  (single chunk); chunked sweeps change only f32 accumulation grouping
  (assignments/counts stay exact);
- hand-batched `lloyd_sweep_batched` == per-slice `lloyd_sweep`;
- batched `train_pq` == sequential per-subspace `train_pq_sequential` at
  the same keys (including the per-subspace early-stop schedule);
- fused one-pass residual encode == chunked host-loop reference;
- counting-sort CSR == stable argsort;
- delta `pack()` == full re-pack.

The flagged approximations (k-means|| init, mini-batch Lloyd) are
recall-parity tested, not bitwise.

Structural pin: no Lloyd iteration materializes an (n, c) or (n,)
intermediate outside a chunk tile (jaxpr-level, like the search-side pin
in test_search_pipeline.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_ivf, pack_ivf, search_jit, true_neighbors
from repro.core.ivf import _csr_from_assignments, _stable_counting_sort, finalize_ivf
from repro.core.kmeans import lloyd_step, train_kmeans
from repro.core.mutable import MutableIVF
from repro.data.vectors import make_manifold
from repro.kernels.lloyd import (_grouped_argmin, lloyd_sweep,
                                 lloyd_sweep_batched, lloyd_sweep_pallas)
from repro.quant.pq import train_pq, train_pq_sequential


@pytest.fixture(scope="module")
def ds():
    return make_manifold(jax.random.PRNGKey(0), n=6000, d=24, nq=32,
                         intrinsic_dim=6)


# --------------------------------------------------------------- argmin
def test_grouped_argmin_exact_with_ties():
    k = jax.random.PRNGKey(3)
    dm = jax.random.normal(k, (257, 48))
    # inject exact duplicates of row minima at random other columns so the
    # first-tie rule is actually exercised
    rows = jnp.arange(257)
    mins = jnp.min(dm, -1)
    dup_col = jax.random.randint(jax.random.PRNGKey(4), (257,), 0, 48)
    dm = dm.at[rows, dup_col].set(mins)
    idx, mv = _grouped_argmin(dm)
    assert np.array_equal(np.asarray(idx), np.asarray(jnp.argmin(dm, -1)))
    assert np.array_equal(np.asarray(mv), np.asarray(jnp.min(dm, -1)))


# ---------------------------------------------------------- fused Lloyd
def test_lloyd_sweep_single_chunk_bitwise_vs_lloyd_step():
    """At chunk == n the fused sweep reduces in exactly the reference
    order: new centroids AND distortion must match lloyd_step bitwise."""
    n, d, c = 4096, 32, 37           # c deliberately NOT a multiple of 8
    X = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    C = jax.random.normal(jax.random.PRNGKey(2), (c, d))
    ref_C, ref_assign, ref_dist = lloyd_step(X, C, c, chunk=n)
    new_C, counts, dist = lloyd_sweep(X, C, c, chunk=n)
    assert np.array_equal(np.asarray(ref_C), np.asarray(new_C))
    assert float(ref_dist) == float(dist)
    ref_counts = np.bincount(np.asarray(ref_assign), minlength=c)
    assert np.array_equal(ref_counts, np.asarray(counts).astype(np.int64))


def test_lloyd_sweep_chunked_counts_exact_sums_close():
    """Chunk boundaries change only the f32 accumulation grouping: the
    assignments (hence counts) stay exact, centroids agree to rounding."""
    n, d, c = 5000, 16, 24
    X = jax.random.normal(jax.random.PRNGKey(5), (n, d))
    C = jax.random.normal(jax.random.PRNGKey(6), (c, d))
    C1, counts1, d1 = lloyd_sweep(X, C, c, chunk=n)
    C2, counts2, d2 = lloyd_sweep(X, C, c, chunk=512)
    assert np.array_equal(np.asarray(counts1), np.asarray(counts2))
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(d1) - float(d2)) < 1e-4


def test_lloyd_sweep_batched_bitwise_per_slice():
    m, n, s, k = 7, 3000, 4, 16
    Xb = jax.random.normal(jax.random.PRNGKey(7), (m, n, s))
    Cb = jax.random.normal(jax.random.PRNGKey(8), (m, k, s))
    newC, counts, loss = lloyd_sweep_batched(Xb, Cb, k, chunk=1024)
    for j in range(m):
        c1, n1, l1 = lloyd_sweep(Xb[j], Cb[j], k, chunk=1024)
        assert np.array_equal(np.asarray(c1), np.asarray(newC[j]))
        assert np.array_equal(np.asarray(n1), np.asarray(counts[j]))
        assert float(l1) == float(loss[j])


def test_lloyd_sweep_pallas_matches_scan():
    """Interpret-mode Pallas route vs the scan route: identical counts,
    centroids to accumulation-order rounding (MXU one-hot vs scatter)."""
    n, d, c = 2048, 16, 32
    X = jax.random.normal(jax.random.PRNGKey(9), (n, d))
    C = jax.random.normal(jax.random.PRNGKey(10), (c, d))
    C1, counts1, d1 = lloyd_sweep(X, C, c, chunk=n)
    C2, counts2, d2 = lloyd_sweep_pallas(X, C, c, bn=512, interpret=True)
    assert np.array_equal(np.asarray(counts1), np.asarray(counts2))
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(d1) - float(d2)) < 1e-3


def test_no_lloyd_iteration_n_sized_intermediates():
    """ISSUE 4 acceptance: a Lloyd iteration materializes nothing
    (n, c)-shaped and no second-pass (n,) vector — every per-point
    intermediate lives inside a chunk tile."""
    from repro.analysis import jaxpr_shapes as _jaxpr_shapes
    n, d, c, chunk = 40_000, 32, 64, 4096
    X = jnp.zeros((n, d))
    C = jnp.zeros((c, d))
    closed = jax.make_jaxpr(
        lambda X, C: lloyd_sweep(X, C, c, chunk=chunk))(X, C)
    shapes = _jaxpr_shapes(closed.jaxpr)
    bad = [s for s in shapes
           if (len(s) == 1 and s[0] >= n)                 # (n,) second pass
           or (len(s) >= 2 and s[0] >= n and s[1] >= c)   # (n, c) dense
           or int(np.prod(s, dtype=np.int64)) >= n * c]
    assert not bad, f"n-sized Lloyd intermediates: {bad}"


def test_train_kmeans_final_assign_skip():
    X = jax.random.normal(jax.random.PRNGKey(11), (2000, 8))
    full = train_kmeans(jax.random.PRNGKey(12), X, 16, iters=4)
    skip = train_kmeans(jax.random.PRNGKey(12), X, 16, iters=4,
                        final_assign=False)
    assert np.array_equal(np.asarray(full.centroids),
                          np.asarray(skip.centroids))
    assert skip.assignments is None


# ------------------------------------------------------------ batched PQ
def test_batched_pq_bitwise_equals_sequential():
    """All m subspaces trained jointly == m sequential train_kmeans calls
    at the same keys, including per-subspace early-stop decisions."""
    X = jax.random.normal(jax.random.PRNGKey(13), (6000, 32))
    for iters in (3, 12):            # 12 iters: early stop kicks in per-m
        b = train_pq(jax.random.PRNGKey(14), X, 8, iters=iters)
        s = train_pq_sequential(jax.random.PRNGKey(14), X, 8, iters=iters)
        assert np.array_equal(np.asarray(b.centers), np.asarray(s.centers)), \
            f"batched != sequential at iters={iters}"


def test_batched_pq_bitwise_with_sampling():
    """The internal row-subsample paths (n > sample, and the per-subspace
    init subsample) must also coincide batched vs sequential."""
    X = jax.random.normal(jax.random.PRNGKey(15), (4000, 16))
    b = train_pq(jax.random.PRNGKey(16), X, 4, iters=4, sample=2048)
    s = train_pq_sequential(jax.random.PRNGKey(16), X, 4, iters=4,
                            sample=2048)
    assert np.array_equal(np.asarray(b.centers), np.asarray(s.centers))
    # init_sample < sample: exercises the vmapped per-subspace choice()
    b2 = train_pq(jax.random.PRNGKey(16), X, 4, iters=4, sample=2048,
                  init_sample=512)
    s2 = train_pq_sequential(jax.random.PRNGKey(16), X, 4, iters=4,
                             sample=2048, init_sample=512)
    assert np.array_equal(np.asarray(b2.centers), np.asarray(s2.centers))


def test_pq_encode_non_multiple_of_group_centers():
    """n_centers that doesn't divide the argmin group width must still
    encode (padded with never-chosen +inf) and match plain jnp.argmin."""
    from repro.quant.pq import PQCodebook, pq_encode
    X = jax.random.normal(jax.random.PRNGKey(30), (500, 16))
    cb = train_pq(jax.random.PRNGKey(31), X, 4, n_centers=12, iters=3)
    codes = np.asarray(pq_encode(cb, X))
    assert codes.max() < 12
    Xs = X.reshape(500, 4, 4)
    cn = jnp.sum(cb.centers * cb.centers, -1)
    dm = cn[None] - 2.0 * jnp.einsum("bms,mks->bmk", Xs, cb.centers)
    assert np.array_equal(codes, np.asarray(jnp.argmin(dm, -1)))


# --------------------------------------------------- fused residual encode
def test_fused_encode_bitwise_equals_chunked(ds):
    X = np.asarray(ds.X[:3000], np.float32)
    C = np.asarray(train_kmeans(jax.random.PRNGKey(17), X, 16, iters=3,
                                final_assign=False).centroids)
    rng = np.random.default_rng(0)
    assignments = np.stack([rng.integers(0, 16, 3000),
                            rng.integers(0, 16, 3000)], axis=1).astype(np.int32)
    kf = jax.random.PRNGKey(18)
    fused = finalize_ivf(kf, X, C, assignments, pq_subspaces=8,
                         encode_chunk=512, fused_encode=True)
    ref = finalize_ivf(kf, X, C, assignments, pq_subspaces=8,
                       encode_chunk=512, fused_encode=False)
    assert np.array_equal(fused.codes, ref.codes)
    assert np.array_equal(fused.point_ids, ref.point_ids)
    assert np.array_equal(fused.starts, ref.starts)
    np.testing.assert_array_equal(np.asarray(fused.pq.centers),
                                  np.asarray(ref.pq.centers))
    # encode_chunk is a pure tiling knob: codes are per-row exact
    other = finalize_ivf(kf, X, C, assignments, pq_subspaces=8,
                         encode_chunk=4096, fused_encode=True)
    assert np.array_equal(fused.codes, other.codes)


# ------------------------------------------------------------ CSR sort
def test_counting_sort_equals_stable_argsort():
    # without scipy the fallback IS argsort and this pin is vacuous —
    # scipy ships in requirements-dev.txt precisely so CI tests the
    # counting-sort branch; fail loudly if the environment lost it
    pytest.importorskip("scipy", reason="counting-sort fast path needs "
                        "scipy (requirements-dev.txt)")
    rng = np.random.default_rng(1)
    for n, c in ((1, 1), (100, 7), (50_000, 513)):
        keys = rng.integers(0, c, n).astype(np.int32)
        assert np.array_equal(_stable_counting_sort(keys, c),
                              np.argsort(keys, kind="stable"))
    assert _stable_counting_sort(np.empty(0, np.int32), 5).size == 0


def test_csr_from_assignments_order():
    A = np.array([[2, 0], [1, 2], [2, 1], [0, 1]], np.int32)
    starts, point_ids, order = _csr_from_assignments(A, 3)
    assert starts.tolist() == [0, 2, 5, 8]
    # partition 2 receives rows 0, 1, 2 in stable flat order
    assert point_ids[5:8].tolist() == [0, 1, 2]
    assert point_ids[0:2].tolist() == [0, 3]
    assert point_ids[2:5].tolist() == [1, 2, 3]


# ------------------------------------------------- flagged approximations
def _recall_of(idx, Q, tn):
    ids, _ = search_jit(pack_ivf(idx), jnp.asarray(Q), top_t=8, final_k=10,
                        rerank_budget=128)
    return float((np.asarray(ids)[:, :, None] == tn[:, None, :10])
                 .any(-1).mean())


def test_kmeans_parallel_and_minibatch_recall_parity(ds):
    tn = true_neighbors(ds.X, ds.Q, k=10)
    base = _recall_of(build_ivf(jax.random.PRNGKey(20), ds.X, 24,
                                pq_subspaces=8, train_iters=6), ds.Q, tn)
    par = _recall_of(build_ivf(jax.random.PRNGKey(20), ds.X, 24,
                               pq_subspaces=8, train_iters=6,
                               init="parallel"), ds.Q, tn)
    mb = _recall_of(build_ivf(jax.random.PRNGKey(20), ds.X, 24,
                              pq_subspaces=8, train_iters=12,
                              batch_size=1024), ds.Q, tn)
    assert par >= base - 0.03, (par, base)
    assert mb >= base - 0.05, (mb, base)


# ------------------------------------------------------------ delta pack
def test_delta_pack_identical_to_full_repack(ds):
    mut = MutableIVF.build(jax.random.PRNGKey(21), ds.X[:4000], 16,
                           spill_mode="soar", pq_subspaces=8, train_iters=3)
    mut.pack()                                   # seed cached snapshot
    mut.add(ds.X[4000:4800])
    mut.remove(np.arange(100, 300))
    delta = mut.pack()                           # delta-updated snapshot
    assert mut._dirty_parts is not None and not mut._dirty_parts.any()
    mut._invalidate()
    full = mut.pack()                            # full re-pack, same state
    assert np.array_equal(np.asarray(delta.part_ids),
                          np.asarray(full.part_ids))
    assert np.array_equal(np.asarray(delta.part_codes),
                          np.asarray(full.part_codes))
    if delta.part_codes2 is not None:
        assert np.array_equal(np.asarray(delta.part_codes2),
                              np.asarray(full.part_codes2))
    assert np.array_equal(np.asarray(delta.sizes), np.asarray(full.sizes))
    assert np.array_equal(np.asarray(delta.rerank)[:mut.n_total],
                          np.asarray(full.rerank)[:mut.n_total])


def test_delta_pack_search_matches_after_mutation_burst(ds):
    """Serving loop shape: interleaved add/remove/pack/search must equal a
    from-scratch pack at every step (the cadence the bench times)."""
    mut = MutableIVF.build(jax.random.PRNGKey(22), ds.X[:3000], 16,
                           spill_mode="soar", pq_subspaces=8, train_iters=3)
    Q = jnp.asarray(ds.Q[:8])
    kw = dict(top_t=6, final_k=5, rerank_budget=64)
    for step in range(4):
        lo = 3000 + step * 200
        ids_new = mut.add(ds.X[lo:lo + 200])
        mut.remove(ids_new[::3])
        di, dv = search_jit(mut.pack(), Q, **kw)
        mut._invalidate()
        fi, fv = search_jit(mut.pack(), Q, **kw)
        assert np.array_equal(np.asarray(di), np.asarray(fi))
        np.testing.assert_allclose(np.asarray(dv), np.asarray(fv),
                                   rtol=1e-6, atol=1e-6)
