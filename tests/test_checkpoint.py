import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, restore, save
from repro.configs import get_config
from repro.models import transformer as T
from repro.train import optimizer as opt


def _tree_eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                                         "d": jnp.array(3)}}
    p = str(tmp_path / "ck")
    save(p, tree, step=5)
    back, step, _ = restore(p, tree)
    assert step == 5
    assert _tree_eq(tree, back)
    assert np.asarray(back["b"]["c"]).dtype == np.dtype(jnp.bfloat16)


def test_manager_retention_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, {"x": jnp.full((2,), s)})
    assert m.steps() == [3, 4]
    assert m.latest_step() == 4
    back, step, _ = m.restore({"x": jnp.zeros((2,))})
    assert step == 4 and float(back["x"][0]) == 4


def test_atomic_save_overwrites_cleanly(tmp_path):
    p = str(tmp_path / "ck")
    save(p, {"x": jnp.zeros(3)}, step=1)
    save(p, {"x": jnp.ones(3)}, step=2)
    back, step, _ = restore(p, {"x": jnp.zeros(3)})
    assert step == 2 and float(back["x"][0]) == 1.0


def test_restore_missing_step_is_clear(tmp_path):
    m = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        m.restore({"x": jnp.zeros(2)})
    m.save(3, {"x": jnp.zeros(2)})
    with pytest.raises(FileNotFoundError, match="step 7"):
        m.restore({"x": jnp.zeros(2)}, step=7)


def test_retention_never_deletes_just_written(tmp_path):
    # keep < 1 is clamped: the newest write always survives
    m = CheckpointManager(str(tmp_path), keep=0)
    m.save(1, {"x": jnp.zeros(2)})
    assert m.steps() == [1]
    # an out-of-order save of an OLD step is still the newest write
    m2 = CheckpointManager(str(tmp_path / "b"), keep=1)
    for s in (5, 9, 2):
        m2.save(s, {"x": jnp.full((2,), s)})
    assert 2 in m2.steps()
    back, step, _ = m2.restore({"x": jnp.zeros(2)}, step=2)
    assert step == 2 and float(back["x"][0]) == 2


def test_steps_ignores_stray_dirs(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(4, {"x": jnp.zeros(2)})
    for stray in ("notes", "ckpt_abc", "ckpt_00000009.tmp"):
        (tmp_path / stray).mkdir()
    (tmp_path / "ckpt_readme.txt").write_text("hi")
    assert m.steps() == [4]
    assert m.latest_step() == 4


def test_train_state_roundtrip_with_real_model(tmp_path):
    cfg = get_config("granite-3-2b").smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ostate = opt.init(params)
    m = CheckpointManager(str(tmp_path))
    m.save_train_state(42, params, ostate)
    p2, o2, data_step = m.restore_train_state(cfg)
    assert data_step == 42
    assert _tree_eq(params, p2)
    assert int(o2.step) == 0


def test_elastic_restore_respects_new_sharding(tmp_path):
    """Restore with explicit shardings → leaves land with that sharding
    (single-device here; the 8-device variant runs in test_distributed.py)."""
    from jax.sharding import SingleDeviceSharding
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    p = str(tmp_path / "ck")
    save(p, tree, step=0)
    sh = {"w": SingleDeviceSharding(jax.devices()[0])}
    back, _, _ = restore(p, tree, shardings=sh)
    assert back["w"].sharding == sh["w"]
