import numpy as np

from repro.configs import get_config
from repro.data.pipeline import PipelineSpec, TokenPipeline, for_model


def test_deterministic_and_resumable():
    p = TokenPipeline(PipelineSpec(vocab_size=1000, seq_len=32, global_batch=8))
    b1 = p.batch_at(7)
    b2 = p.batch_at(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_next_tokens():
    p = TokenPipeline(PipelineSpec(vocab_size=1000, seq_len=32, global_batch=4))
    b = p.batch_at(0)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_batch():
    p = TokenPipeline(PipelineSpec(vocab_size=1000, seq_len=16, global_batch=8))
    shards = [p.batch_at(3, shard=i, n_shards=4) for i in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    # shards are distinct
    assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])


def test_tokens_in_vocab_range():
    p = TokenPipeline(PipelineSpec(vocab_size=101, seq_len=64, global_batch=4))
    b = p.batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 101


def test_modality_batches():
    cfg = get_config("hubert-xlarge").smoke_config()
    p = for_model(cfg, seq_len=16, global_batch=2)
    b = p.batch_at(0)
    assert "frames" in b and b["frames"].shape == (2, 16, cfg.d_model)
    cfg = get_config("paligemma-3b").smoke_config()
    p = for_model(cfg, seq_len=16, global_batch=2)
    b = p.batch_at(0)
    assert b["patches"].shape == (2, cfg.n_prefix_embeds, cfg.d_model)
