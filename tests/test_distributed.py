"""Multi-device semantics tests (8 virtual CPU devices via subprocess, so
the main pytest process keeps its single-device view)."""
import subprocess
import sys

SCRIPT_ANN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import build_sharded_ivf, make_distributed_search
from repro.launch.mesh import set_mesh
from repro.core import true_neighbors
from repro.data.vectors import make_manifold

ds = make_manifold(jax.random.PRNGKey(0), n=16_000, d=32, nq=64, intrinsic_dim=8)
tn = true_neighbors(ds.X, ds.Q, k=10)
mesh = jax.make_mesh((8,), ("data",))
sharded = build_sharded_ivf(jax.random.PRNGKey(1), ds.X, n_shards=8,
                            n_partitions=16, spill_mode="soar", train_iters=5)
search = make_distributed_search(mesh, ("data",), top_t=8, final_k=10)
with set_mesh(mesh):
    ids, scores = jax.jit(search)(sharded, jnp.asarray(ds.Q))
ids = np.asarray(ids)
rec = (ids[:, :, None] == tn[:, None, :]).any(-1).mean()
assert rec > 0.80, f"distributed recall {rec}"
# global ids must be valid and deduplicated
assert ids.min() >= 0 and ids.max() < 16_000
for row in ids:
    assert len(set(row.tolist())) == len(row)
print("OK recall", rec)
"""

SCRIPT_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt.checkpoint import save, restore

tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(8)}
d = tempfile.mkdtemp()
p = d + "/ck"
save(p, tree, step=3)
# restore onto a 2x4 mesh with w sharded over both axes — elastic re-mesh
mesh = jax.make_mesh((2, 4), ("a", "b"))
sh = {"w": NamedSharding(mesh, P("a", "b")), "b": NamedSharding(mesh, P("b"))}
back, step, _ = restore(p, tree, shardings=sh)
assert step == 3
assert back["w"].sharding == sh["w"]
np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
print("OK")
"""

SCRIPT_TRAIN_SPMD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data.pipeline import for_model
from repro.launch.mesh import build_rules, set_mesh
from repro.models.layers import set_logical_rules
from repro.models import transformer as T
from repro.train import optimizer as opt
from repro.train.train_loop import make_train_step

cfg = get_config("granite-3-2b").smoke_config()
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = build_rules({}, batch_size=8)
rules["heads"] = None  # 4 smoke heads won't split 4-way AND kv too; keep simple
set_logical_rules(rules)
pipe = for_model(cfg, seq_len=32, global_batch=8)
params = T.init_params(jax.random.PRNGKey(0), cfg)
lr_fn = opt.warmup_cosine(1e-3, 5, 100)
step = make_train_step(cfg, lr_fn, accum=2)
with set_mesh(mesh):
    pspec = T.param_pspecs(cfg, rules)
    params = jax.device_put(params, jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), pspec))
    ostate = opt.init(params)
    jstep = jax.jit(step)
    for i in range(3):
        params, ostate, m = jstep(params, ostate, pipe.batch_at(i))
loss = float(m["loss"])
assert np.isfinite(loss)
# compare against single-device reference for step equivalence
set_logical_rules({})
params_ref = T.init_params(jax.random.PRNGKey(0), cfg)
ostate_ref = opt.init(params_ref)
jref = jax.jit(make_train_step(cfg, lr_fn, accum=2))
for i in range(3):
    params_ref, ostate_ref, mr = jref(params_ref, ostate_ref, pipe.batch_at(i))
ref = float(mr["loss"])
assert abs(loss - ref) / max(abs(ref), 1e-6) < 5e-2, (loss, ref)
print("OK", loss, ref)
"""


def _run(script):
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                       # force CPU: the image ships libtpu, and
                                       # probing it burns 60s+ per subprocess
                                       "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout


SCRIPT_ANN_PQ = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import build_sharded_ivf_pq, make_distributed_search_pq
from repro.launch.mesh import set_mesh
from repro.core import true_neighbors
from repro.data.vectors import make_manifold

ds = make_manifold(jax.random.PRNGKey(0), n=16_000, d=32, nq=64, intrinsic_dim=8)
tn = true_neighbors(ds.X, ds.Q, k=10)
mesh = jax.make_mesh((8,), ("data",))
sharded = build_sharded_ivf_pq(jax.random.PRNGKey(1), ds.X, n_shards=8,
                               n_partitions=16, pq_subspaces=8,
                               spill_mode="soar", train_iters=5)
search = make_distributed_search_pq(mesh, ("data",), top_t=8, final_k=10,
                                    rerank_k=128, q_chunk=32)
with set_mesh(mesh):
    ids, scores = jax.jit(search)(sharded, jnp.asarray(ds.Q))
ids = np.asarray(ids)
rec = (ids[:, :, None] == tn[:, None, :]).any(-1).mean()
assert rec > 0.75, f"distributed PQ recall {rec}"
assert ids.min() >= 0 and ids.max() < 16_000
print("OK recall", rec)
"""


SCRIPT_ANN_FILTERED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import (build_sharded_ivf, build_sharded_ivf_pq,
                                    make_distributed_search,
                                    make_distributed_search_pq, shard_filters)
from repro.launch.mesh import set_mesh
from repro.data.vectors import make_manifold

ds = make_manifold(jax.random.PRNGKey(0), n=8_000, d=32, nq=32, intrinsic_dim=8)
mask = np.random.default_rng(0).random(8_000) < 0.2
alive = np.flatnonzero(mask)
sc = ds.Q.astype(np.float32) @ ds.X[alive].T
tn = alive[np.argsort(-sc, axis=1)[:, :10]]        # FILTERED exact top-10
mesh = jax.make_mesh((8,), ("data",))
filt = shard_filters(mask, [1000] * 8)
sharded = build_sharded_ivf(jax.random.PRNGKey(1), ds.X, n_shards=8,
                            n_partitions=16, spill_mode="soar", train_iters=4)
search = make_distributed_search(mesh, ("data",), top_t=10, final_k=10,
                                 with_filter=True)
with set_mesh(mesh):
    ids, _ = jax.jit(search)(sharded, jnp.asarray(ds.Q), filt)
ids = np.asarray(ids)
rec = (ids[:, :, None] == tn[:, None, :]).any(-1).mean()
assert rec > 0.9, f"filtered distributed recall {rec}"
assert mask[ids[ids >= 0]].all(), "result violated the subset filter"
shardedpq = build_sharded_ivf_pq(jax.random.PRNGKey(1), ds.X, n_shards=8,
                                 n_partitions=16, pq_subspaces=8,
                                 spill_mode="soar", train_iters=4)
searchpq = make_distributed_search_pq(mesh, ("data",), top_t=10, final_k=10,
                                      rerank_k=128, q_chunk=32,
                                      with_filter=True)
with set_mesh(mesh):
    idsp, _ = jax.jit(searchpq)(shardedpq, jnp.asarray(ds.Q), filt)
idsp = np.asarray(idsp)
recp = (idsp[:, :, None] == tn[:, None, :]).any(-1).mean()
assert recp > 0.85, f"filtered distributed PQ recall {recp}"
assert mask[idsp[idsp >= 0]].all()
print("OK recall", rec, recp)
"""


def test_distributed_ann_search():
    _run(SCRIPT_ANN)


def test_distributed_ann_search_pq():
    _run(SCRIPT_ANN_PQ)


def test_distributed_ann_search_filtered():
    _run(SCRIPT_ANN_FILTERED)


def test_elastic_checkpoint_remesh():
    _run(SCRIPT_ELASTIC)


def test_spmd_train_step_matches_single_device():
    _run(SCRIPT_TRAIN_SPMD)


SCRIPT_ANN_ROUTER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.build import build_ivf_sharded
from repro.core.distributed import (make_distributed_search,
                                    make_distributed_search_pq,
                                    sharded_from_indexes,
                                    sharded_from_indexes_pq,
                                    stack_tree_routers)
from repro.launch.mesh import set_mesh
from repro.core import true_neighbors
from repro.data.vectors import make_manifold

# each shard builds its own index AND its own two-level router (like its
# own codebook); the with_router=True search paths take the stacked tables
# as a trailing argument and probe through them shard-locally
ds = make_manifold(jax.random.PRNGKey(0), n=8_000, d=32, nq=32, intrinsic_dim=8)
tn = true_neighbors(ds.X, ds.Q, k=10)
nl = 1_000
idxs = [build_ivf_sharded(jax.random.fold_in(jax.random.PRNGKey(1), s),
                          ds.X[s * nl:(s + 1) * nl], 16, spill_mode="soar",
                          train_iters=4, pq_subspaces=8, router="tree",
                          router_kw=dict(n_super=4, t_route=3))
        for s in range(8)]
srt = stack_tree_routers([i.router for i in idxs])
mesh = jax.make_mesh((8,), ("data",))
search = make_distributed_search(mesh, ("data",), top_t=8, final_k=10,
                                 with_router=True, t_route=3)
with set_mesh(mesh):
    ids, _ = jax.jit(search)(sharded_from_indexes(idxs), jnp.asarray(ds.Q), srt)
ids = np.asarray(ids)
rec = (ids[:, :, None] == tn[:, None, :]).any(-1).mean()
assert rec > 0.70, f"tree-routed distributed recall {rec}"
assert ids.max() < 8_000
searchpq = make_distributed_search_pq(mesh, ("data",), top_t=8, final_k=10,
                                      rerank_k=128, q_chunk=32,
                                      with_router=True, t_route=3)
with set_mesh(mesh):
    idsp, _ = jax.jit(searchpq)(sharded_from_indexes_pq(idxs),
                                jnp.asarray(ds.Q), srt)
idsp = np.asarray(idsp)
recp = (idsp[:, :, None] == tn[:, None, :]).any(-1).mean()
assert recp > 0.65, f"tree-routed distributed PQ recall {recp}"
print("OK recall", rec, recp)
"""


def test_distributed_ann_search_tree_routed():
    _run(SCRIPT_ANN_ROUTER)
