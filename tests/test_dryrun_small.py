"""Launch-path integration test: lower+compile train & decode steps on a
small (2,4) mesh in a subprocess (8 virtual devices), including the HLO
roofline analysis — the same code path dryrun.py uses on the 512-chip mesh."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import build_rules, set_mesh, to_shardings
from repro.launch import specs as S
from repro.launch.hlo_analysis import analyze
from repro.models.config import ShapeCell
from repro.models.layers import set_logical_rules
from repro.models import transformer as T
from repro.serve.engine import make_serve_step
from repro.train import optimizer as opt
from repro.train.train_loop import make_train_step

cfg = get_config("granite-3-2b").smoke_config()
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = build_rules({"heads": None, "kv_heads": None}, batch_size=8,
                    dp_degree=2)
set_logical_rules(rules)

# --- train step
cell = ShapeCell("tiny_train", 64, 8, "train")
fn, args, insh, outsh = S.train_cell_specs(cfg, cell, rules, False)
with set_mesh(mesh):
    compiled = jax.jit(fn, in_shardings=to_shardings(mesh, insh),
                       out_shardings=to_shardings(mesh, outsh),
                       donate_argnums=(0, 1)).lower(*args).compile()
    mem = compiled.memory_analysis()
r = analyze(compiled.as_text())
assert r["flops"] > 0
assert r["hbm_bytes"] > 0
assert mem.temp_size_in_bytes > 0
print("train ok: flops", r["flops"])

# --- decode step
cell = ShapeCell("tiny_decode", 64, 8, "decode")
fn, args, insh, outsh = S.decode_cell_specs(cfg, cell, rules)
with set_mesh(mesh):
    compiled = jax.jit(fn, in_shardings=to_shardings(mesh, insh),
                       out_shardings=to_shardings(mesh, outsh),
                       donate_argnums=(2,)).lower(*args).compile()
r = analyze(compiled.as_text())
assert r["flops"] > 0
print("decode ok")
print("OK")
"""


def test_small_mesh_dryrun_path():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "OK" in r.stdout
