"""Durable index lifecycle (DESIGN.md §3.11): snapshot round trips are
bitwise, every injected crash point recovers to a committed state (never a
torn hybrid), corruption surfaces CorruptSnapshotError, and the serving
entry points reject malformed inputs at the edge.

The crash matrix runs in-process (mode="raise": the writer flushes+fsyncs
up to the injection point, so the on-disk state IS the crash state) —
plus a couple of true os._exit subprocess crashes validating end-to-end
that nothing depends on interpreter-side cleanup.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.ckpt import faults
from repro.ckpt.faults import InjectedCrash
from repro.ckpt.index_store import (CorruptSnapshotError, load_snapshot,
                                    save_snapshot)
from repro.ckpt.wal import REC_ADD, MutationWAL, read_records
from repro.serve.engine import AnnEngine

D = 16
K = 5


@pytest.fixture(autouse=True)
def _clean_faults():
    yield
    faults.uninstall()


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module")
def queries(rng):
    return rng.normal(size=(12, D)).astype(np.float32)


@pytest.fixture(scope="module")
def built(rng):
    """One shared engine: PQ + tree router + hard and soft tombstones —
    every piece of state the snapshot must carry."""
    X = rng.normal(size=(500, D)).astype(np.float32)
    eng = AnnEngine.build(jax.random.PRNGKey(0), X, 16, pq_subspaces=4,
                          router="tree", router_kw={"n_super": 4})
    eng.add(rng.normal(size=(40, D)).astype(np.float32))
    eng.remove([3, 5, 7], hard=True)
    eng.remove([11, 13], hard=False)
    return eng


def _clone(eng, tmp_path, name):
    p = str(tmp_path / name)
    eng.save(p)
    return AnnEngine.open(p), p


# ------------------------------------------------------------ round trips
def test_engine_snapshot_roundtrip_bitwise(built, queries, tmp_path):
    i0, s0 = built.search(queries, k=K)
    e2, _ = _clone(built, tmp_path, "eng")
    i1, s1 = e2.search(queries, k=K)
    assert np.array_equal(i0, i1) and np.array_equal(s0, s1)
    assert (e2.top_t, e2.rerank_budget, e2.bq) == (
        built.top_t, built.rerank_budget, built.bq)
    # tombstone state survives: same soft-deleted population, same filter
    assert e2.index.n_soft_deleted == built.index.n_soft_deleted
    assert np.array_equal(e2.index.alive, built.index.alive)


def test_ivf_snapshot_roundtrip_numpy_engine(built, queries, tmp_path):
    from repro.core.search import search_numpy
    idx = built.index.to_ivf_index()
    i0, st0 = search_numpy(idx, queries, top_t=6, final_k=K,
                           rerank_budget=64)
    p = str(tmp_path / "ivf")
    save_snapshot(p, idx)
    idx2, _ = load_snapshot(p, expect_kind="IVFIndex")
    i1, st1 = search_numpy(idx2, queries, top_t=6, final_k=K,
                           rerank_budget=64)
    assert np.array_equal(i0, i1)
    assert np.array_equal(st0.points_read, st1.points_read)
    # the tree router rode along (probe order is part of the contract)
    assert type(idx2.router).__name__ == type(idx.router).__name__


def test_knn_memory_roundtrip_with_filters(rng, tmp_path):
    from repro.serve.knn_memory import KNNMemory
    Kv = rng.normal(size=(300, 8)).astype(np.float32)
    V = rng.normal(size=(300, 8)).astype(np.float32)
    mem = KNNMemory.build(Kv, V, n_partitions=8, engine="jit")
    mem.add(rng.normal(size=(16, 8)).astype(np.float32),
            rng.normal(size=(16, 8)).astype(np.float32), segment=2)
    mem.remove([1, 2], hard=False)
    q = rng.normal(size=(6, 8)).astype(np.float32)
    p = str(tmp_path / "mem")
    mem.save(p)
    m2 = KNNMemory.open(p)
    for kw in ({}, {"recency": 200}, {"segment": 2}):
        i0, k0, v0 = mem.retrieve(q, k=8, **kw)
        i1, k1, v1 = m2.retrieve(q, k=8, **kw)
        assert np.array_equal(i0, i1)
        assert np.array_equal(k0, k1) and np.array_equal(v0, v1)


def test_sharded_envelope_roundtrip(rng, tmp_path):
    from repro.core.build import build_ivf_sharded
    from repro.core.distributed import (load_sharded, save_sharded,
                                        sharded_from_indexes_pq)
    from repro.core.mutable import MutableIVF
    X = rng.normal(size=(512, 8)).astype(np.float32)
    shards = [build_ivf_sharded(jax.random.PRNGKey(s),
                                X[s * 256:(s + 1) * 256], 8,
                                pq_subspaces=2) for s in range(2)]
    shards[0] = MutableIVF.from_index(shards[0])
    shards[0].add(rng.normal(size=(10, 8)).astype(np.float32))
    s0 = sharded_from_indexes_pq(shards)
    p = str(tmp_path / "shards")
    save_sharded(p, shards, extra={"note": 1})
    loaded, extra = load_sharded(p)
    assert extra == {"note": 1}
    s1 = sharded_from_indexes_pq(loaded)
    for a, b in zip(jax.tree_util.tree_leaves(s0),
                    jax.tree_util.tree_leaves(s1)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- corruption → error
def test_corruption_raises_not_garbage(built, tmp_path):
    cases = [
        ("arrays mid-file flip", "index/arrays.bin",
         lambda p: faults.flip_byte(p, 1000)),
        ("arrays tail flip", "index/arrays.bin",
         lambda p: faults.flip_byte(p, -1)),
        ("arrays truncated", "index/arrays.bin",
         lambda p: faults.truncate_tail(p, 7)),
        ("manifest flip", "index/manifest.json",
         lambda p: faults.flip_byte(p, -2)),
        ("manifest truncated", "index/manifest.json",
         lambda p: faults.truncate_tail(p, 30)),
    ]
    for i, (label, rel, inject) in enumerate(cases):
        p = str(tmp_path / f"c{i}")
        built.save(p)
        inject(os.path.join(p, rel))
        with pytest.raises(CorruptSnapshotError):
            AnnEngine.open(p)


def test_missing_snapshot_is_clear(tmp_path):
    with pytest.raises(CorruptSnapshotError, match="no snapshot"):
        load_snapshot(str(tmp_path / "nope"))


# --------------------------------------------------------------- WAL unit
def test_wal_roundtrip_and_torn_tail(tmp_path):
    p = str(tmp_path / "wal.log")
    with MutationWAL(p) as w:
        w.append(REC_ADD, {"i": 0}, {"x": np.arange(6, dtype=np.float32)})
        w.append(REC_ADD, {"i": 1}, {"x": np.ones((2, 3), np.int32)})
        last = w.append(REC_ADD, {"i": 2})
    assert last == 3
    recs = list(read_records(p))
    assert [m["i"] for _, _, m, _ in recs] == [0, 1, 2]
    assert np.array_equal(recs[1][3]["x"], np.ones((2, 3), np.int32))
    # tear the final record: committed prefix survives, tail dropped
    faults.truncate_tail(p, 5)
    assert [m["i"] for _, _, m, _ in read_records(p)] == [0, 1]
    # reopening truncates the torn bytes and continues the sequence
    with MutationWAL(p) as w:
        assert w.last_seq == 2
        assert w.append(REC_ADD, {"i": 9}) == 3
    assert [m["i"] for _, _, m, _ in read_records(p)] == [0, 1, 9]


def test_wal_midfile_corruption_raises(tmp_path):
    p = str(tmp_path / "wal.log")
    with MutationWAL(p) as w:
        w.append(REC_ADD, {"i": 0}, {"x": np.zeros(8, np.float32)})
        w.append(REC_ADD, {"i": 1})
    faults.flip_byte(p, 30)            # inside record 0's payload
    with pytest.raises(CorruptSnapshotError):
        list(read_records(p))
    with pytest.raises(CorruptSnapshotError):
        MutationWAL(p)                 # the opener validates too


def test_wal_guards(tmp_path):
    with pytest.raises(ValueError):
        MutationWAL(str(tmp_path / "w"), fsync="sometimes")
    with MutationWAL(str(tmp_path / "w2"), fsync="never") as w:
        w.append(REC_ADD, {"i": 0})
        with pytest.raises(ValueError):
            w.rotate(0)                # records past 0 are in the log
        w.rotate(w.last_seq)
    assert os.path.getsize(str(tmp_path / "w2")) == 0
    # start_seq floors the sequence after a rotation
    with MutationWAL(str(tmp_path / "w2"), start_seq=7) as w:
        assert w.append(REC_ADD) == 8


# ------------------------------------------------- in-process crash matrix
SNAPSHOT_FAULTS = [
    ("snapshot:arrays+0", "old"),
    ("snapshot:arrays+64", "old"),
    ("snapshot:arrays+4099", "old"),
    ("snapshot:manifest+0", "old"),
    ("snapshot:manifest+10", "old"),
    ("commit:between_renames", "old"),
    ("commit:before_cleanup", "new"),
]


def test_snapshot_crash_matrix(built, queries, tmp_path):
    """Every crash point during an overwriting save reopens to a committed
    state — the previous snapshot for crashes before the swap completes,
    the new one after — bitwise."""
    ra = built.search(queries, k=K)
    for i, (spec, expect) in enumerate(SNAPSHOT_FAULTS):
        engB, p = _clone(built, tmp_path, f"m{i}")
        engB.add(np.linspace(0, 1, 3 * D, dtype=np.float32).reshape(3, D))
        rb = engB.search(queries, k=K)
        faults.install(spec)
        with pytest.raises(InjectedCrash):
            engB.save(p)
        faults.uninstall()
        r2 = AnnEngine.open(p).search(queries, k=K)
        want = ra if expect == "old" else rb
        assert np.array_equal(r2[0], want[0]), (spec, expect)
        assert np.array_equal(r2[1], want[1]), (spec, expect)


def test_first_save_crash_leaves_no_committed_state(built, tmp_path):
    """Crash during the very first save: there is no previous snapshot to
    fall back to — open must refuse loudly, not serve a torn index."""
    p = str(tmp_path / "first")
    faults.install("snapshot:arrays+128")
    with pytest.raises(InjectedCrash):
        built.save(p)
    faults.uninstall()
    with pytest.raises(CorruptSnapshotError):
        AnnEngine.open(p)


WAL_FAULTS = [
    ("wal:append+0", "pre"),           # nothing of the record on disk
    ("wal:append+5", "pre"),           # torn header
    ("wal:append+23", "pre"),          # header complete less one byte
    ("wal:append+60", "pre"),          # torn payload
    ("wal:record", "post"),            # record durable, apply interrupted
]


def test_wal_crash_matrix(built, queries, tmp_path):
    """A crash anywhere inside a logged mutation recovers to exactly the
    pre-mutation state (torn record dropped) or the post-mutation state
    (record fully durable, replayed on open) — never between."""
    add = np.linspace(-1, 1, 4 * D, dtype=np.float32).reshape(4, D)
    for i, (spec, expect) in enumerate(WAL_FAULTS):
        _, p = _clone(built, tmp_path, f"w{i}")
        eng = AnnEngine.open(p, wal=True)
        r_pre = eng.search(queries, k=K)
        faults.install(spec)
        with pytest.raises(InjectedCrash):
            eng.add(add)
        faults.uninstall()
        eng2 = AnnEngine.open(p)
        r2 = eng2.search(queries, k=K)
        if expect == "pre":
            want = r_pre
        else:                          # replay applies the committed add
            ref = AnnEngine.open(p.replace(f"w{i}", "w0"))
            ref.add(add)
            want = ref.search(queries, k=K)
        assert np.array_equal(r2[0], want[0]), (spec, expect)
        assert np.array_equal(r2[1], want[1]), (spec, expect)


def test_checkpoint_commit_crash_recovers_previous(tmp_path):
    """The ckpt/checkpoint.py satellite: the old rmtree-then-rename window
    lost the only copy; the rename-aside swap keeps one at every point."""
    import jax.numpy as jnp

    from repro.ckpt.checkpoint import restore, save
    p = str(tmp_path / "ck")
    save(p, {"x": jnp.zeros(4)}, step=1)
    faults.install("commit:between_renames")
    with pytest.raises(InjectedCrash):
        save(p, {"x": jnp.ones(4)}, step=2)
    faults.uninstall()
    back, step, _ = restore(p, {"x": jnp.zeros(4)})
    assert step == 1 and float(np.asarray(back["x"])[0]) == 0.0
    # and the interrupted swap was finished: a clean save works again
    save(p, {"x": jnp.ones(4)}, step=2)
    _, step, _ = restore(p, {"x": jnp.zeros(4)})
    assert step == 2


# ------------------------------------------------- true-crash subprocesses
_CHILD = r"""
import os, sys
import numpy as np
import jax
from repro.ckpt import faults
from repro.serve.engine import AnnEngine

d = sys.argv[1]
rng = np.random.default_rng(0)
X = rng.normal(size=(300, 8)).astype(np.float32)
Q = rng.normal(size=(6, 8)).astype(np.float32)
add = np.linspace(0, 1, 4 * 8, dtype=np.float32).reshape(4, 8)

eng = AnnEngine.build(jax.random.PRNGKey(0), X, 8, pq_subspaces=2)
p = os.path.join(d, "eng")
eng.save(p)
eng = AnnEngine.open(p, wal=True)
np.save(os.path.join(d, "q.npy"), Q)
i, s = eng.search(Q, k=4)
np.save(os.path.join(d, "pre.npy"), np.concatenate(
    [i.astype(np.float64), s.astype(np.float64)], axis=1))

stage = os.environ["CRASH_STAGE"]
faults.install()          # reads REPRO_FAULT / REPRO_FAULT_MODE=exit
if stage == "save":
    eng.add(add)          # committed through the WAL
    i, s = eng.search(Q, k=4)
    np.save(os.path.join(d, "post.npy"), np.concatenate(
        [i.astype(np.float64), s.astype(np.float64)], axis=1))
    eng.save(p)           # dies mid-commit (os._exit, no cleanup)
else:
    eng.add(add)          # dies mid-append
os._exit(0)
"""


@pytest.mark.parametrize("stage,fault,expect", [
    ("save", "commit:between_renames", "post"),
    ("mutate", "wal:append+30", "pre"),
])
def test_subprocess_crash_recovery(tmp_path, stage, fault, expect):
    """End-to-end with a REAL crash (os._exit: no atexit, no interpreter
    cleanup): reopen serves bitwise the last committed state."""
    env = dict(os.environ, JAX_PLATFORMS="cpu", CRASH_STAGE=stage,
               REPRO_FAULT=fault, REPRO_FAULT_MODE="exit")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _CHILD, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 42, (r.returncode, r.stdout, r.stderr)
    eng = AnnEngine.open(str(tmp_path / "eng"))
    Q = np.load(tmp_path / "q.npy")
    i, s = eng.search(Q, k=4)
    got = np.concatenate([i.astype(np.float64), s.astype(np.float64)],
                         axis=1)
    want = np.load(tmp_path / f"{expect}.npy")
    assert np.array_equal(got, want)


# ------------------------------------------------------- hardened serving
def test_search_input_validation(built, queries):
    with pytest.raises(ValueError, match="top_t"):
        built.search(queries, top_t=0)       # was silently self.top_t
    with pytest.raises(ValueError, match="k must"):
        built.search(queries, k=0)
    with pytest.raises(ValueError, match="dim"):
        built.search(queries[:, :5])
    with pytest.raises(ValueError, match="numeric"):
        built.search(np.array(["a", "b"]))
    with pytest.raises(ValueError, match="shape"):
        built.search(np.zeros((2, 2, D), np.float32))
    bad = queries.copy()
    bad[0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        built.search(bad)
    ids, _ = built.search(bad, k=K, sanitize=True)
    assert ids.shape == (queries.shape[0], K)
    # float64 values that overflow the float32 cast are caught too
    with pytest.raises(ValueError, match="non-finite"):
        built.search(np.full((1, D), 1e300))
    with pytest.raises(ValueError):
        AnnEngine(built.index, top_t=0)


def test_empty_batches(built):
    i, s = built.search(np.empty((0, D), np.float32), k=7)
    assert i.shape == (0, 7) and s.shape == (0, 7)
    from repro.core.search import search_numpy
    out, stats = search_numpy(built.index.to_ivf_index(),
                              np.empty((0, D), np.float32), top_t=4,
                              final_k=6)
    assert out.shape == (0, 6) and stats.points_read.shape == (0,)


def test_knn_retrieve_validation(rng):
    from repro.serve.knn_memory import KNNMemory
    Kv = rng.normal(size=(200, 8)).astype(np.float32)
    mem = KNNMemory.build(Kv, Kv, n_partitions=4)
    q = rng.normal(size=(3, 8)).astype(np.float32)
    with pytest.raises(ValueError, match="top_t"):
        mem.retrieve(q, top_t=0)
    with pytest.raises(ValueError, match="k must"):
        mem.retrieve(q, k=0)
    with pytest.raises(ValueError, match="non-finite"):
        mem.retrieve(np.full((1, 8), np.inf, np.float32))
