"""Filtered / subset search subsystem (ISSUE 5 tentpole) + the three
satellite crash/recompile regressions.

Pins, per the acceptance criteria:

1. Parity: with full probes / full budgets, filtered search on BOTH engines
   equals filtered brute force; at default budgets and selectivity 0.1 the
   recall gap to filtered exact is < 0.01.
2. Degenerates: all-zero filters return all -1; an all-ones filter is
   bitwise-identical to unfiltered search; empty / fully-tombstoned /
   explicit-pmax=0 packs search cleanly (all -1 via the _pad_topk contract)
   instead of crashing.
3. Filter+spill dedup: a spilled point that passes the filter still dedups
   to one result slot.
4. Candidate-locality survives filtering: the filtered+escalating jit trace
   has no (n,)- or (*, n)-shaped equation output (§3.6 invariant, extended
   to §3.9).
5. Crash/recompile satellites: top_t > n_partitions is clamped on every
   path (was an argpartition/top_k crash), and AnnEngine's small-batch
   serving no longer compiles one executable per distinct nq.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MutableIVF, build_ivf, pack_ivf, search_jit, search_numpy
from repro.core.search import search_jit_batched
from repro.data.vectors import make_manifold
from repro.serve.engine import AnnEngine
from repro.serve.knn_memory import KNNMemory

N, D, NQ = 8_000, 32, 37
C_PARTS = 32
TOP_T, FINAL_K = 12, 10


@pytest.fixture(scope="module")
def spilled():
    ds = make_manifold(jax.random.PRNGKey(0), n=N, d=D, nq=NQ,
                       intrinsic_dim=8)
    idx = build_ivf(jax.random.PRNGKey(1), ds.X, C_PARTS, spill_mode="soar",
                    pq_subspaces=8, train_iters=5)
    return ds, idx, pack_ivf(idx)


def _mask(sel: float, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).random(N) < sel


def _filtered_exact(X, Q, mask, k: int = FINAL_K) -> np.ndarray:
    alive = np.flatnonzero(mask)
    sc = Q.astype(np.float32) @ X[alive].T
    return alive[np.argsort(-sc, axis=1)[:, :k]]


def _recall(ids, tn) -> float:
    return float((ids[:, :, None] == tn[:, None, :]).any(-1).mean())


# ------------------------------------------------------------------ parity

def test_numpy_full_probe_filtered_is_exact(spilled):
    """Full probe + exact scoring under a filter ≡ filtered brute force."""
    ds, idx, _ = spilled
    mask = _mask(0.3)
    tn = _filtered_exact(ds.X, ds.Q, mask)
    ids, _ = search_numpy(idx, ds.Q, top_t=C_PARTS, final_k=FINAL_K,
                          rerank_budget=0, filter_mask=mask)
    assert _recall(ids, tn) == 1.0


def test_jit_full_window_filtered_is_exact(spilled):
    ds, idx, packed = spilled
    mask = _mask(0.3)
    tn = _filtered_exact(ds.X, ds.Q, mask)
    window = C_PARTS * packed.part_ids.shape[1]
    ids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=C_PARTS,
                        final_k=FINAL_K, rerank_budget=window,
                        filter=jnp.asarray(mask.astype(np.uint8)))
    assert _recall(np.asarray(ids), tn) == 1.0


def test_engines_identical_filtered(spilled):
    """Window-covering budget → both engines reduce to exact rerank of the
    same filtered deduped candidate set → identical ids."""
    ds, idx, packed = spilled
    mask = _mask(0.4)
    window = TOP_T * packed.part_ids.shape[1]
    jids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                         final_k=FINAL_K, rerank_budget=window,
                         filter=jnp.asarray(mask.astype(np.uint8)),
                         escalate=False)
    nids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=window, filter_mask=mask,
                           escalate=False)
    assert np.array_equal(np.asarray(jids), nids)


def test_filtered_recall_acceptance_sel_0p1(spilled):
    """ISSUE 5 acceptance: selectivity 0.1, default budgets, both engines
    within 0.01 of filtered exact search."""
    ds, idx, packed = spilled
    mask = _mask(0.1)
    tn = _filtered_exact(ds.X, ds.Q, mask)
    jids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                         final_k=FINAL_K,
                         filter=jnp.asarray(mask.astype(np.uint8)))
    nids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=256, filter_mask=mask)
    assert _recall(np.asarray(jids), tn) >= 0.99
    assert _recall(nids, tn) >= 0.99


def test_results_respect_filter(spilled):
    ds, idx, packed = spilled
    mask = _mask(0.2, seed=3)
    jids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                         final_k=FINAL_K,
                         filter=jnp.asarray(mask.astype(np.uint8)))
    nids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=256, filter_mask=mask)
    jids = np.asarray(jids)
    assert mask[jids[jids >= 0]].all()
    assert mask[nids[nids >= 0]].all()


def test_escalation_rescues_thin_filters(spilled):
    """At selectivity 0.01 the surviving window is thinner than the rerank
    budget → the second (jit) / looped (numpy) escalation pass must recover
    recall lost to the starved first probe."""
    ds, idx, packed = spilled
    mask = _mask(0.01, seed=7)
    tn = _filtered_exact(ds.X, ds.Q, mask)
    f = jnp.asarray(mask.astype(np.uint8))
    base, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                         final_k=FINAL_K, filter=f, escalate=False)
    esc, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                        final_k=FINAL_K, filter=f, escalate=True)
    r_base, r_esc = _recall(np.asarray(base), tn), _recall(np.asarray(esc), tn)
    assert r_esc >= r_base
    assert r_esc >= 0.99
    nesc, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=256, filter_mask=mask)
    assert _recall(nesc, tn) >= 0.99


def test_short_filter_mask_zero_pads(spilled):
    """A mask shorter than n_points must exclude the uncovered ids (like
    MutableIVF.filter_bitmap), not crash the candidate gather."""
    ds, idx, _ = spilled
    short = np.ones(N // 2, bool)
    ids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                          rerank_budget=256, filter_mask=short)
    assert (ids >= 0).any()
    assert (ids[ids >= 0] < N // 2).all()


def test_nopq_escalation_threshold_is_final_k():
    """Regression: on a no-PQ index the numpy engine compared the unique-
    survivor count against rerank_budget — which the no-PQ scoring path
    ignores — so any subset smaller than the budget walked every query to
    a full filtered brute-force scan on every call."""
    ds = make_manifold(jax.random.PRNGKey(2), n=4000, d=16, nq=8,
                       intrinsic_dim=6)
    idx = build_ivf(jax.random.PRNGKey(3), ds.X, 16, spill_mode="soar",
                    train_iters=3)                       # no PQ stage
    mask = np.zeros(4000, bool)
    mask[np.random.default_rng(0).choice(4000, 200, replace=False)] = True
    _, stats = search_numpy(idx, ds.Q, top_t=4, final_k=10,
                            rerank_budget=256, filter_mask=mask)
    # plenty of unique survivors ≥ final_k at the first probe → the
    # escalation loop must NOT walk to a full scan of the index
    assert stats.points_read.max() < idx.n_assignments


# -------------------------------------------------------------- degenerates

def test_all_filtered_returns_minus_one(spilled):
    ds, idx, packed = spilled
    zeros = np.zeros(N, bool)
    jids, jvals = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                             final_k=FINAL_K,
                             filter=jnp.zeros(N, jnp.uint8))
    nids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=256, filter_mask=zeros)
    assert (np.asarray(jids) == -1).all()
    assert np.isneginf(np.asarray(jvals)).all()
    assert (nids == -1).all()


def test_full_mask_is_bitwise_unfiltered(spilled):
    """An all-ones filter changes nothing: same ids AND same scores as the
    unfiltered pipeline (whose trace itself is the PR 4 one)."""
    ds, idx, packed = spilled
    Q = jnp.asarray(ds.Q)
    kw = dict(top_t=TOP_T, final_k=FINAL_K, rerank_budget=256)
    uids, uvals = search_jit(packed, Q, **kw)
    fids, fvals = search_jit(packed, Q, filter=jnp.ones(N, jnp.uint8), **kw)
    assert np.array_equal(np.asarray(uids), np.asarray(fids))
    assert np.array_equal(np.asarray(uvals), np.asarray(fvals))
    unp, _ = search_numpy(idx, ds.Q, filter_mask=np.ones(N, bool), **kw)
    ref, _ = search_numpy(idx, ds.Q, **kw)
    assert np.array_equal(unp, ref)


# ----------------------------------------------------- filter + spill dedup

def test_filtered_spill_still_dedups(spilled):
    """Every point sits in two partitions (SOAR spill); one passing the
    filter must still occupy exactly one result slot."""
    ds, idx, packed = spilled
    counts = np.bincount(idx.point_ids, minlength=idx.n_points)
    assert np.all(counts == 2)            # precondition: duplicates exist
    mask = _mask(0.5, seed=11)
    jids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                         final_k=FINAL_K,
                         filter=jnp.asarray(mask.astype(np.uint8)))
    nids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=256, filter_mask=mask)
    for row in np.asarray(jids):
        live = row[row >= 0]
        assert len(set(live.tolist())) == len(live)
    for row in nids:
        live = row[row >= 0]
        assert len(set(live.tolist())) == len(live)


# ------------------------------------------------ candidate-locality (§3.9)

# shared recursive walker (repro/analysis/jaxpr_walk.py, DESIGN.md §3.14)
from repro.analysis import jaxpr_shapes as _jaxpr_shapes  # noqa: E402


def test_no_database_sized_intermediates_filtered(spilled):
    """§3.6's jaxpr pin extended to the filtered+escalating path: the (n,)
    filter bitmap is an INPUT, gathered per window — no equation may emit
    an (n,)- or (*, n)-shaped buffer."""
    ds, idx, packed = spilled
    n = idx.n_points
    f = jnp.asarray(_mask(0.1).astype(np.uint8))
    closed = jax.make_jaxpr(
        lambda p, q, fb: search_jit(p, q, top_t=TOP_T, final_k=FINAL_K,
                                    rerank_budget=256, filter=fb,
                                    escalate=True))(packed,
                                                    jnp.asarray(ds.Q), f)
    bad = [s for s in _jaxpr_shapes(closed.jaxpr)
           if s == (n,) or (len(s) == 2 and s[1] == n)]
    assert not bad, f"database-sized intermediates in filtered path: {bad}"


# ------------------------------------------- satellite: top_t > n_partitions

def test_topt_overflow_clamped_numpy(spilled):
    """Regression: np.argpartition kth out-of-bounds when top_t > c."""
    ds, idx, _ = spilled
    big, _ = search_numpy(idx, ds.Q, top_t=10 * C_PARTS, final_k=FINAL_K,
                          rerank_budget=256)
    ref, _ = search_numpy(idx, ds.Q, top_t=C_PARTS, final_k=FINAL_K,
                          rerank_budget=256)
    assert np.array_equal(big, ref)


def test_topt_overflow_clamped_jit(spilled):
    """Regression: lax.top_k width overflow when top_t > c."""
    ds, idx, packed = spilled
    big, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=10 * C_PARTS,
                        final_k=FINAL_K, rerank_budget=256)
    ref, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=C_PARTS,
                        final_k=FINAL_K, rerank_budget=256)
    assert np.array_equal(np.asarray(big), np.asarray(ref))


def test_topt_overflow_clamped_engine(spilled):
    ds, idx, _ = spilled
    eng = AnnEngine(MutableIVF.from_index(idx))
    ids, _ = eng.search(ds.Q, k=5, top_t=10 * C_PARTS)
    assert ids.shape == (NQ, 5) and (ids >= 0).all()


# --------------------------------------- satellite: degenerate / empty packs

def test_pack_ivf_explicit_pmax_zero(spilled):
    """Regression: `pmax or sizes.max()` treated an explicit 0 as unset;
    now it is honored as a cap → an all-sentinel width-1 pack that searches
    to all -1 instead of crashing top_k on a zero-width window."""
    ds, idx, _ = spilled
    packed = pack_ivf(idx, pmax=0)
    assert packed.part_ids.shape[1] == 1
    assert (np.asarray(packed.part_ids) == -1).all()
    assert (np.asarray(packed.sizes) == 0).all()
    ids, vals = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                           final_k=FINAL_K, rerank_budget=256)
    assert (np.asarray(ids) == -1).all()
    assert np.isneginf(np.asarray(vals)).all()


def test_fully_tombstoned_index_searches_clean(spilled):
    """Regression: a fully-removed (hence fully-compacted) index produced a
    zero-width pack whose downstream top_k crashed."""
    ds, idx, _ = spilled
    mut = MutableIVF.from_index(idx)
    assert mut.remove(np.arange(idx.n_points)) == idx.n_points
    csr = mut.to_ivf_index()
    assert csr.point_ids.size == 0
    packed = pack_ivf(csr)                # sizes all zero → width-1 sentinel
    ids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                        final_k=FINAL_K, rerank_budget=256)
    assert (np.asarray(ids) == -1).all()
    nids, _ = search_numpy(csr, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=256)
    assert (nids == -1).all()
    mids, _ = search_jit(mut.pack(), jnp.asarray(ds.Q), top_t=TOP_T,
                         final_k=FINAL_K, rerank_budget=256)
    assert (np.asarray(mids) == -1).all()


# ------------------------------------ satellite: per-nq recompile in serving

def test_engine_small_batches_share_one_compile(spilled):
    """Regression: `bq=min(self.bq, nq)` keyed a fresh jit executable on
    every distinct small query-batch size; bucketed padding must serve all
    of nq ∈ [1, 8] from one executable."""
    ds, idx, _ = spilled
    from repro.analysis import CacheWatch
    eng = AnnEngine(MutableIVF.from_index(idx))
    eng.search(ds.Q[:3], k=5)                    # warm the bucket
    with CacheWatch(search_jit_batched):         # shared sentinel (§3.14)
        outs = {nq: eng.search(ds.Q[:nq], k=5)[0] for nq in range(1, 9)}
    full, _ = eng.search(ds.Q, k=5)
    for nq, ids in outs.items():
        assert ids.shape == (nq, 5)
        assert np.array_equal(ids, full[:nq])    # padding never leaks


# --------------------------------------------- serving-stack filter plumbing

def test_engine_filter_ids_and_soft_remove(spilled):
    ds, idx, _ = spilled
    eng = AnnEngine(MutableIVF.from_index(idx), top_t=TOP_T)
    allow = np.flatnonzero(_mask(0.2, seed=5))
    ids, _ = eng.search(ds.Q, k=FINAL_K, filter_ids=allow)
    assert np.isin(ids[ids >= 0], allow).all()
    # soft remove: zero data movement (slots intact), served via the filter
    victims = allow[:200]
    slots_before = int((eng.index.part_ids >= 0).sum())
    assert eng.remove(victims, hard=False) == 200
    assert int((eng.index.part_ids >= 0).sum()) == slots_before
    ids2, _ = eng.search(ds.Q, k=FINAL_K)
    assert not np.isin(ids2, victims).any()
    # user filter composes with the standing tombstone filter
    ids3, _ = eng.search(ds.Q, k=FINAL_K, filter_ids=allow)
    assert not np.isin(ids3, victims).any()
    assert np.isin(ids3[ids3 >= 0], allow).all()
    # hardening reclaims the slots and preserves exclusion
    assert eng.index.harden_soft_deletes() == 200
    ids4, _ = eng.search(ds.Q, k=FINAL_K)
    assert not np.isin(ids4, victims).any()


@pytest.mark.parametrize("engine", ["numpy", "jit"])
def test_knn_memory_recency_and_segment_filters(engine):
    rng = np.random.default_rng(0)
    K = rng.standard_normal((1500, 16)).astype(np.float32)
    V = rng.standard_normal((1500, 16)).astype(np.float32)
    mem = KNNMemory.build(K, V, n_partitions=16, engine=engine, segment=0)
    k1 = rng.standard_normal((120, 16)).astype(np.float32)
    ids1 = mem.add(k1, rng.standard_normal((120, 16)).astype(np.float32),
                   segment=1)
    q = np.concatenate([K[:5], k1[:5]]).astype(np.float32)
    seg_ids, _, _ = mem.retrieve(q, k=8, top_t=8, segment=1)
    assert np.isin(seg_ids[seg_ids >= 0], ids1).all()
    rec_ids, _, _ = mem.retrieve(q, k=8, top_t=8, recency=120)
    assert (rec_ids[rec_ids >= 0] >= 1500).all()
    # recency ∩ segment 0 = empty → all padding, and attend returns zeros
    both, _, _ = mem.retrieve(q, k=8, top_t=8, recency=120, segment=0)
    assert (both == -1).all()
    out, aids = mem.attend(q, k=8, top_t=8, recency=120, segment=0)
    assert (aids == -1).all() and (out == 0).all()
