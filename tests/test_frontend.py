"""Serving front-end (ISSUE 8 tentpole, DESIGN.md §3.12).

Pins, per the acceptance criteria:

1. Determinism: a request served inside a coalesced batch is BITWISE
   identical to the same request served solo at the same index epoch —
   under genuinely concurrent clients.
2. No recompiles: coalescing reuses the engine's power-of-two padding
   buckets, so batching across arbitrary arrival patterns adds ZERO jit
   cache entries beyond the buckets solo serving already compiled.
3. Deadline flushing: a partial batch dispatches once the oldest request
   has spent half its deadline budget queued (and `max_delay_ms` clamps
   that wait under generous deadlines).
4. Tenant filters: standing per-tenant bitmaps are cached per index
   epoch (one device upload per tenant per epoch), LRU-evicted at
   capacity, and invalidated by mutation.
5. Mutations are barriers: searches submitted before an enqueued
   mutation serve the pre-mutation epoch, searches after it the
   post-mutation epoch — observable via SearchResult.epoch.
6. Durability: save/open round-trips the batching config and the tenant
   registry alongside the engine snapshot.
7. Replica fan-out (subprocess, 8 virtual devices): policy="replica"
   shards coalesced batches across devices with bitwise-local results.
"""
import subprocess
import sys
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.search import search_jit_batched
from repro.data.vectors import make_manifold
from repro.serve.api import SearchParams
from repro.serve.engine import AnnEngine
from repro.serve.frontend import (ServingFrontend, TenantFilterBank,
                                  UnknownTenantError)

N, D, NQ = 3_000, 24, 32


@pytest.fixture(scope="module")
def ds():
    return make_manifold(jax.random.PRNGKey(0), n=N, d=D, nq=NQ,
                         intrinsic_dim=8)


@pytest.fixture()
def engine(ds):
    return AnnEngine.build(jax.random.PRNGKey(1), ds.X, 16,
                           spill_mode="soar", train_iters=5)


# ------------------------------------------------------------- determinism
def test_coalesced_equals_solo(ds, engine):
    """Concurrent single-query clients coalesce into shared dispatches;
    every client's rows are bitwise the solo engine answer."""
    solo = {i: engine.search(ds.Q[i:i + 1], k=6) for i in range(NQ)}
    with ServingFrontend(engine, policy="local",
                         default_deadline_ms=200.0) as fe:
        results = {}

        def client(i):
            results[i] = fe.search(ds.Q[i:i + 1], SearchParams(k=6))

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(NQ)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = dict(fe.stats)
    assert stats["requests"] == NQ
    assert stats["dispatches"] < NQ          # coalescing actually happened
    assert stats["coalesced"] == NQ - stats["dispatches"]
    for i in range(NQ):
        assert np.array_equal(results[i].ids, solo[i][0]), i
        assert np.array_equal(results[i].scores, solo[i][1]), i
        assert results[i].batch_size >= 1
        assert results[i].queued_us >= 0.0


def test_inline_filter_dispatches_solo(ds, engine):
    mask = np.zeros(N, np.uint8)
    mask[: N // 4] = 1
    ref_ids, ref_sc = engine.search(ds.Q[:3], k=5, filter_mask=mask)
    with ServingFrontend(engine, policy="local") as fe:
        r = fe.search(ds.Q[:3], SearchParams(k=5, filter_mask=mask))
        assert fe.stats["dispatches"] == 1 and r.batch_size == 3
    assert np.array_equal(r.ids, ref_ids)
    assert np.array_equal(r.scores, ref_sc)


# ------------------------------------------------------------ no recompiles
def test_no_recompilation_from_coalescing(ds, engine):
    """Coalesced dispatch reuses the solo path's padding buckets: after
    warming the buckets solo traffic uses, arbitrary concurrent batch
    sizes through the front-end add no jit cache entries."""
    from repro.analysis import CacheWatch
    for nq in (1, 9, 17):            # warm buckets 8, 16, 32
        engine.search(ds.Q[:nq], k=6)
    with CacheWatch(search_jit_batched):         # shared sentinel (§3.14)
        with ServingFrontend(engine, policy="local", max_batch=32,
                             default_deadline_ms=100.0) as fe:
            futs = []
            for i in range(24):      # mixed sizes, concurrent arrival
                nq = 1 + (i % 3)
                futs.append(fe.submit(ds.Q[i % NQ:i % NQ + nq],
                                      SearchParams(k=6)))
            for f in futs:
                f.result()


# --------------------------------------------------------- deadline flushes
def test_deadline_flushes_partial_batch(ds, engine):
    """max_delay_ms=None → pure half-deadline policy: a partial batch
    (3 ≪ max_batch) must dispatch once half the 80 ms budget is spent,
    not wait for the batch to fill."""
    with ServingFrontend(engine, policy="local", max_batch=64,
                         max_delay_ms=None) as fe:
        t0 = time.perf_counter()
        futs = [fe.submit(ds.Q[i:i + 1],
                          SearchParams(k=5, deadline_ms=80.0))
                for i in range(3)]
        res = [f.result(timeout=5.0) for f in futs]
        elapsed_ms = (time.perf_counter() - t0) * 1e3
    assert all(r.batch_size == 3 for r in res)   # one coalesced dispatch
    assert fe.stats["dispatches"] == 1
    # flushed by the deadline timer: waited at least ~half the budget
    # (not dispatched instantly as a full batch) but well under the
    # full deadline plus engine time
    assert elapsed_ms < 5_000


def test_max_delay_clamps_generous_deadlines(ds, engine):
    """A 10 s deadline must NOT stall the queue 5 s — max_delay_ms caps
    the batching wait."""
    with ServingFrontend(engine, policy="local", max_batch=64,
                         max_delay_ms=5.0) as fe:
        t0 = time.perf_counter()
        fe.search(ds.Q[:1], SearchParams(k=5, deadline_ms=10_000.0))
        elapsed = time.perf_counter() - t0
    assert elapsed < 3.0


# ---------------------------------------------------------- tenant filters
def test_tenant_filter_serving(ds, engine):
    ids_t0 = np.flatnonzero(np.arange(N) % 3 == 0)
    with ServingFrontend(engine, policy="local") as fe:
        fe.register_tenant("t0", ids=ids_t0)
        r = fe.search(ds.Q, SearchParams(k=6, tenant="t0"))
        # tenant serving == engine-level subset filtering, bitwise
        ref_ids, ref_sc = engine.search(ds.Q, k=6, filter_ids=ids_t0)
        assert np.array_equal(r.ids, ref_ids)
        assert np.array_equal(r.scores, ref_sc)
        ok = r.ids[r.ids >= 0]
        assert (ok % 3 == 0).all()
        with pytest.raises(UnknownTenantError):
            fe.search(ds.Q[:1], SearchParams(k=3, tenant="nope"))


def test_tenant_lru_eviction_and_epoch_invalidation(ds, engine):
    bank = TenantFilterBank(engine.index, capacity=2)
    for t in ("a", "b", "c"):
        bank.register(t, ids=np.arange(100))
    bank.get("a"); bank.get("b")
    assert bank.fills == 2
    bank.get("a"); bank.get("b")               # steady state: cache hits
    assert bank.fills == 2
    bank.get("c")                              # fills + evicts "a" (LRU)
    assert bank.fills == 3 and "a" not in bank._cache
    bank.get("a")                              # re-upload after eviction
    assert bank.fills == 4
    engine.remove([0, 1], hard=False)          # mutation bumps the epoch
    bank.get("a")                              # stale → rebuild
    assert bank.fills == 5
    assert int(np.asarray(bank.get("a"))[0]) == 0   # tombstone composed in
    assert bank.fills == 5                     # second get in-epoch: hit
    bank.extend("a", [200, 201])               # registry bump → rebuild
    assert int(np.asarray(bank.get("a"))[200]) == 1
    assert bank.fills == 6


def test_tenant_coalescing_same_tenant_only(ds, engine):
    """Same-tenant requests share a dispatch; different tenants never
    share one (their filter bitmaps differ)."""
    with ServingFrontend(engine, policy="local",
                         default_deadline_ms=200.0) as fe:
        fe.register_tenant("a", ids=np.arange(0, N, 2))
        fe.register_tenant("b", ids=np.arange(1, N, 2))
        futs = ([fe.submit(ds.Q[i:i + 1], SearchParams(k=4, tenant="a"))
                 for i in range(4)]
                + [fe.submit(ds.Q[i:i + 1], SearchParams(k=4, tenant="b"))
                   for i in range(4)])
        res = [f.result(timeout=10.0) for f in futs]
    assert all(r.tenant == "a" for r in res[:4])
    assert all(r.tenant == "b" for r in res[4:])
    for r in res[:4]:
        ok = r.ids[r.ids >= 0]
        assert (ok % 2 == 0).all()
    for r in res[4:]:
        ok = r.ids[r.ids >= 0]
        assert (ok % 2 == 1).all()
    assert fe.stats["dispatches"] >= 2


# ------------------------------------------------------- mutation barriers
def test_mutation_is_a_barrier(ds, engine):
    """Searches queued before a mutation serve the old epoch; searches
    queued after it serve the new one — even when everything is enqueued
    back-to-back before the dispatcher wakes."""
    from concurrent.futures import Future
    from repro.serve.frontend import _Request
    # generous deadlines: queued-behind-a-barrier requests must not be
    # shed by deadline enforcement while the mutation (re)compiles
    with ServingFrontend(engine, policy="local", max_batch=64,
                         max_delay_ms=5.0) as fe:
        e0 = engine.index._alive_epoch
        pre = [fe.submit(ds.Q[i:i + 1],
                         SearchParams(k=5, deadline_ms=10_000.0))
               for i in range(3)]
        mfut: Future = Future()
        fe._enqueue(_Request("remove", mfut,
                             payload=(np.arange(N), False)))
        post = [fe.submit(ds.Q[i:i + 1],
                          SearchParams(k=5, deadline_ms=10_000.0))
                for i in range(3)]
        pre_r = [f.result(timeout=10.0) for f in pre]
        assert mfut.result(timeout=10.0) == N
        post_r = [f.result(timeout=10.0) for f in post]
    for r in pre_r:                  # served before the tombstoning
        assert r.epoch == e0
        assert (r.ids >= 0).any()
    for r in post_r:                 # served after: everything is dead
        assert r.epoch > e0
        assert (r.ids == -1).all()


def test_add_with_tenant_is_atomic(ds, engine):
    """add(tenant=...) extends the tenant's standing filter in the same
    barrier as the insert: the fresh points are immediately findable
    under their tenant, and only the allowed ids are ever served."""
    rng = np.random.default_rng(7)
    with ServingFrontend(engine, policy="local") as fe:
        fe.register_tenant("t", ids=[0])
        new = rng.normal(size=(5, D)).astype(np.float32)
        ids = fe.add(new, tenant="t")
        allowed = {0, *map(int, ids)}
        r = fe.search(new, SearchParams(k=3, tenant="t"))
        served = set(map(int, r.ids[r.ids >= 0]))
        assert served and served <= allowed
        # a brand-new tenant can be created by its first add, too
        ids2 = fe.add(new, tenant="fresh")
        r2 = fe.search(new, SearchParams(k=3, tenant="fresh"))
        srv2 = set(map(int, r2.ids[r2.ids >= 0]))
        assert srv2 and srv2 <= set(map(int, ids2))


# -------------------------------------------------------------- durability
def test_save_open_round_trip(tmp_path, ds, engine):
    with ServingFrontend(engine, policy="local", max_batch=48,
                         max_delay_ms=3.0,
                         default_deadline_ms=77.0) as fe:
        fe.register_tenant("acme", ids=np.arange(0, N, 5))
        ref = fe.search(ds.Q, SearchParams(k=6, tenant="acme"))
        fe.save(str(tmp_path / "snap"))
    fe2 = ServingFrontend.open(str(tmp_path / "snap"))
    try:
        assert fe2.max_batch == 48 and fe2.max_delay_ms == 3.0
        assert fe2.default_deadline_ms == 77.0
        assert fe2.tenants.tenants == ["acme"]
        r = fe2.search(ds.Q, SearchParams(k=6, tenant="acme"))
        assert np.array_equal(r.ids, ref.ids)
        assert np.array_equal(r.scores, ref.scores)
    finally:
        fe2.close()


def test_close_rejects_new_work(ds, engine):
    fe = ServingFrontend(engine, policy="local")
    fe.search(ds.Q[:1], SearchParams(k=3))
    fe.close()
    fe.close()                                    # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        fe.submit(ds.Q[:1], SearchParams(k=3))


# ----------------------------------------------------------- replica policy
SCRIPT_REPLICA = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.data.vectors import make_manifold
from repro.serve.api import SearchParams
from repro.serve.engine import AnnEngine
from repro.serve.frontend import ServingFrontend

assert len(jax.devices()) == 8
ds = make_manifold(jax.random.PRNGKey(0), n=3_000, d=24, nq=32,
                   intrinsic_dim=8)
eng = AnnEngine.build(jax.random.PRNGKey(1), ds.X, 16, train_iters=5)
solo_ids, solo_sc = eng.search(ds.Q, k=6)

fe = ServingFrontend(eng, policy="replica", default_deadline_ms=200.0)
r = fe.search(ds.Q, SearchParams(k=6))
assert fe.stats["replica_dispatches"] == 1
assert np.array_equal(r.ids, solo_ids), "replica ids != local"
assert np.array_equal(r.scores, solo_sc), "replica scores != local"

# tenant filter under replica fan-out, still bitwise local
fe.register_tenant("t", ids=np.arange(0, 3_000, 2))
rt = fe.search(ds.Q, SearchParams(k=6, tenant="t"))
ref_ids, ref_sc = eng.search(ds.Q, k=6, filter_ids=np.arange(0, 3_000, 2))
assert np.array_equal(rt.ids, ref_ids)
assert np.array_equal(rt.scores, ref_sc)

# "auto" on 8 devices picks replica
fe.policy = "auto"
fe.search(ds.Q, SearchParams(k=6))
assert fe.stats["replica_dispatches"] == 3
fe.close()
print("OK")
"""


def test_replica_policy_multidevice():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT_REPLICA], capture_output=True,
        text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                        "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout
