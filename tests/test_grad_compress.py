"""int8 compressed psum vs exact psum (8-device subprocess not needed:
shard_map over a 1-device mesh still exercises the code path; the
multi-device semantics run in test_distributed.py)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.train.grad_compress import compressed_psum, compressed_psum_with_feedback

mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 3.0

exact = jnp.sum(x, axis=0)
f = shard_map(lambda xs: compressed_psum(xs[0], "data"), mesh=mesh,
              in_specs=P("data"), out_specs=P())
got = f(x)
rel = float(jnp.max(jnp.abs(got - exact)) / jnp.max(jnp.abs(exact)))
assert rel < 0.05, f"one-shot int8 psum rel err {rel}"

# error feedback: averaged over steps, bias vanishes
err = jnp.zeros((8, 128))
acc_exact = jnp.zeros(128)
acc_comp = jnp.zeros(128)
def step(key, err):
    g = jax.random.normal(key, (8, 128))
    def body(gs, es):
        red, ne = compressed_psum_with_feedback(gs[0], es[0], "data")
        return red, ne[None]                     # residual stays per-shard
    f2 = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=(P(), P("data")))
    red, new_err = f2(g, err)
    return g.sum(0), red, new_err
key = jax.random.PRNGKey(1)
for i in range(30):
    key, k = jax.random.split(key)
    ex, red, err = step(k, err)
    acc_exact += ex
    acc_comp += red
rel = float(jnp.linalg.norm(acc_comp - acc_exact) / jnp.linalg.norm(acc_exact))
assert rel < 0.05, f"error-feedback accumulated rel err {rel}"
# and error feedback must beat naive compression accumulated over steps
acc_naive = jnp.zeros(128)
key = jax.random.PRNGKey(1)
f1 = shard_map(lambda gs: compressed_psum(gs[0], "data"), mesh=mesh,
               in_specs=P("data"), out_specs=P())
for i in range(30):
    key, k = jax.random.split(key)
    acc_naive += f1(jax.random.normal(k, (8, 128)))
rel_naive = float(jnp.linalg.norm(acc_naive - acc_exact) / jnp.linalg.norm(acc_exact))
assert rel < rel_naive, (rel, rel_naive)
print("OK")
"""


def test_compressed_psum_multidevice():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_quantize_roundtrip_bounds():
    from repro.train.grad_compress import quantize
    x = jnp.linspace(-5, 5, 100)
    scale = jnp.float32(5 / 127.0)
    q = quantize(x, scale)
    back = q.astype(jnp.float32) * scale
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) / 2 + 1e-6
