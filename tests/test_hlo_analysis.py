"""Validate the HLO static analyzer against hand-computable programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, shape_bytes


def _compile_text(f, *abstract):
    return jax.jit(f).lower(*abstract).compile().as_text()


def test_shape_bytes():
    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("bf16[128]{0}") == 256
    assert shape_bytes("(f32[2], s8[4])") == 12
    assert shape_bytes("pred[]") == 1


def test_single_matmul_flops():
    f = lambda a, b: a @ b
    txt = _compile_text(f, jax.ShapeDtypeStruct((64, 32), jnp.float32),
                        jax.ShapeDtypeStruct((32, 16), jnp.float32))
    r = analyze(txt)
    assert r["flops"] == pytest.approx(2 * 64 * 32 * 16, rel=1e-6)


@pytest.mark.parametrize("iters", [1, 5, 23])
def test_scan_flops_scaled_by_trip_count(iters):
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, jnp.arange(iters))
        return out
    txt = _compile_text(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze(txt)
    expected = 2 * 128**3 * iters
    assert r["flops"] == pytest.approx(expected, rel=0.05), \
        (r["flops"], expected)


def test_nested_scan_multiplier():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c2, None
        out, _ = jax.lax.scan(outer, x, jnp.arange(4))
        return out
    txt = _compile_text(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    r = analyze(txt)
    expected = 2 * 64**3 * 3 * 4
    assert r["flops"] == pytest.approx(expected, rel=0.05)


def test_hbm_bytes_scale_with_loop():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, jnp.arange(10))
        return out
    txt = _compile_text(f, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                        jax.ShapeDtypeStruct((256, 256), jnp.float32))
    r = analyze(txt)
    # each iteration must re-read w (256*256*4 = 262144 B) → ≥ 10×
    assert r["hbm_bytes"] >= 10 * 262144
