import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_ivf, search_numpy, search_jit, pack_ivf, true_neighbors
from repro.data.vectors import make_manifold


@pytest.fixture(scope="module")
def small_ds():
    ds = make_manifold(jax.random.PRNGKey(0), n=20_000, d=32, nq=50,
                       intrinsic_dim=8)
    tn = true_neighbors(ds.X, ds.Q, k=10)
    return ds, tn


@pytest.fixture(scope="module")
def soar_index(small_ds):
    ds, _ = small_ds
    return build_ivf(jax.random.PRNGKey(1), ds.X, 64, spill_mode="soar",
                     pq_subspaces=8, train_iters=6)


def test_csr_validity(soar_index):
    idx = soar_index
    assert idx.starts[0] == 0 and idx.starts[-1] == idx.n_assignments
    assert np.all(np.diff(idx.starts) >= 0)
    # every point appears exactly twice (primary + spill), distinct partitions
    counts = np.bincount(idx.point_ids, minlength=idx.n_points)
    assert np.all(counts == 2)
    assert np.all(idx.assignments[:, 0] != idx.assignments[:, 1])
    # point_ids in partition p really are assigned to p
    for p in (0, 13, 63):
        seg = idx.point_ids[idx.starts[p]:idx.starts[p + 1]]
        ok = (idx.assignments[seg] == p).any(axis=1)
        assert ok.all()


def test_full_probe_is_exact(small_ds, soar_index):
    ds, tn = small_ds
    ids, stats = search_numpy(soar_index, ds.Q, top_t=64, final_k=10,
                              rerank_budget=0)
    rec = (ids[:, :, None] == tn[:, None, :]).any(-1).mean()
    assert rec == 1.0
    assert np.all(stats.points_read == soar_index.n_assignments)


def test_recall_improves_with_probes(small_ds, soar_index):
    ds, tn = small_ds
    recs = []
    for t in (1, 4, 16):
        ids, _ = search_numpy(soar_index, ds.Q, top_t=t, final_k=10)
        recs.append((ids[:, :, None] == tn[:, None, :]).any(-1).mean())
    assert recs[0] <= recs[1] <= recs[2]
    assert recs[2] > 0.8


def test_no_duplicate_results(small_ds, soar_index):
    ds, _ = small_ds
    ids, _ = search_numpy(soar_index, ds.Q, top_t=8, final_k=10,
                          rerank_budget=100)
    for row in ids:
        row = row[row >= 0]
        assert len(set(row.tolist())) == len(row)


def test_pq_path_close_to_exact_path(small_ds, soar_index):
    ds, tn = small_ds
    ids_pq, _ = search_numpy(soar_index, ds.Q, top_t=16, final_k=10,
                             rerank_budget=400)
    rec_pq = (ids_pq[:, :, None] == tn[:, None, :]).any(-1).mean()
    ids_ex, _ = search_numpy(soar_index, ds.Q, top_t=16, final_k=10,
                             rerank_budget=0)
    rec_ex = (ids_ex[:, :, None] == tn[:, None, :]).any(-1).mean()
    assert rec_pq >= rec_ex - 0.05


def test_jit_path_matches_numpy_path(small_ds, soar_index):
    ds, tn = small_ds
    packed = pack_ivf(soar_index)
    jids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=16, final_k=10,
                         rerank_budget=512)
    jids = np.asarray(jids)
    rec_jit = (jids[:, :, None] == tn[:, None, :]).any(-1).mean()
    ids_np, _ = search_numpy(soar_index, ds.Q, top_t=16, final_k=10,
                             rerank_budget=512)
    rec_np = (ids_np[:, :, None] == tn[:, None, :]).any(-1).mean()
    assert abs(rec_jit - rec_np) < 0.05
    assert rec_jit > 0.75


def test_memory_model_matches_paper(small_ds, soar_index):
    """§3.5: spilling adds 4 + d/2s bytes/pt; relative growth ≈ 1/(8s+1)
    for f32 rerank data."""
    ds, _ = small_ds
    none_idx = build_ivf(jax.random.PRNGKey(1), ds.X, 64, spill_mode="none",
                         pq_subspaces=8, train_iters=3)
    m_soar = soar_index.memory_bytes(rerank="f32")
    m_none = none_idx.memory_bytes(rerank="f32")
    d = ds.X.shape[1]
    s = d // 8
    per_pt_extra = 4 + d / (2 * s)
    expected_growth = per_pt_extra * ds.X.shape[0]
    got_growth = m_soar["total"] - m_none["total"]
    assert abs(got_growth - expected_growth) / expected_growth < 1e-6
    rel = got_growth / m_none["total"]
    assert abs(rel - 1 / (8 * s + 1)) < 0.03
