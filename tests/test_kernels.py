"""Per-kernel allclose validation vs pure-jnp oracles (interpret mode on CPU).

Sweeps shapes/dtypes per the deliverable spec; hypothesis property tests on
the assignment kernels' invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- pq_score
@pytest.mark.parametrize("nq,n,m", [
    (1, 64, 8), (7, 300, 16), (128, 512, 16), (33, 1000, 4), (2, 2048, 32),
])
def test_pq_score_matches_ref(nq, n, m):
    luts = _rand(0, nq, m, 16)
    codes = jax.random.randint(jax.random.PRNGKey(1), (n, m), 0, 16)
    got = ops.pq_score(luts, codes)
    want = ref.pq_score_ref(luts, codes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bq,bn", [(8, 128), (128, 512), (256, 256)])
def test_pq_score_block_shape_invariance(bq, bn):
    luts = _rand(2, 17, 8, 16)
    codes = jax.random.randint(jax.random.PRNGKey(3), (137, 8), 0, 16)
    got = ops.pq_score(luts, codes, bq=bq, bn=bn)
    want = ref.pq_score_ref(luts, codes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pq_score_dtype_bf16_lut():
    luts = _rand(4, 4, 16, 16).astype(jnp.bfloat16).astype(jnp.float32)
    codes = jax.random.randint(jax.random.PRNGKey(5), (256, 16), 0, 16)
    got = ops.pq_score(luts, codes)
    want = ref.pq_score_ref(luts, codes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- vq_assign
@pytest.mark.parametrize("n,c,d", [
    (100, 16, 32), (513, 100, 64), (1000, 777, 128), (64, 2000, 100),
])
def test_vq_assign_matches_ref(n, c, d):
    X = _rand(10, n, d)
    C = _rand(11, c, d)
    idx, val = ops.vq_assign(X, C)
    ridx, rval = ref.vq_assign_ref(X, C)
    # compare chosen distances (ties could differ in index, never in value)
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                               rtol=1e-4, atol=1e-4)
    chosen = jnp.sum((X - C[idx]) ** 2, -1)
    ref_chosen = jnp.sum((X - C[ridx]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(chosen), np.asarray(ref_chosen),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bn,bc", [(128, 128), (512, 256), (256, 1024)])
def test_vq_assign_block_invariance(bn, bc):
    X = _rand(12, 300, 48)
    C = _rand(13, 500, 48)
    idx, _ = ops.vq_assign(X, C, bn=bn, bc=bc)
    ridx, _ = ref.vq_assign_ref(X, C)
    assert (np.asarray(idx) == np.asarray(ridx)).mean() > 0.999


# -------------------------------------------------------------- soar_assign
@pytest.mark.parametrize("n,c,d,lam", [
    (200, 64, 32, 1.0), (513, 256, 64, 1.5), (100, 1000, 100, 0.5),
])
def test_soar_assign_matches_ref(n, c, d, lam):
    X = _rand(20, n, d)
    C = _rand(21, c, d)
    prim, _ = ref.vq_assign_ref(X, C)
    r = X - C[prim]
    rhat = r / jnp.maximum(jnp.linalg.norm(r, -1, keepdims=True), 1e-12)
    idx, val = ops.soar_assign(X, rhat, prim, C, lam=lam)
    ridx, rval = ref.soar_assign_ref(X, rhat, prim, C, lam)
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                               rtol=1e-4, atol=1e-4)
    assert not np.any(np.asarray(idx) == np.asarray(prim)), "spill == primary"


def test_soar_assign_lam0_is_second_closest():
    X = _rand(22, 128, 16)
    C = _rand(23, 64, 16)
    prim, _ = ref.vq_assign_ref(X, C)
    rhat = jnp.zeros_like(X).at[:, 0].set(1.0)
    idx, _ = ops.soar_assign(X, rhat, prim, C, lam=0.0)
    d2 = (jnp.sum(C * C, -1)[None] - 2 * X @ C.T)
    d2 = jnp.where(jax.nn.one_hot(prim, 64, dtype=bool), jnp.inf, d2)
    second = jnp.argmin(d2, -1)
    assert (np.asarray(idx) == np.asarray(second)).mean() > 0.999


# ------------------------------------------- fused batched assignment path
@pytest.mark.parametrize("n,c,d,lam", [(300, 64, 32, 1.0), (513, 130, 48, 0.7)])
def test_assign_fused_pallas_route_matches_gemm_route(n, c, d, lam):
    """The Pallas (vq_assign + soar_assign kernels, interpret mode here)
    route of the sharded-build assignment agrees with the chunked two-GEMM
    route — same argmins, loss computed by the fused kernel."""
    from repro.kernels.soar_assign import assign_fused

    X = _rand(30, n, d)
    C = _rand(31, c, d)
    gemm = np.asarray(assign_fused(X, C, lam=lam, n_spills=1, chunk=256,
                                   use_pallas=False))
    pall = np.asarray(assign_fused(X, C, lam=lam, n_spills=1,
                                   use_pallas=True, interpret=True))
    # tie-adjacent rows may flip under different GEMM tilings; require
    # near-total agreement rather than bitwise identity
    assert (gemm[:, 0] == pall[:, 0]).mean() > 0.999
    assert (gemm[:, 1] == pall[:, 1]).mean() > 0.999
    assert not np.any(pall[:, 1] == pall[:, 0])


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 150), c=st.integers(4, 90), d=st.integers(2, 64),
       lam=st.floats(0.0, 3.0), spills=st.integers(1, 3),
       seed=st.integers(0, 2**30))
def test_assign_fused_property(n, c, d, lam, spills, seed):
    """Fused batched assignment invariants: column 0 is the Euclidean
    argmin, every row has distinct assignments, spills minimize the
    accumulated SOAR loss over the remaining centroids."""
    from repro.kernels.soar_assign import assign_fused

    spills = min(spills, c - 1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    C = jax.random.normal(k2, (c, d))
    A = np.asarray(assign_fused(X, C, lam=float(lam), n_spills=spills,
                                chunk=64))
    assert A.shape == (n, 1 + spills)
    d_all = jnp.sum((X[:, None, :] - C[None, :, :]) ** 2, -1)
    np.testing.assert_array_equal(A[:, 0], np.asarray(jnp.argmin(d_all, -1)))
    for i in range(n):
        assert len(set(A[i].tolist())) == 1 + spills
    # spill 1 minimizes the single-spill SOAR loss over non-primary centroids
    r = X - C[A[:, 0]]
    rhat = r / jnp.maximum(jnp.linalg.norm(r, axis=-1, keepdims=True), 1e-12)
    rp = X[:, None, :] - C[None, :, :]
    loss = jnp.sum(rp * rp, -1) + lam * jnp.einsum("nd,ncd->nc", rhat, rp) ** 2
    loss = jnp.where(jax.nn.one_hot(A[:, 0], c, dtype=bool), jnp.inf, loss)
    chosen = np.asarray(loss)[np.arange(n), A[:, 1]]
    np.testing.assert_allclose(chosen, np.asarray(jnp.min(loss, -1)),
                               rtol=1e-3, atol=1e-3)


# ----------------------------------------------------- hypothesis properties
@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 200), c=st.integers(2, 120), d=st.integers(2, 96),
       seed=st.integers(0, 2**30))
def test_vq_assign_property(n, c, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    C = jax.random.normal(k2, (c, d))
    idx, val = ops.vq_assign(X, C)
    # invariant: reported min distance equals distance to reported centroid,
    # and is <= distance to every centroid
    d_all = jnp.sum((X[:, None, :] - C[None, :, :]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(val),
                               np.asarray(jnp.min(d_all, -1)), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(jnp.sum((X - C[idx]) ** 2, -1)),
        np.asarray(jnp.min(d_all, -1)), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 100), c=st.integers(3, 80), d=st.integers(2, 64),
       lam=st.floats(0.0, 4.0), seed=st.integers(0, 2**30))
def test_soar_assign_property(n, c, d, lam, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    C = jax.random.normal(k2, (c, d))
    prim, _ = ref.vq_assign_ref(X, C)
    r = X - C[prim]
    rhat = r / jnp.maximum(jnp.linalg.norm(r, -1, keepdims=True), 1e-12)
    idx, val = ops.soar_assign(X, rhat, prim, C, lam=float(lam))
    # invariant: the kernel's loss is minimal over all non-primary centroids
    rp = X[:, None, :] - C[None, :, :]
    loss = jnp.sum(rp * rp, -1) + lam * jnp.einsum("nd,ncd->nc", rhat, rp) ** 2
    loss = jnp.where(jax.nn.one_hot(prim, c, dtype=bool), jnp.inf, loss)
    np.testing.assert_allclose(np.asarray(val), np.asarray(jnp.min(loss, -1)),
                               rtol=1e-3, atol=1e-3)
    assert not np.any(np.asarray(idx) == np.asarray(prim))


# --------------------------------------------------------------- tree_route
def _tree_tables(key, S, cmax, d, frac_pad=0.25):
    """Random router tables with ragged children (-1 pad like training)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(key), 3)
    SC = jax.random.normal(k1, (S, d), jnp.float32)
    CC = jax.random.normal(k2, (S, cmax, d), jnp.float32)
    ids = jnp.arange(S * cmax, dtype=jnp.int32).reshape(S, cmax)
    pad = jax.random.uniform(k3, (S, cmax)) < frac_pad
    pad = pad.at[:, 0].set(False)          # every super keeps >= 1 child
    CH = jnp.where(pad, -1, ids)
    CC = jnp.where(pad[:, :, None], 0.0, CC)
    return SC, CC, CH


@pytest.mark.parametrize("nq,S,cmax,d,tr", [
    (1, 4, 3, 8, 1), (7, 16, 9, 32, 3), (40, 8, 16, 16, 8),
    (130, 32, 5, 24, 4),   # nq > bq tile: multi-tile grid
])
def test_tree_route_pallas_matches_ref(nq, S, cmax, d, tr):
    from repro.kernels import tree_route as trk

    Q = _rand(40, nq, d)
    SC, CC, CH = _tree_tables(41, S, cmax, d)
    ws, wi = trk.tree_route_ref(Q, SC, CC, CH, t_route=tr)
    gs, gi = trk.tree_route_pallas(Q, SC, CC, CH, t_route=tr, bq=64,
                                   interpret=True)
    # same per-round supers (random normals: no super-score ties), so ids
    # must match exactly; -inf pad masks must coincide; finite scores are
    # the same dot products modulo accumulation order
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(gi))
    wmask = np.isfinite(np.asarray(ws))
    gmask = np.isfinite(np.asarray(gs))
    np.testing.assert_array_equal(wmask, gmask)
    np.testing.assert_allclose(np.asarray(gs)[gmask], np.asarray(ws)[wmask],
                               rtol=1e-4, atol=1e-4)


def test_tree_route_pallas_tile_invariance():
    from repro.kernels import tree_route as trk

    Q = _rand(50, 37, 16)
    SC, CC, CH = _tree_tables(51, 8, 6, 16)
    a = trk.tree_route_pallas(Q, SC, CC, CH, t_route=2, bq=8, interpret=True)
    b = trk.tree_route_pallas(Q, SC, CC, CH, t_route=2, bq=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               rtol=1e-5, atol=1e-5)


def test_tree_route_dispatcher_cpu_uses_ref():
    """On CPU the dispatcher must take the jnp reference path and agree
    with an explicit ref call bitwise."""
    from repro.kernels import tree_route as trk

    Q = _rand(60, 9, 8)
    SC, CC, CH = _tree_tables(61, 4, 5, 8)
    ds_, di_ = trk.tree_route(Q, SC, CC, CH, t_route=2)
    rs_, ri_ = trk.tree_route_ref(Q, SC, CC, CH, t_route=2)
    np.testing.assert_array_equal(np.asarray(di_), np.asarray(ri_))
    np.testing.assert_array_equal(np.asarray(ds_), np.asarray(rs_))
