import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.kmeans import (train_kmeans, kmeans_pp_init, lloyd_step,
                               assign_euclidean, assign_euclidean_topk)


def test_distortion_monotone():
    X = jax.random.normal(jax.random.PRNGKey(0), (5000, 16))
    res = train_kmeans(jax.random.PRNGKey(1), X, 32, iters=10)
    h = res.history
    assert all(h[i + 1] <= h[i] + 1e-6 for i in range(len(h) - 1)), h


def test_assignment_is_argmin():
    X = jax.random.normal(jax.random.PRNGKey(2), (300, 8))
    C = jax.random.normal(jax.random.PRNGKey(3), (20, 8))
    a = assign_euclidean(X, C)
    brute = jnp.argmin(jnp.sum((X[:, None] - C[None]) ** 2, -1), -1)
    assert np.array_equal(np.asarray(a), np.asarray(brute))


def test_kmeanspp_centers_are_datapoints():
    X = jax.random.normal(jax.random.PRNGKey(4), (1000, 8))
    C = kmeans_pp_init(jax.random.PRNGKey(5), X, 16)
    d = jnp.min(jnp.sum((C[:, None] - X[None]) ** 2, -1), -1)
    assert float(jnp.max(d)) < 1e-9


def test_empty_cluster_keeps_centroid():
    X = jnp.ones((50, 4))                      # all identical points
    C = jnp.stack([jnp.ones(4), jnp.full(4, 100.0)])
    C2, assign, _ = lloyd_step(X, C, 2)
    assert np.array_equal(np.asarray(assign), np.zeros(50))
    np.testing.assert_allclose(np.asarray(C2[1]), np.full(4, 100.0))


def test_topk_assign_consistent():
    X = jax.random.normal(jax.random.PRNGKey(6), (200, 8))
    C = jax.random.normal(jax.random.PRNGKey(7), (30, 8))
    top2 = assign_euclidean_topk(X, C, 2)
    assert np.array_equal(np.asarray(top2[:, 0]), np.asarray(assign_euclidean(X, C)))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 300), c=st.integers(2, 16), d=st.integers(2, 24),
       seed=st.integers(0, 1 << 30))
def test_kmeans_property_distortion_beats_random(n, c, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    res = train_kmeans(k2, X, c, iters=5)
    rand_C = jax.random.normal(jax.random.fold_in(k2, 9), (c, d))
    rand_d = float(jnp.mean(jnp.min(jnp.sum((X[:, None] - rand_C[None]) ** 2, -1), -1)))
    assert float(res.distortion) <= rand_d + 1e-6
