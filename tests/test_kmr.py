import jax
import numpy as np
import pytest

from repro.core import build_ivf, kmr_curve, points_to_recall, true_neighbors
from repro.core.kmr import rank_statistics
from repro.data.vectors import make_manifold


@pytest.fixture(scope="module")
def setup():
    ds = make_manifold(jax.random.PRNGKey(0), n=20_000, d=32, nq=50,
                       intrinsic_dim=8)
    tn = true_neighbors(ds.X, ds.Q, k=20)
    idx_none = build_ivf(jax.random.PRNGKey(1), ds.X, 64, spill_mode="none",
                         train_iters=6)
    idx_soar = build_ivf(jax.random.PRNGKey(1), ds.X, 64, spill_mode="soar",
                         train_iters=6)
    return ds, tn, idx_none, idx_soar


def test_curve_monotone_and_complete(setup):
    ds, tn, idx_none, idx_soar = setup
    for idx in (idx_none, idx_soar):
        cv = kmr_curve(idx, ds.Q, tn, k=20)
        assert np.all(np.diff(cv.recall_at_t) >= -1e-6)
        assert abs(cv.recall_at_t[-1] - 1.0) < 1e-6
        assert abs(cv.points_at_t[-1] - idx.n_assignments) < 1e-3
        assert np.all(np.diff(cv.points_at_t) >= -1e-3)


def test_spilling_dominates_at_fixed_t(setup):
    """At the same partition count t, a spilled index can only improve
    rank-based recall (min over two ranks <= primary rank)."""
    ds, tn, idx_none, idx_soar = setup
    # identical centroids/primary => comparable rank space
    assert np.allclose(idx_none.centroids, idx_soar.centroids)
    cv_n = kmr_curve(idx_none, ds.Q, tn, k=20)
    cv_s = kmr_curve(idx_soar, ds.Q, tn, k=20)
    assert np.all(cv_s.recall_at_t >= cv_n.recall_at_t - 1e-6)


def test_points_to_recall_interpolation(setup):
    ds, tn, idx_none, _ = setup
    cv = kmr_curve(idx_none, ds.Q, tn, k=20)
    p50 = points_to_recall(cv, 0.5)
    p90 = points_to_recall(cv, 0.9)
    assert 0 < p50 <= p90 <= idx_none.n_assignments
    assert points_to_recall(cv, 1.1) == float("inf")


def test_rank_statistics_shapes(setup):
    ds, tn, _, idx_soar = setup
    pr, sr = rank_statistics(idx_soar, ds.Q, tn)
    assert pr.shape == (50, 20) and sr.shape == (50, 20)
    assert pr.min() >= 0 and pr.max() < 64
    assert not np.array_equal(pr, sr)
