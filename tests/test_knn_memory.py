"""SOAR-kNN attention memory: retrieval quality vs exact top-k attention."""
import jax
import numpy as np
import pytest

from repro.data.vectors import make_manifold
from repro.serve.knn_memory import KNNMemory, exact_topk_attention


@pytest.fixture(scope="module")
def setup():
    hd, n_ctx, nq = 32, 20_000, 64
    ds = make_manifold(jax.random.PRNGKey(0), n=n_ctx, d=hd, nq=nq,
                       intrinsic_dim=8)
    values = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (n_ctx, hd)), np.float32)
    return ds.X, values, ds.Q


def test_knn_attention_close_to_exact(setup):
    keys, values, q = setup
    mem = KNNMemory.build(keys, values, n_partitions=64, spill_mode="soar")
    out, ids = mem.attend(q, k=16, top_t=8)
    exact_out, exact_ids = exact_topk_attention(q, keys, values, k=16)
    key_recall = (ids[:, :, None] == exact_ids[:, None, :]).any(-1).mean()
    assert key_recall > 0.85, key_recall
    rel = np.linalg.norm(out - exact_out, axis=1) / np.maximum(
        np.linalg.norm(exact_out, axis=1), 1e-9)
    assert np.mean(rel) < 0.15, np.mean(rel)


def test_soar_beats_no_spill_at_fixed_probes(setup):
    keys, values, q = setup
    rec = {}
    for mode in ("none", "soar"):
        mem = KNNMemory.build(keys, values, n_partitions=64, spill_mode=mode)
        ids, _, _ = mem.retrieve(q, k=16, top_t=2)   # tight probe budget
        _, exact_ids = exact_topk_attention(q, keys, values, k=16)
        rec[mode] = (ids[:, :, None] == exact_ids[:, None, :]).any(-1).mean()
    assert rec["soar"] >= rec["none"] - 0.02, rec
