"""Launch-spec unit tests: model_flops accounting, serve rules, shape skips,
rule resolution — pure host-side logic (no device requirements)."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_rule_overrides
from repro.launch import specs as S
from repro.launch.mesh import BASE_RULES, build_rules
from repro.models.config import SHAPES, cell_applicable


def test_model_flops_moe_uses_active_params():
    dense = get_config("granite-3-2b")
    moe = get_config("qwen3-moe-30b-a3b")
    cell = SHAPES["train_4k"]
    f_moe = S.model_flops(moe, cell)
    n_total = S.param_count(moe)
    # active params must be well below total for a top-8-of-128 model
    active = f_moe / (6.0 * cell.global_batch * cell.seq_len)
    assert active < 0.35 * n_total
    # dense: active == total
    f_dense = S.model_flops(dense, cell)
    assert f_dense == pytest.approx(
        6.0 * S.param_count(dense) * cell.global_batch * cell.seq_len)


def test_decode_flops_counts_one_token_per_seq():
    cfg = get_config("granite-3-2b")
    f = S.model_flops(cfg, SHAPES["decode_32k"])
    assert f == pytest.approx(2.0 * S.param_count(cfg) * 128)


def test_serve_rules_replicate_small_keep_fsdp_large():
    small = get_config("granite-3-2b")
    big = get_config("mistral-large-123b")
    base = dict(BASE_RULES)
    assert S.serve_rules(small, base)["embed"] is None
    assert S.serve_rules(big, base)["embed"] == "data"


def test_batch1_rules_shard_kv_seq_over_everything():
    r = build_rules({}, multi_pod=False, batch_size=1)
    assert r["batch"] is None
    assert r["kv_seq"] == ("data", "model")
    r2 = build_rules({}, multi_pod=True, batch_size=1)
    assert r2["kv_seq"] == ("pod", "data", "model")


def test_cell_applicability_matrix():
    runnable = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = cell_applicable(cfg, shape)
            runnable += ok
    assert runnable == 31   # 10 + 10 + 9 + 2 (DESIGN.md §Shape skips)


def test_arch_overrides_resolve():
    for arch in ARCH_IDS:
        r = build_rules(dict(get_rule_overrides(arch)), batch_size=256)
        # a mesh axis may not be assigned twice within one tensor's spec —
        # spot-check the known conflict classes
        assert r.get("expert_mlp") is None
        if arch == "xlstm-350m":
            assert r["heads"] is None and r["head"] == "model"


def test_train_accum_targets():
    assert S.train_accum(get_config("granite-3-2b"), 16) == 8      # micro 2
    assert S.train_accum(get_config("mistral-large-123b"), 16) == 16  # micro 1
    assert S.train_accum(get_config("jamba-v0.1-52b"), 16) == 16
