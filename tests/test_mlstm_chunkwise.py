"""Chunkwise-parallel mLSTM must match the sequential recurrence exactly
(it's an algebraic reformulation, not an approximation) — incl. state
handoff across calls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (MLSTM_CHUNK, _mlstm_chunkwise,
                              _mlstm_sequential)


def _inputs(key, B, S, H, hd):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * hd ** -0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * hd ** -0.5
    v = jax.random.normal(ks[2], (B, S, H, hd))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2.0
    fg = jax.random.normal(ks[4], (B, S, H)) * 2.0 + 1.0
    return q, k, v, ig, fg


@pytest.mark.parametrize("S", [128, 256])
def test_chunkwise_matches_sequential(S):
    B, H, hd = 2, 3, 16
    q, k, v, ig, fg = _inputs(jax.random.PRNGKey(0), B, S, H, hd)
    C0 = jnp.zeros((B, H, hd, hd))
    n0 = jnp.zeros((B, H, hd))
    m0 = jnp.full((B, H), -1e30)
    h_seq, (C1, n1, m1) = _mlstm_sequential(q, k, v, ig, fg, C0, n0, m0, S)
    h_chk, (C2, n2, m2) = _mlstm_chunkwise(q, k, v, ig, fg, C0, n0, m0, S)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m1), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(C2), np.asarray(C1), rtol=2e-3,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(n2), np.asarray(n1), rtol=2e-3,
                               atol=2e-4)


def test_chunkwise_with_nonzero_initial_state():
    B, H, hd, S = 1, 2, 8, 128
    q, k, v, ig, fg = _inputs(jax.random.PRNGKey(1), B, S, H, hd)
    kc = jax.random.split(jax.random.PRNGKey(2), 3)
    C0 = jax.random.normal(kc[0], (B, H, hd, hd)) * 0.5
    n0 = jax.random.normal(kc[1], (B, H, hd)) * 0.5
    m0 = jnp.zeros((B, H))
    h_seq, _ = _mlstm_sequential(q, k, v, ig, fg, C0, n0, m0, S)
    h_chk, _ = _mlstm_chunkwise(q, k, v, ig, fg, C0, n0, m0, S)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq),
                               rtol=2e-4, atol=2e-4)


def test_state_handoff_chunked_to_sequential():
    """prefill (chunkwise) → decode (sequential single step) consistency."""
    B, H, hd, S = 1, 2, 8, 128
    q, k, v, ig, fg = _inputs(jax.random.PRNGKey(3), B, S + 1, H, hd)
    C0 = jnp.zeros((B, H, hd, hd))
    n0 = jnp.zeros((B, H, hd))
    m0 = jnp.full((B, H), -1e30)
    # full sequential over S+1 steps (can't chunk S+1; use seq as truth)
    h_all, _ = _mlstm_sequential(q, k, v, ig, fg, C0, n0, m0, S + 1)
    # chunkwise over first S, then one sequential step
    sl = lambda t: t[:, :S]
    _, (C1, n1, m1) = _mlstm_chunkwise(sl(q), sl(k), sl(v), sl(ig), sl(fg),
                                       C0, n0, m0, S)
    la = lambda t: t[:, S:]
    h_last, _ = _mlstm_sequential(la(q), la(k), la(v), la(ig), la(fg),
                                  C1, n1, m1, 1)
    np.testing.assert_allclose(np.asarray(h_last[:, 0]),
                               np.asarray(h_all[:, S]), rtol=2e-4, atol=2e-4)
