"""Per-arch smoke tests: REDUCED same-family configs, one forward/train step
on CPU, asserting output shapes + no NaNs (full configs are exercised only
via the dry-run). Plus prefill→decode consistency for causal archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T


def make_batch(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "audio":
        return {"frames": jax.random.normal(k1, (B, S, cfg.d_model)),
                "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        return {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
                "patches": jax.random.normal(k3, (B, cfg.n_prefix_embeds,
                                                  cfg.d_model)),
                "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch).smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(T.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.all(np.isfinite(np.asarray(g))), (arch, path)
    # hidden-state shape check
    x, _ = T.forward(params, batch, cfg)
    S = batch["labels"].shape[1] + (cfg.n_prefix_embeds
                                    if cfg.frontend == "vision" else 0)
    assert x.shape == (2, S, cfg.d_model)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).has_decode])
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must reproduce full-forward logits.

    capacity_factor is raised so no MoE tokens drop: capacity dropping is
    batch-shape-dependent (a documented property of capacity-based MoE), so
    exact consistency is only defined in the drop-free regime. fp32 compute:
    this test validates the state-handoff LOGIC exactly (bf16 recurrent-state
    rounding is a separate, expected effect).
    """
    cfg = get_config(arch).smoke_config().replace(
        capacity_factor=8.0, compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S)
    full = dict(batch)
    # full forward logits at position S-1 (counting text positions)
    x, _ = T.forward(params, full, cfg)
    logits_full = T.logits_from_hidden(params, x[:, -1:, :], cfg)

    prefix = cfg.n_prefix_embeds if cfg.frontend == "vision" else 0
    part = dict(batch)
    part["tokens"] = batch["tokens"][:, :S - 1]
    max_seq = S + prefix
    logits_pre, caches = T.prefill(params, part, cfg, max_seq=max_seq)
    last_tok = batch["tokens"][:, S - 1:S]
    idx = jnp.array(S - 1 + prefix, jnp.int32)
    logits_dec, _ = T.decode_step(params, last_tok, caches, idx, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_config_shapes(arch):
    """The FULL config builds abstract params with the exact assigned dims
    (no allocation: ShapeDtypeStructs only)."""
    cfg = get_config(arch)
    ab = T.abstract_params(cfg)
    leaves = jax.tree.leaves(ab)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    n_params = sum(int(np.prod(l.shape)) for l in leaves)
    # sanity: parameter count in the right ballpark for the model scale
    expected = {"granite-3-2b": 2.5e9, "nemotron-4-15b": 15e9,
                "minitron-8b": 8e9, "mistral-large-123b": 123e9,
                "paligemma-3b": 2.9e9, "qwen3-moe-30b-a3b": 30e9,
                "moonshot-v1-16b-a3b": 16e9, "xlstm-350m": 0.35e9,
                "hubert-xlarge": 1.0e9, "jamba-v0.1-52b": 52e9}[arch]
    assert 0.4 * expected < n_params < 2.6 * expected, (arch, n_params)


def test_moe_capacity_drop_keeps_output_finite():
    cfg = get_config("qwen3-moe-30b-a3b").smoke_config().replace(
        capacity_factor=0.5)   # force overflow drops
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss = T.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


def test_remat_matches_no_remat():
    cfg = get_config("granite-3-2b").smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    l1 = T.loss_fn(params, batch, cfg)
    l2 = T.loss_fn(params, batch, cfg.replace(remat="none"))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
