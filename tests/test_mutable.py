"""Mutable index (core/mutable.py): incremental mutation ≡ from-scratch.

The pinned contract: an index mutated through build → add → delete →
compact returns IDENTICAL search results to an index built from scratch on
the same surviving vectors against the same frozen codebook/PQ — across
both the jit and numpy engines. Identity (not approximate recall) is
achievable because insertion order preserves CSR slot order and point-id
maps are monotonic, so even sort tie-breaking coincides.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MutableIVF, build_ivf_sharded, pack_ivf, search_jit,
                        search_numpy)
from repro.data.vectors import make_manifold
from repro.serve.engine import AnnEngine
from repro.serve.knn_memory import KNNMemory


@pytest.fixture(scope="module")
def ds():
    return make_manifold(jax.random.PRNGKey(0), n=8000, d=24, nq=32,
                         intrinsic_dim=6)


@pytest.fixture(scope="module")
def mutated(ds):
    """build(6000) → add(2000) → remove(700) → compact, plus the
    from-scratch comparator on the survivors."""
    base, extra = ds.X[:6000], ds.X[6000:]
    idx = build_ivf_sharded(jax.random.PRNGKey(1), base, 32,
                            spill_mode="soar", pq_subspaces=8, train_iters=5)
    mut = MutableIVF.from_index(idx)
    new_ids = mut.add(extra)
    rng = np.random.default_rng(0)
    victims = np.concatenate([rng.choice(6000, 500, replace=False),
                              rng.choice(new_ids, 200, replace=False)])
    assert mut.remove(victims) == 700
    mut.compact()
    scratch = mut.rebuild_reference()
    live = np.flatnonzero(mut.alive[:mut.n_total])
    id_map = np.full(mut.n_total, -1, np.int64)
    id_map[live] = np.arange(live.size)
    return mut, scratch, id_map, victims


def _mapped(ids, id_map):
    return np.where(ids >= 0, id_map[np.maximum(ids, 0)], -1)


def test_incremental_equals_scratch_jit(mutated, ds):
    mut, scratch, id_map, _ = mutated
    kw = dict(top_t=8, final_k=10, rerank_budget=128)
    mi, mv = search_jit(mut.pack(), jnp.asarray(ds.Q), **kw)
    si, sv = search_jit(pack_ivf(scratch), jnp.asarray(ds.Q), **kw)
    assert np.array_equal(_mapped(np.asarray(mi), id_map), np.asarray(si))
    np.testing.assert_allclose(np.asarray(mv), np.asarray(sv),
                               rtol=1e-5, atol=1e-5)


def test_incremental_equals_scratch_numpy(mutated, ds):
    mut, scratch, id_map, _ = mutated
    kw = dict(top_t=8, final_k=10, rerank_budget=128)
    mi, _ = search_numpy(mut.to_ivf_index(), ds.Q, **kw)
    si, _ = search_numpy(scratch, ds.Q, **kw)
    assert np.array_equal(_mapped(mi, id_map), si)


def test_removed_ids_never_returned(mutated, ds):
    mut, _, _, victims = mutated
    ids, _ = search_jit(mut.pack(), jnp.asarray(ds.Q), top_t=16, final_k=20,
                        rerank_budget=256)
    assert not np.isin(np.asarray(ids), victims).any()
    ids_np, _ = search_numpy(mut.to_ivf_index(), ds.Q, top_t=16, final_k=20)
    assert not np.isin(ids_np, victims).any()


def test_added_ids_retrievable(mutated, ds):
    """Query AT an inserted vector → its id must come back on top."""
    mut, _, _, _ = mutated
    live_added = [i for i in range(6000, 8000) if mut.alive[i]][:16]
    Q = mut.rerank[live_added]
    ids, _ = search_jit(mut.pack(), jnp.asarray(Q), top_t=8, final_k=5,
                        rerank_budget=64)
    hit = (np.asarray(ids) == np.asarray(live_added)[:, None]).any(axis=1)
    assert hit.mean() > 0.9


def test_tombstones_then_threshold_compaction(ds):
    idx = build_ivf_sharded(jax.random.PRNGKey(2), ds.X[:3000], 16,
                            spill_mode="soar", train_iters=3)
    mut = MutableIVF.from_index(idx, compact_threshold=0.2)
    slots_before = mut.n_slots
    mut.remove(np.arange(0, 3000, 10))           # 10% dead — below threshold
    assert mut.n_dead_slots > 0 and mut.n_slots == slots_before
    mut.remove(np.arange(1, 3000, 7))            # crosses 20% → auto-compact
    assert mut.n_dead_slots == 0
    assert mut.n_slots < slots_before
    counts = np.bincount(mut.to_ivf_index().point_ids,
                         minlength=mut.n_total)
    alive = mut.alive[:mut.n_total]
    assert np.all(counts[alive] == 2) and np.all(counts[~alive] == 0)


def test_partition_capacity_growth(ds):
    """Adding far more points than the initial capacity slack grows the
    padded partition arrays instead of dropping assignments."""
    idx = build_ivf_sharded(jax.random.PRNGKey(3), ds.X[:1000], 8,
                            spill_mode="soar", pq_subspaces=8, train_iters=3)
    mut = MutableIVF.from_index(idx)
    cap0 = mut.part_ids.shape[1]
    mut.add(ds.X[1000:5000])
    assert mut.part_ids.shape[1] > cap0
    assert mut.n_alive == 5000
    counts = np.bincount(mut.to_ivf_index().point_ids, minlength=5000)
    assert np.all(counts == 2)


def test_remove_is_idempotent_and_bounded(ds):
    idx = build_ivf_sharded(jax.random.PRNGKey(4), ds.X[:1000], 8,
                            train_iters=3)
    mut = MutableIVF.from_index(idx)
    assert mut.remove([5, 5, 5]) == 1
    assert mut.remove([5]) == 0                   # already dead
    assert mut.remove([10**6, -3]) == 0           # out of range
    assert mut.n_alive == 999


def test_empty_then_repopulate(ds):
    """Fully tombstoning the index must not break search (the candidate
    window shrinks below final_k → padded -1 results), and re-adding into
    the emptied index serves fresh stable ids."""
    idx = build_ivf_sharded(jax.random.PRNGKey(6), ds.X[:1000], 8,
                            pq_subspaces=8, train_iters=2)
    mut = MutableIVF.from_index(idx)
    mut.remove(np.arange(1000))
    ids, vals = search_jit(mut.pack(), jnp.asarray(ds.Q[:4]), top_t=4,
                           final_k=5, rerank_budget=16)
    assert (np.asarray(ids) == -1).all() and np.asarray(ids).shape == (4, 5)
    ids_np, _ = search_numpy(mut.to_ivf_index(), ds.Q[:4], top_t=4,
                             final_k=5)
    assert (ids_np == -1).all()
    new = mut.add(ds.X[:50])
    assert new[0] == 1000                       # id space is append-only
    ids2, _ = search_jit(mut.pack(), jnp.asarray(ds.X[:8]), top_t=6,
                         final_k=3, rerank_budget=32)
    assert (np.asarray(ids2)[:, 0] == new[:8]).all()


def test_knn_memory_online_mutation(ds):
    keys, values = ds.X[:4000], np.tanh(ds.X[:4000] * 2.0)
    extra_k, extra_v = ds.X[4000:4500], np.tanh(ds.X[4000:4500] * 2.0)
    for engine in ("numpy", "jit"):
        mem = KNNMemory.build(keys, values, n_partitions=16, engine=engine)
        new_ids = mem.add(extra_k, extra_v)
        assert new_ids.shape == (500,)
        ids, K, V = mem.retrieve(extra_k[:8], k=4, top_t=4)
        assert np.isin(new_ids[:8], ids).mean() > 0.9
        mem.remove(new_ids)
        ids2, _, _ = mem.retrieve(extra_k[:8], k=4, top_t=4)
        assert not np.isin(ids2, new_ids).any()


def test_ann_engine_roundtrip(ds):
    eng = AnnEngine.build(jax.random.PRNGKey(5), ds.X[:3000], 16,
                          pq_subspaces=8, train_iters=3, top_t=8)
    ids0, _ = eng.search(ds.Q, k=5)
    assert ids0.shape == (ds.Q.shape[0], 5) and (ids0 >= 0).all()
    new = eng.add(ds.X[3000:3100])
    assert eng.n_alive == 3100
    ids1, _ = eng.search(np.asarray(ds.X[3000:3100]), k=3)
    assert (ids1[:, 0] == new).mean() > 0.9
    eng.remove(new)
    ids2, _ = eng.search(np.asarray(ds.X[3000:3100]), k=3)
    assert not np.isin(ids2, new).any()
