"""Pipeline parallelism: 2-stage pipelined loss/grads must match the plain
(non-pipelined) model exactly (subprocess: 2 virtual devices)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.train.pipeline import (make_pipelined_loss, stack_stage_params,
                                  pipelined_loss_and_grad)
from repro.launch.mesh import set_mesh

cfg = get_config("granite-3-2b").smoke_config().replace(
    compute_dtype="float32", remat="none")
params = T.init_params(jax.random.PRNGKey(0), cfg)
M, mb, S = 4, 2, 16
k = jax.random.PRNGKey(1)
tokens = jax.random.randint(k, (M, mb, S), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.fold_in(k, 1), (M, mb, S), 0,
                            cfg.vocab_size)

# reference: plain per-microbatch loss, averaged
ref_losses = [float(T.loss_fn(params, {"tokens": tokens[i],
                                       "labels": labels[i]}, cfg))
              for i in range(M)]
ref = float(np.mean(ref_losses))

mesh = jax.make_mesh((2,), ("pod",))
sp = stack_stage_params(params, cfg, n_stages=2)
with set_mesh(mesh):
    fn = make_pipelined_loss(cfg, mesh, n_stages=2)
    got = float(jax.jit(fn)(sp, tokens, labels))
assert abs(got - ref) / abs(ref) < 1e-5, (got, ref)

# gradient check: pipelined grads of the group params match sequential grads.
# jax<0.5's shard_map transpose cannot differentiate this program (spec-check
# failure on scalar residuals); the forward equality above still holds there,
# so degrade to a visible skip rather than a false failure.
def ref_loss_fn(p):
    return sum(T.loss_fn(p, {"tokens": tokens[i], "labels": labels[i]}, cfg)
               for i in range(M)) / M
ref_grads = jax.grad(ref_loss_fn)(params)
try:
    with set_mesh(mesh):
        _, pipe_grads = pipelined_loss_and_grad(cfg, mesh, sp, tokens, labels)
except Exception as e:
    if type(e).__name__ != "_SpecError" or hasattr(jax, "set_mesh"):
        raise
    print("OK", got, ref, "(grad check skipped: shard_map transpose "
          "unsupported on this jax)")
else:
    # compare one representative group-leaf: reassemble stage halves
    pg = np.asarray(pipe_grads["groups"]["pos0_attn"]["wq"])   # (2, G/2, ...)
    rg = np.asarray(ref_grads["groups"]["pos0_attn"]["wq"])    # (G, ...)
    pg_full = pg.reshape(rg.shape)
    np.testing.assert_allclose(pg_full, rg, rtol=2e-4, atol=1e-6)
    # embed grads live on stage 0
    pe = np.asarray(pipe_grads["embed"]["table"])[0]
    re = np.asarray(ref_grads["embed"]["table"])
    np.testing.assert_allclose(pe, re, rtol=2e-4, atol=1e-6)
    print("OK", got, ref)
"""


def test_two_stage_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "OK" in r.stdout
