import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.quant.pq import (train_pq, pq_encode, pq_decode, pq_lut, pq_score,
                            pq_score_batch)
from repro.quant.int8 import int8_quantize, int8_dequantize


@pytest.fixture(scope="module")
def pq_setup():
    X = jax.random.normal(jax.random.PRNGKey(0), (4000, 32))
    cb = train_pq(jax.random.PRNGKey(1), X, n_subspaces=8, iters=5)
    codes = pq_encode(cb, X)
    return X, cb, codes


def test_reconstruction_beats_random_codes(pq_setup):
    X, cb, codes = pq_setup
    rec = pq_decode(cb, codes)
    err = float(jnp.mean(jnp.sum((X - rec) ** 2, -1)))
    rand_codes = jax.random.randint(jax.random.PRNGKey(2), codes.shape, 0, 16
                                    ).astype(jnp.uint8)
    rand_err = float(jnp.mean(jnp.sum((X - pq_decode(cb, rand_codes)) ** 2, -1)))
    assert err < 0.5 * rand_err


def test_lut_score_equals_decoded_dot(pq_setup):
    X, cb, codes = pq_setup
    q = jax.random.normal(jax.random.PRNGKey(3), (32,))
    lut = pq_lut(cb, q)
    s = pq_score(lut, codes[:100])
    exact = pq_decode(cb, codes[:100]) @ q
    np.testing.assert_allclose(np.asarray(s), np.asarray(exact), rtol=1e-4,
                               atol=1e-4)


def test_batch_score_matches_single(pq_setup):
    X, cb, codes = pq_setup
    Q = jax.random.normal(jax.random.PRNGKey(4), (5, 32))
    luts = jax.vmap(lambda q: pq_lut(cb, q))(Q)
    batch = pq_score_batch(luts, codes[:50])
    for i in range(5):
        np.testing.assert_allclose(np.asarray(batch[i]),
                                   np.asarray(pq_score(luts[i], codes[:50])),
                                   rtol=1e-4, atol=1e-4)


def test_codes_in_range(pq_setup):
    _, _, codes = pq_setup
    c = np.asarray(codes)
    assert c.min() >= 0 and c.max() < 16


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 64), d=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 1 << 30))
def test_int8_roundtrip_property(n, d, seed):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 3.0
    q = int8_quantize(X)
    back = int8_dequantize(q)
    amax = np.abs(np.asarray(X)).max(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(X),
                               atol=float((amax / 127.0).max()) + 1e-6)
