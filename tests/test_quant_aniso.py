import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import assign_euclidean, train_kmeans
from repro.quant.anisotropic import (anisotropic_assign, anisotropic_kmeans,
                                     anisotropic_loss_values, eta_from_threshold)


def test_eta_one_equals_euclidean():
    X = jax.random.normal(jax.random.PRNGKey(0), (400, 16))
    C = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    a_iso = anisotropic_assign(X, C, eta=1.0)
    a_euc = assign_euclidean(X, C)
    assert np.array_equal(np.asarray(a_iso), np.asarray(a_euc))


def test_assign_minimizes_aniso_loss():
    X = jax.random.normal(jax.random.PRNGKey(2), (200, 8))
    C = jax.random.normal(jax.random.PRNGKey(3), (25, 8))
    eta = 4.0
    a = anisotropic_assign(X, C, eta=eta)
    chosen = anisotropic_loss_values(X, C, a, eta)
    for j in range(25):
        other = anisotropic_loss_values(X, C, jnp.full((200,), j, jnp.int32), eta)
        assert np.all(np.asarray(chosen) <= np.asarray(other) + 1e-4)


def test_aniso_training_beats_euclidean_on_aniso_loss():
    X = jax.random.normal(jax.random.PRNGKey(4), (5000, 16))
    X = X / jnp.linalg.norm(X, axis=-1, keepdims=True)
    eta = eta_from_threshold(0.2, 16)
    C_a, assign_a = anisotropic_kmeans(jax.random.PRNGKey(5), X, 16, eta, iters=5)
    km = train_kmeans(jax.random.PRNGKey(5), X, 16, iters=8)
    loss_a = float(jnp.mean(anisotropic_loss_values(X, C_a, assign_a, eta)))
    loss_e = float(jnp.mean(anisotropic_loss_values(
        X, km.centroids, km.assignments, eta)))
    assert loss_a < loss_e


def test_eta_from_threshold_monotone():
    assert eta_from_threshold(0.0, 100) == 0.0
    vals = [eta_from_threshold(t, 100) for t in (0.1, 0.2, 0.4)]
    assert vals[0] < vals[1] < vals[2]
