"""Serving-tier resilience (ISSUE 9 tentpole, DESIGN.md §3.13).

Pins, per the acceptance criteria:

1. Error taxonomy: ServingError subclasses carry queued_us/engine_us and
   a retryable classification (`is_retryable`).
2. Fault-injection grammar: @N / @NxM firing windows, ";" multi-plan,
   modes error/transient/delay, and the repro.ckpt.faults shim sharing
   state with repro.faults.
3. Circuit breaker: CLOSED → OPEN → HALF_OPEN (single probe) → CLOSED
   walked with a fake clock; HealthTracker mask/shards_ok renderings.
4. Admission control: bounded queue rejects (OverloadedError) or sheds
   least-deadline-slack searches; mutations never shed and never evict
   searches.
5. Deadline enforcement: an explicitly-deadlined request that expires
   while queued fails with DeadlineExceededError (queued_us populated)
   WITHOUT consuming engine time; best-effort requests never expire.
6. Containment: an engine Exception fails only its group and the
   dispatcher keeps serving; transient faults are absorbed by bounded
   retry + backoff (SearchResult.retries); mutations never retry.
7. Stranded-Future regression: a BaseException out of the engine fails
   every pending/in-flight Future, poisons submit with
   FrontendClosedError, and close() still returns — zero hung Futures.
8. Shutdown ordering: close() during an in-flight mutation, submits
   racing close(), close(drain=False) failing queued work
   deterministically.
9. Durability composition: a WAL crash mid-mutation BEHIND the front-end
   recovers bitwise per the PR 7 contract.
10. Degraded fan-out (subprocess, 8 virtual devices): with_health
    all-healthy is bitwise the plain path; a dead shard's ids vanish
    while healthy shards' answers survive; the replica breaker falls
    back to bitwise-identical local serving flagged degraded, then
    heals through the half-open probe.
"""
import subprocess
import sys
import threading
import time
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro import faults
from repro.data.vectors import make_manifold
from repro.faults import (FaultPlan, InjectedCrash, InjectedFault,
                          InjectedTransientFault)
from repro.serve.api import (DeadlineExceededError, FrontendClosedError,
                             OverloadedError, SearchParams, ServingError,
                             is_retryable)
from repro.serve.engine import AnnEngine
from repro.serve.frontend import ServingFrontend, _Request
from repro.serve.health import (CLOSED, HALF_OPEN, OPEN, CircuitBreaker,
                                HealthTracker, shards_ok_from_mask)

N, D, NQ = 2_000, 16, 16


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.uninstall()
    yield
    faults.uninstall()


@pytest.fixture(scope="module")
def ds():
    return make_manifold(jax.random.PRNGKey(0), n=N, d=D, nq=NQ,
                         intrinsic_dim=8)


@pytest.fixture()
def engine(ds):
    return AnnEngine.build(jax.random.PRNGKey(1), ds.X, 16,
                           spill_mode="soar", train_iters=5)


def _stall_search(fe, ds, ms):
    """Park the dispatcher inside a search dispatch for ~ms via a latency
    spike on engine:search (hit 1 only), so subsequent submits pile up in
    the queue deterministically. Returns the sacrificial future."""
    faults.inject("engine:search@1x1", mode="delay", delay_ms=ms)
    fut = fe.submit(ds.Q[:1], SearchParams(k=3))
    t0 = time.perf_counter()
    while fe._q and time.perf_counter() - t0 < 5.0:
        time.sleep(0.001)
    assert not fe._q, "dispatcher never picked up the stall request"
    return fut


def _stall_mutation(fe, ms):
    """Same, but inside a mutation (engine:add) — keeps the
    engine:search hit counter untouched for plans armed on it."""
    faults.inject("engine:add@1x1", mode="delay", delay_ms=ms)
    mfut: Future = Future()
    X = np.zeros((1, D), np.float32)
    fe._enqueue(_Request("add", mfut, payload=(X, None),
                         t_admit=time.perf_counter(), cost=1))
    t0 = time.perf_counter()
    while fe._q and time.perf_counter() - t0 < 5.0:
        time.sleep(0.001)
    assert not fe._q, "dispatcher never picked up the stall mutation"
    return mfut


# ------------------------------------------------------------ taxonomy
def test_error_taxonomy():
    e = OverloadedError("full", queued_us=5.0)
    assert isinstance(e, ServingError) and isinstance(e, RuntimeError)
    assert e.queued_us == 5.0 and e.engine_us == 0.0
    assert is_retryable(e)                       # the caller may back off
    assert not is_retryable(DeadlineExceededError("late"))
    assert not is_retryable(FrontendClosedError("closed"))
    assert is_retryable(InjectedTransientFault("x"))
    assert not is_retryable(InjectedFault("x"))
    # stdlib transient types classify retryable without the attribute
    assert is_retryable(TimeoutError())
    assert is_retryable(ConnectionError())
    assert not is_retryable(ValueError())


def test_deadline_param_bounds():
    assert SearchParams(deadline_ms=0.05).validate().deadline_ms == 0.05
    assert (SearchParams(deadline_ms=600_000).validate().deadline_ms
            == 600_000.0)
    assert SearchParams().validate().deadline_ms is None
    for bad in (0, 0.01, -5, 600_001, float("nan")):
        with pytest.raises(ValueError, match="deadline_ms"):
            SearchParams(deadline_ms=bad).validate()


# ------------------------------------------------------- fault grammar
def test_fault_window_grammar():
    plan = FaultPlan.parse("p@2x3", mode="error")
    assert (plan.point, plan.hits, plan.times) == ("p", 2, 3)
    faults.install("p@2x3", mode="error")
    fired = []
    for _ in range(6):
        try:
            faults.serve_point("p")
            fired.append(False)
        except InjectedFault:
            fired.append(True)
    assert fired == [False, True, True, True, False, False]


def test_fault_multi_plan_and_shim_share_state():
    faults.install("a@1;b@1", mode="transient")
    with pytest.raises(InjectedTransientFault):
        faults.serve_point("a")
    with pytest.raises(InjectedTransientFault):
        faults.serve_point("b")
    from repro.ckpt import faults as shim
    assert shim.InjectedCrash is faults.InjectedCrash
    assert shim.InjectedFault is faults.InjectedFault
    shim.inject("c@1", mode="error")             # append through the shim
    with pytest.raises(InjectedFault):
        faults.serve_point("c")                  # ...fires via the module


def test_fault_delay_mode_is_a_latency_spike():
    faults.install("d", mode="delay", delay_ms=30.0)
    t0 = time.perf_counter()
    faults.serve_point("d")                      # sleeps, does not raise
    assert time.perf_counter() - t0 >= 0.025


# ------------------------------------------------------ circuit breaker
def test_circuit_breaker_state_machine():
    t = [0.0]
    cb = CircuitBreaker(fail_threshold=2, reset_after_s=10.0,
                        clock=lambda: t[0])
    assert cb.state == CLOSED and cb.allow()
    cb.record_failure()
    assert cb.state == CLOSED                    # under threshold
    cb.record_failure()
    assert cb.state == OPEN and not cb.allow()
    t[0] = 9.9
    assert not cb.allow()                        # window not elapsed
    t[0] = 10.0
    assert cb.state == HALF_OPEN
    assert cb.allow()                            # the single probe
    assert not cb.allow()                        # concurrent caller denied
    cb.record_failure()                          # failed probe re-arms
    assert cb.state == OPEN
    t[0] = 20.0
    assert cb.allow()
    cb.record_success()
    assert cb.state == CLOSED and cb.allow()
    cb.record_failure()
    cb.record_success()                          # success resets the streak
    cb.record_failure()
    assert cb.state == CLOSED


def test_health_tracker_mask_and_shards_ok():
    h = HealthTracker(fail_threshold=1, reset_after_s=60.0)
    h.failure(2)
    m = h.mask(4)
    assert m.tolist() == [1, 1, 0, 1]
    assert shards_ok_from_mask(m) == (0, 1, 3)
    assert h.healthy(range(4)) == (0, 1, 3)
    assert h.snapshot()[2] == OPEN


# ---------------------------------------------------- admission control
def test_admission_reject(ds, engine):
    fe = ServingFrontend(engine, policy="local", max_queue=4,
                         overload="reject", max_delay_ms=1.0,
                         mutation_cost=2)
    try:
        _stall_search(fe, ds, 500.0)
        futs = [fe.submit(ds.Q[i:i + 1], SearchParams(k=4))
                for i in range(4)]               # fills the budget exactly
        with pytest.raises(OverloadedError):
            fe.submit(ds.Q[:1], SearchParams(k=4))
        # an over-budget mutation is rejected, never admitted by eviction
        with pytest.raises(OverloadedError):
            fe._enqueue(_Request("add", Future(), payload=(None, None),
                                 t_admit=time.perf_counter(), cost=2))
        assert fe.stats["rejected"] == 2
        for f in futs:                           # admitted work completes
            assert f.result(timeout=60).ids.shape == (1, 4)
    finally:
        fe.close()
    assert fe._cost == 0                         # cost accounting balances


def test_admission_shed_oldest(ds, engine):
    fe = ServingFrontend(engine, policy="local", max_queue=4,
                         overload="shed-oldest", max_delay_ms=1.0,
                         mutation_cost=2)
    try:
        _stall_search(fe, ds, 500.0)
        # least slack: the only request with an explicit deadline
        doomed = fe.submit(ds.Q[:1], SearchParams(k=4, deadline_ms=5_000.0))
        keep = [fe.submit(ds.Q[i:i + 1], SearchParams(k=4))
                for i in range(1, 4)]            # best-effort: inf slack
        newcomer = fe.submit(ds.Q[4:5], SearchParams(k=4))
        with pytest.raises(OverloadedError) as ei:
            doomed.result(timeout=5)
        assert ei.value.queued_us >= 0.0
        assert fe.stats["shed"] == 1
        # a mutation must NOT evict queued searches under shed-oldest
        with pytest.raises(OverloadedError):
            fe._enqueue(_Request("add", Future(), payload=(None, None),
                                 t_admit=time.perf_counter(), cost=2))
        assert fe.stats["rejected"] == 1
        for f in keep + [newcomer]:
            assert f.result(timeout=60).ids.shape == (1, 4)
    finally:
        fe.close()
    assert fe._cost == 0


# -------------------------------------------------- deadline enforcement
def test_deadline_expiry_sheds_queued(ds, engine):
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0)
    try:
        fe.search(ds.Q[:1], SearchParams(k=4))   # warm the k=4 bucket
        _stall_search(fe, ds, 300.0)
        doomed = fe.submit(ds.Q[:1], SearchParams(k=4, deadline_ms=50.0))
        ok = fe.submit(ds.Q[1:2], SearchParams(k=4))  # best-effort
        with pytest.raises(DeadlineExceededError) as ei:
            doomed.result(timeout=30)
        assert ei.value.queued_us >= 50e3 * 0.9  # spent >= ~the budget
        assert ei.value.engine_us == 0.0         # never reached the engine
        r = ok.result(timeout=60)
        assert r.ids.shape == (1, 4)             # best-effort never expires
        assert fe.stats["expired"] == 1
    finally:
        fe.close()


# ------------------------------------------------ containment and retry
def test_transient_fault_absorbed_by_retry(ds, engine):
    want = engine.search_request(ds.Q[:2], SearchParams(k=4))
    faults.install("engine:search@1x2", mode="transient")
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0,
                         retry_backoff_ms=0.5)
    try:
        r = fe.search(ds.Q[:2], SearchParams(k=4))
        assert r.retries == 2                    # two blips absorbed
        assert fe.stats["retries"] == 2
        assert fe.stats["failures"] == 0
        assert np.array_equal(r.ids, want.ids)
        assert np.array_equal(r.scores, want.scores)
    finally:
        fe.close()


def test_nonretryable_fault_fails_only_its_group(ds, engine):
    faults.install("engine:search@1x1", mode="error")
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0)
    try:
        with pytest.raises(InjectedFault):
            fe.search(ds.Q[:1], SearchParams(k=4))
        assert fe.stats["failures"] == 1
        r = fe.search(ds.Q[:1], SearchParams(k=4))   # keeps serving
        assert r.ids.shape == (1, 4) and r.retries == 0
    finally:
        fe.close()


def test_retry_budget_is_bounded(ds, engine):
    faults.install("engine:search", mode="transient")   # permanently down
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0,
                         max_retries=1, retry_backoff_ms=0.5)
    try:
        with pytest.raises(InjectedTransientFault):
            fe.search(ds.Q[:1], SearchParams(k=4))
        assert fe.stats["retries"] == 1 and fe.stats["failures"] == 1
        faults.uninstall()
        assert fe.search(ds.Q[:1], SearchParams(k=4)).ids.shape == (1, 4)
    finally:
        fe.close()


def test_mutations_never_retried(ds, engine):
    faults.install("engine:add@1x1", mode="transient")
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0)
    try:
        with pytest.raises(InjectedTransientFault):
            fe.add(np.zeros((1, D), np.float32))
        assert fe.stats["retries"] == 0          # retryable, but a write
        assert fe.stats["failures"] == 1
        assert fe.search(ds.Q[:1], SearchParams(k=4)).ids.shape == (1, 4)
    finally:
        fe.close()


# ------------------------------------------- stranded-Future regression
def test_dispatcher_death_strands_no_futures(ds, engine):
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0)
    mfut = _stall_mutation(fe, 400.0)
    faults.inject("engine:search@1", mode="raise")   # BaseException
    s1 = fe.submit(ds.Q[:1], SearchParams(k=3))      # dispatched first
    s2 = fe.submit(ds.Q[:1], SearchParams(k=4))      # queued behind it
    assert mfut.result(timeout=30) is not None       # stall add completed
    with pytest.raises(InjectedCrash):
        s1.result(timeout=30)                        # in-flight: the cause
    with pytest.raises(FrontendClosedError):
        s2.result(timeout=30)                        # queued: failed fast
    faults.uninstall()
    with pytest.raises(FrontendClosedError, match="closed"):
        fe.submit(ds.Q[:1], SearchParams(k=3))       # submit is poisoned
    fe.close()                                       # returns promptly
    assert not fe._thread.is_alive()
    assert fe._cost == 0


# ---------------------------------------------------- shutdown ordering
def test_close_during_inflight_mutation(ds, engine):
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0)
    mfut = _stall_mutation(fe, 400.0)
    t0 = time.perf_counter()
    fe.close()                                       # mutation in flight
    assert time.perf_counter() - t0 < 30.0
    assert mfut.result(timeout=1) is not None        # the write finished
    assert not fe._thread.is_alive()


def test_close_without_drain_fails_queued_work(ds, engine):
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0)
    _stall_search(fe, ds, 400.0)
    queued = [fe.submit(ds.Q[i:i + 1], SearchParams(k=4))
              for i in range(3)]
    fe.close(drain=False)
    for f in queued:
        with pytest.raises(FrontendClosedError):
            f.result(timeout=5)
    with pytest.raises(FrontendClosedError):
        fe.submit(ds.Q[:1], SearchParams(k=4))
    assert fe._cost == 0


def test_concurrent_submits_racing_close(ds, engine):
    fe = ServingFrontend(engine, policy="local", max_delay_ms=1.0)
    fe.search(ds.Q[:1], SearchParams(k=4))           # warm the bucket
    futs, lock = [], threading.Lock()

    def client():
        for i in range(30):
            try:
                f = fe.submit(ds.Q[i % NQ:i % NQ + 1], SearchParams(k=4))
            except FrontendClosedError:
                return
            with lock:
                futs.append(f)

    threads = [threading.Thread(target=client) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.005)
    fe.close()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    # every accepted Future completes — served or failed, never hung
    done = sum(1 for f in futs if f.result(timeout=30).ids.shape == (1, 4))
    assert done == len(futs)


# --------------------------------------------- durability composition
def test_wal_crash_behind_frontend_recovers_bitwise(ds, tmp_path):
    """PR 7 contract through the serving loop: a crash after the WAL
    record is durable ("wal:record") but before apply completes recovers
    to exactly the post-mutation state on reopen."""
    eng = AnnEngine.build(jax.random.PRNGKey(2), ds.X, 16, train_iters=5)
    p, pref = str(tmp_path / "live"), str(tmp_path / "ref")
    eng.save(p)
    eng.save(pref)
    add = np.linspace(-1, 1, 3 * D, dtype=np.float32).reshape(3, D)
    fe = ServingFrontend(AnnEngine.open(p, wal=True), policy="local",
                         max_delay_ms=1.0)
    fe.search(ds.Q[:2], SearchParams(k=5))
    faults.install("wal:record")
    with pytest.raises(InjectedCrash):
        fe.add(add)                              # crash mid-mutation
    faults.uninstall()
    with pytest.raises(FrontendClosedError):
        fe.submit(ds.Q[:1], SearchParams(k=5))   # front-end is dead
    fe.close()
    ref = AnnEngine.open(pref)                   # the committed state:
    ref.add(add)                                 # snapshot + the logged add
    want = ref.search(ds.Q, k=5)
    got = AnnEngine.open(p).search(ds.Q, k=5)    # WAL replay on open
    assert np.array_equal(got[0], want[0])
    assert np.array_equal(got[1], want[1])


# ------------------------------------------------- degraded fan-out
SCRIPT_HEALTH = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import build_sharded_ivf, make_distributed_search
from repro.launch.mesh import set_mesh
from repro.data.vectors import make_manifold
from repro.serve.health import HealthTracker, shards_ok_from_mask

ds = make_manifold(jax.random.PRNGKey(0), n=8_000, d=16, nq=16,
                   intrinsic_dim=8)
mesh = jax.make_mesh((8,), ("data",))
sharded = build_sharded_ivf(jax.random.PRNGKey(1), ds.X, n_shards=8,
                            n_partitions=16, spill_mode="soar",
                            train_iters=3)
plain = make_distributed_search(mesh, ("data",), top_t=8, final_k=10)
degr = make_distributed_search(mesh, ("data",), top_t=8, final_k=10,
                               with_health=True)
with set_mesh(mesh):
    ids0, sc0 = jax.jit(plain)(sharded, jnp.asarray(ds.Q))
    ones = jnp.ones((8,), jnp.uint8)
    ids1, sc1 = jax.jit(degr)(sharded, jnp.asarray(ds.Q), ones)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids1)), "healthy != plain"
    assert np.array_equal(np.asarray(sc0), np.asarray(sc1))
    h = HealthTracker(fail_threshold=1)
    h.failure(3)                        # shard 3 down
    mask = h.mask(8)
    assert shards_ok_from_mask(mask) == (0, 1, 2, 4, 5, 6, 7)
    ids2, sc2 = jax.jit(degr)(sharded, jnp.asarray(ds.Q), jnp.asarray(mask))
ids0, ids2 = np.asarray(ids0), np.asarray(ids2)
per = 8_000 // 8
lo, hi = 3 * per, 4 * per
assert ids2.min() >= 0                  # partial results, never sentinels
assert not ((ids2 >= lo) & (ids2 < hi)).any(), "dead shard leaked results"
# healthy shards' global answers all survive into the degraded top-k
keep = ~((ids0 >= lo) & (ids0 < hi))
for q in range(ids0.shape[0]):
    assert set(ids0[q][keep[q]].tolist()) <= set(ids2[q].tolist()), q
print("OK")
"""


SCRIPT_REPLICA_DEGRADED = r"""
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro import faults
from repro.data.vectors import make_manifold
from repro.serve.api import SearchParams
from repro.serve.engine import AnnEngine
from repro.serve.frontend import ServingFrontend

ds = make_manifold(jax.random.PRNGKey(0), n=2_000, d=16, nq=16,
                   intrinsic_dim=8)
eng = AnnEngine.build(jax.random.PRNGKey(1), ds.X, 16, train_iters=5)
solo_ids, solo_sc = eng.search(ds.Q, k=6)
fe = ServingFrontend(eng, policy="replica", breaker_threshold=2,
                     breaker_reset_s=0.5)
plan = faults.install("replica:dispatch", mode="error")  # replicas down
r1 = fe.search(ds.Q, SearchParams(k=6))
assert r1.degraded, "fallback must be flagged"
assert np.array_equal(r1.ids, solo_ids)        # full-coverage local serve
assert np.array_equal(r1.scores, solo_sc)
r2 = fe.search(ds.Q, SearchParams(k=6))        # second failure trips it
assert r2.degraded and fe.health.state("replica") == "open"
r3 = fe.search(ds.Q, SearchParams(k=6))        # breaker open: no attempt
assert r3.degraded and plan._hit_count == 2
assert np.array_equal(r3.ids, solo_ids)
assert fe.stats["degraded"] == 3
assert fe.stats["replica_dispatches"] == 0
faults.uninstall()
time.sleep(0.6)                                # reset window elapses
r4 = fe.search(ds.Q, SearchParams(k=6))        # half-open probe heals it
assert not r4.degraded
assert fe.health.state("replica") == "closed"
assert fe.stats["replica_dispatches"] == 1
assert np.array_equal(r4.ids, solo_ids)        # replica path stays bitwise
fe.close()
print("OK")
"""


def _run(script):
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout


def test_degraded_shard_fanout_multidevice():
    _run(SCRIPT_HEALTH)


def test_replica_breaker_fallback_multidevice():
    _run(SCRIPT_REPLICA_DEGRADED)
