"""Router abstraction tests (ISSUE 6): the probe stage as a first-class
Router (core/router.py, DESIGN.md §3.10).

Pins, in order of importance:

1. `FlatRouter` probe sets are BITWISE-identical to the pre-refactor
   inline GEMM + top-t on both engines (property-tested against inline
   reference implementations copied from the pre-refactor code), and
   end-to-end search with an explicit FlatRouter is slot-exact equal to
   the default path, filtered and unfiltered — the refactor changed zero
   behavior.
2. `TreeRouter` at `t_route = n_super` degrades to exact flat routing
   (same probe sets, modulo ties at the top-t boundary).
3. The `top_t` clamp lives in ONE place (`clamp_top_t`) and every entry
   point agrees: an absurdly large top_t returns exactly the top_t=c
   result through search_numpy, search_jit, search_jit_batched,
   AnnEngine.search, and KNNMemory.retrieve.
4. Dimension mismatches raise a clear ValueError on both engines.
5. Routers ride the index through build → pack → mutation snapshots →
   rebuild (frozen-router contract), with emptied partitions pruned from
   the serving view.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import build_ivf, pack_ivf, search_numpy, search_jit
from repro.core.mutable import MutableIVF
from repro.core.router import (FlatRouter, TreeRouter, as_router,
                               clamp_top_t, train_tree_router)
from repro.core.search import search_jit_batched
from repro.data.vectors import make_manifold

N, D, NQ, C = 6_000, 32, 29, 48
TOP_T, FINAL_K = 10, 10


@pytest.fixture(scope="module")
def built():
    ds = make_manifold(jax.random.PRNGKey(0), n=N, d=D, nq=NQ,
                       intrinsic_dim=8)
    idx = build_ivf(jax.random.PRNGKey(1), ds.X, C, spill_mode="soar",
                    pq_subspaces=8, train_iters=4)
    return ds, idx, pack_ivf(idx)


@pytest.fixture(scope="module")
def tree(built):
    _, idx, _ = built
    return train_tree_router(jax.random.PRNGKey(2), idx.centroids,
                             n_super=8, t_route=3)


# ----------------------------------------------------------- probe bitwise
def _inline_probe_numpy(Q, C_, top_t):
    """The pre-refactor `_search_numpy_pass` probe head, verbatim."""
    scores_c = Q @ C_.T
    top_parts = np.argpartition(-scores_c, top_t - 1, axis=1)[:, :top_t]
    row = np.arange(Q.shape[0])[:, None]
    ordsel = np.argsort(-scores_c[row, top_parts], axis=1)
    top_parts = top_parts[row, ordsel]
    return scores_c[row, top_parts], top_parts


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nq=st.integers(1, 6),
       c=st.integers(2, 40), d=st.integers(2, 24), t=st.integers(1, 40))
def test_flat_route_numpy_bitwise(seed, nq, c, d, t):
    rng = np.random.default_rng(seed)
    Q = rng.standard_normal((nq, d)).astype(np.float32)
    C_ = rng.standard_normal((c, d)).astype(np.float32)
    t = clamp_top_t(t, c) or 1
    want_s, want_p = _inline_probe_numpy(Q, C_, t)
    got_s, got_p = FlatRouter(C_).route_numpy(Q, t)
    assert np.array_equal(want_p, got_p)
    assert np.array_equal(want_s, got_s)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nq=st.integers(1, 5),
       c=st.integers(2, 32), d=st.integers(2, 16), t=st.integers(1, 32))
def test_flat_route_jit_bitwise(seed, nq, c, d, t):
    rng = np.random.default_rng(seed)
    Q = jnp.asarray(rng.standard_normal((nq, d)).astype(np.float32))
    C_ = jnp.asarray(rng.standard_normal((c, d)).astype(np.float32))
    t = clamp_top_t(t, c) or 1
    want_s, want_p = jax.lax.top_k(Q @ C_.T, t)   # the pre-refactor probe
    got_s, got_p = FlatRouter(C_).route(Q, t)
    assert np.array_equal(np.asarray(want_p), np.asarray(got_p))
    assert np.array_equal(np.asarray(want_s), np.asarray(got_s))


@pytest.mark.parametrize("filtered", [False, True])
def test_explicit_flat_router_end_to_end_identity(built, filtered):
    """search with router=FlatRouter(centroids) must be slot-exact equal
    to the default router=None path on BOTH engines (the refactor's
    no-behavior-change contract), filtered and unfiltered."""
    ds, idx, packed = built
    fm = None
    if filtered:
        fm = np.zeros(N, bool)
        fm[::3] = True
    flat = FlatRouter(idx.centroids)
    a, sa = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                         rerank_budget=128, filter_mask=fm)
    b, sb = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                         rerank_budget=128, filter_mask=fm, router=flat)
    assert np.array_equal(a, b)
    assert np.array_equal(sa.unique_candidates, sb.unique_candidates)
    fdev = jnp.asarray(fm.astype(np.uint8)) if filtered else None
    ja, va = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                        final_k=FINAL_K, rerank_budget=128, filter=fdev)
    jb, vb = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                        final_k=FINAL_K, rerank_budget=128, filter=fdev,
                        router=FlatRouter(packed.centroids))
    assert np.array_equal(np.asarray(ja), np.asarray(jb))
    assert np.array_equal(np.asarray(va), np.asarray(vb))


# ------------------------------------------------------- tree degradation
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), c=st.integers(6, 48),
       d=st.integers(2, 12), t=st.integers(1, 16))
def test_tree_at_full_t_route_degrades_to_flat(seed, c, d, t):
    """At t_route = n_super every child is scored, so the tree probe SET
    equals the flat probe set. Integer-valued data keeps both score paths
    exact (any f32 summation order gives the identical value), and rows
    with a score tie at the top-t boundary are skipped — the set is only
    well-defined with a strict gap."""
    rng = np.random.default_rng(seed)
    C_ = rng.integers(-8, 8, (c, d)).astype(np.float32)
    Q = rng.integers(-8, 8, (5, d)).astype(np.float32)
    t = clamp_top_t(t, c) or 1
    rt = train_tree_router(jax.random.PRNGKey(seed % 997), C_,
                           n_super=max(2, int(np.sqrt(c))), iters=3)
    rt = rt.with_t_route(rt.n_super)
    sc = Q @ C_.T
    srt = -np.sort(-sc, axis=1)
    gap = srt[:, t - 1] > srt[:, t] if t < c else np.ones(5, bool)
    _, fp = FlatRouter(C_).route_numpy(Q, t)
    _, tp = rt.route_numpy(Q, t)
    _, jp = rt.route(jnp.asarray(Q), t)
    jp = np.asarray(jp)
    for g, a, b, j in zip(gap, fp, tp, jp):
        if g:
            assert set(a.tolist()) == set(b.tolist())
            assert set(a.tolist()) == set(j.tolist())


# ----------------------------------------------------------- clamp policy
def test_clamp_top_t_is_the_single_source():
    assert clamp_top_t(100, 32) == 32
    assert clamp_top_t(7, 32) == 7
    assert clamp_top_t(-3, 32) == 0


def test_all_entry_points_agree_on_clamp(built):
    """A top_t far beyond n_partitions must clamp identically (to the
    top_t=c result) through EVERY entry point — the clamp was previously
    duplicated with drift across search.py and AnnEngine."""
    ds, idx, packed = built
    huge = 10_000
    want, _ = search_numpy(idx, ds.Q, top_t=C, final_k=FINAL_K,
                           rerank_budget=128)
    got_np, _ = search_numpy(idx, ds.Q, top_t=huge, final_k=FINAL_K,
                             rerank_budget=128)
    assert np.array_equal(want, got_np)
    jw, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=C, final_k=FINAL_K,
                       rerank_budget=128)
    jg, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=huge,
                       final_k=FINAL_K, rerank_budget=128)
    assert np.array_equal(np.asarray(jw), np.asarray(jg))
    bg, _ = search_jit_batched(packed, jnp.asarray(ds.Q), top_t=huge,
                               final_k=FINAL_K, rerank_budget=128, bq=8)
    assert np.array_equal(np.asarray(jw), np.asarray(bg))
    from repro.serve.engine import AnnEngine
    eng = AnnEngine(MutableIVF.from_index(idx), rerank_budget=128)
    ew, _ = eng.search(ds.Q, k=FINAL_K, top_t=C)
    eg, _ = eng.search(ds.Q, k=FINAL_K, top_t=huge)
    assert np.array_equal(ew, eg)
    from repro.serve.knn_memory import KNNMemory
    mem = KNNMemory(MutableIVF.from_index(idx), ds.X.copy())
    mw, _, _ = mem.retrieve(ds.Q, k=FINAL_K, top_t=C)
    mg, _, _ = mem.retrieve(ds.Q, k=FINAL_K, top_t=huge)
    assert np.array_equal(mw, mg)


# ------------------------------------------------------------- dim errors
def test_query_dim_mismatch_raises_numpy(built):
    ds, idx, _ = built
    bad = np.zeros((3, D + 1), np.float32)
    with pytest.raises(ValueError, match="feature dim"):
        search_numpy(idx, bad, top_t=4, final_k=5)


def test_query_dim_mismatch_raises_jit(built):
    _, _, packed = built
    bad = jnp.zeros((3, D - 1), jnp.float32)
    with pytest.raises(ValueError, match="feature dim"):
        search_jit(packed, bad, top_t=4, final_k=5)


# ----------------------------------------------------- tree end-to-end
def test_tree_router_end_to_end_recall(built, tree):
    """Tree-routed search on both engines stays within a recall stone's
    throw of flat at the same top_t while probing a fraction of the
    centroids (the whole point of the router)."""
    ds, idx, packed = built
    gt = np.argsort(-(ds.Q @ ds.X.T), axis=1)[:, :FINAL_K]

    def recall(ids):
        ids = np.asarray(ids)
        return np.mean([len(set(a.tolist()) & set(b.tolist())) / FINAL_K
                        for a, b in zip(ids, gt)])

    flat_ids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                               rerank_budget=128)
    tn, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                         rerank_budget=128, router=tree)
    tj, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                       final_k=FINAL_K, rerank_budget=128,
                       router=tree.device())
    rf, rn, rj = recall(flat_ids), recall(tn), recall(tj)
    assert rn >= rf - 0.12, (rn, rf)
    assert rj >= rf - 0.12, (rj, rf)
    assert tree.probe_flops(TOP_T) < FlatRouter(idx.centroids).probe_flops(
        TOP_T), "tree probe must be cheaper than flat at this config"


def test_tree_escalation_through_router(built, tree):
    """Escalation doubles BOTH the cut (top_t) and the reachable set
    (t_route); a selective filter served through a tree router must
    escalate to valid, subset-respecting results."""
    r2, t2 = tree.escalated(4)
    assert t2 == 8
    assert r2.t_route == min(2 * tree.eff_t_route, tree.n_super)
    assert tree.can_escalate(tree.n_partitions) is True  # t_route headroom
    full = tree.with_t_route(tree.n_super)
    assert full.can_escalate(full.n_partitions) is False
    ds, idx, _ = built
    fm = np.zeros(N, bool)
    fm[::11] = True
    ids, stats = search_numpy(idx, ds.Q, top_t=2, final_k=FINAL_K,
                              rerank_budget=64, filter_mask=fm, router=tree)
    got = ids[ids >= 0]
    assert got.size and fm[got].all()
    assert stats.unique_candidates.min() >= min(64, int(fm.sum()))


# ------------------------------------------------- lifecycle / serialization
def test_router_rides_build_pack_and_snapshots():
    ds = make_manifold(jax.random.PRNGKey(3), n=2_000, d=16, nq=5,
                       intrinsic_dim=4)
    idx = build_ivf(jax.random.PRNGKey(4), ds.X, 16, spill_mode="soar",
                    train_iters=3, router="tree",
                    router_kw=dict(n_super=4, t_route=2))
    assert isinstance(idx.router, TreeRouter)
    assert pack_ivf(idx).router is not None
    m = MutableIVF.from_index(idx)
    assert m.router is idx.router
    assert isinstance(m.pack().router, TreeRouter)
    assert isinstance(m.to_ivf_index().router, TreeRouter)
    # frozen-router rebuild: the instance passes through untouched
    rb = m.rebuild_reference(jax.random.PRNGKey(5))
    assert rb.router is m.router
    # both engines serve through the packed router with no explicit arg
    jids, _ = search_jit(m.pack(), jnp.asarray(ds.Q), top_t=4, final_k=5,
                         rerank_budget=0)
    nids, _ = search_numpy(m.to_ivf_index(), ds.Q, top_t=4, final_k=5)
    assert (np.asarray(jids) >= 0).any() and (nids >= 0).any()


def test_mutable_prunes_emptied_partitions_from_serving_router():
    ds = make_manifold(jax.random.PRNGKey(6), n=1_500, d=16, nq=3,
                       intrinsic_dim=4)
    idx = build_ivf(jax.random.PRNGKey(7), ds.X, 12, spill_mode="none",
                    train_iters=3, router="tree",
                    router_kw=dict(n_super=3, t_route=3))
    m = MutableIVF.from_index(idx)
    p = int(np.argmax(np.diff(idx.starts)))       # a populated partition
    victims = idx.point_ids[idx.starts[p]:idx.starts[p + 1]]
    m.remove(victims, hard=True)
    rt = m.pack().router
    assert p not in np.asarray(rt.children), \
        "emptied partition must prune from the serving router view"
    # repopulating the partition un-prunes it on the next snapshot
    centroid = idx.centroids[p]
    m.add(np.tile(centroid, (4, 1)))
    rt2 = m.pack().router
    assert p in np.asarray(rt2.children)
    # the frozen trained tables were never touched
    assert p in np.asarray(m.router.children)


# ------------------------------------------------------------- spec resolver
def test_as_router_specs(built):
    _, idx, _ = built
    assert as_router(None, idx.centroids) is None
    assert isinstance(as_router("flat", idx.centroids), FlatRouter)
    rt = as_router("tree", idx.centroids, key=jax.random.PRNGKey(0),
                   n_super=4)
    assert isinstance(rt, TreeRouter) and rt.n_partitions == C
    assert as_router(rt, idx.centroids) is rt
    with pytest.raises(ValueError, match="unknown router"):
        as_router("graph", idx.centroids)


def test_knn_memory_with_tree_router(built):
    ds, _, _ = built
    from repro.serve.knn_memory import KNNMemory
    mem = KNNMemory.build(ds.X[:2_000], ds.X[:2_000], n_partitions=16,
                          router="tree", router_kw=dict(n_super=4,
                                                        t_route=2))
    ids, K, V = mem.retrieve(ds.Q, k=8, top_t=4)
    assert ids.shape == (NQ, 8) and (ids >= 0).any()
    mem.engine = "jit"
    jids, _, _ = mem.retrieve(ds.Q, k=8, top_t=4)
    assert jids.shape == (NQ, 8) and (jids >= 0).any()
