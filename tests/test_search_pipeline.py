"""Candidate-local search pipeline regression tests (ISSUE 2 tentpole).

Pins two properties the rewrite must preserve forever:

1. Cross-engine agreement: `search_jit` and `search_numpy` return IDENTICAL
   top-k ids/scores on a spilled index (duplicates guaranteed by SOAR's
   2-way assignment), fixing the dedup-by-max semantics.

2. Candidate-locality: no per-query intermediate in the jit pipeline is
   O(n) — asserted structurally on the jaxpr (no (n,)- or (nq, n)-shaped
   equation outputs, i.e. no dense scatter buffer and no full-database
   top_k).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_ivf, search_numpy, search_jit, pack_ivf
from repro.core.search import dedup_topk_window, search_jit_batched
from repro.data.vectors import make_manifold

N, D, NQ = 8_000, 32, 37
TOP_T, FINAL_K = 12, 10


@pytest.fixture(scope="module")
def spilled():
    ds = make_manifold(jax.random.PRNGKey(0), n=N, d=D, nq=NQ,
                       intrinsic_dim=8)
    idx = build_ivf(jax.random.PRNGKey(1), ds.X, 32, spill_mode="soar",
                    pq_subspaces=8, train_iters=5)
    return ds, idx, pack_ivf(idx)


def test_spill_guarantees_duplicates(spilled):
    """Precondition for the dedup test to be meaningful: every point sits in
    two partitions, so probed windows DO contain duplicate ids."""
    ds, idx, packed = spilled
    counts = np.bincount(idx.point_ids, minlength=idx.n_points)
    assert np.all(counts == 2)


def test_engines_identical_ids_and_scores(spilled):
    """With a budget covering the whole window, both engines reduce to
    exact-rerank of the deduped candidate set → identical output."""
    ds, idx, packed = spilled
    window = TOP_T * packed.part_ids.shape[1]
    jids, jvals = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                             final_k=FINAL_K, rerank_budget=window)
    nids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=window)
    jids, jvals = np.asarray(jids), np.asarray(jvals)
    assert np.array_equal(jids, nids), (
        f"engines disagree on {np.mean(jids != nids):.1%} of slots")
    # scores must be the exact inner products of the returned ids
    expect = np.einsum("qkd,qd->qk", ds.X[jids], ds.Q.astype(np.float32))
    np.testing.assert_allclose(jvals, expect, rtol=1e-5, atol=1e-5)


def test_engines_agree_under_budget_truncation(spilled):
    """A tight budget exercises the approx-ordered truncation in both
    engines; ids may legitimately differ on approx-score ties, so compare
    recall of the sets rather than slot-exact ids."""
    ds, idx, packed = spilled
    jids, _ = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                         final_k=FINAL_K, rerank_budget=128)
    nids, _ = search_numpy(idx, ds.Q, top_t=TOP_T, final_k=FINAL_K,
                           rerank_budget=128)
    jids = np.asarray(jids)
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / FINAL_K
        for a, b in zip(jids, nids)])
    assert overlap > 0.97, overlap


def test_batched_driver_matches_flat(spilled):
    ds, idx, packed = spilled
    flat = search_jit(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                      final_k=FINAL_K, rerank_budget=256)
    tiled = search_jit_batched(packed, jnp.asarray(ds.Q), top_t=TOP_T,
                               final_k=FINAL_K, rerank_budget=256, bq=8)
    assert np.array_equal(np.asarray(flat[0]), np.asarray(tiled[0]))
    np.testing.assert_allclose(np.asarray(flat[1]), np.asarray(tiled[1]))


def test_dedup_topk_window_keeps_max_per_id():
    ids = jnp.asarray([[3, 1, 3, -1, 1, 7]])
    scores = jnp.asarray([[1.0, 5.0, 4.0, 99.0, 2.0, 0.5]])
    out_ids, out_scores = dedup_topk_window(ids, scores, 3)
    assert out_ids.tolist() == [[1, 3, 7]]
    assert out_scores.tolist() == [[5.0, 4.0, 0.5]]


# the recursive walker lives on the shared static-analysis layer now
# (repro/analysis/jaxpr_walk.py, DESIGN.md §3.14) — the assertion below is
# unchanged, and the same invariant is also contract-checked repo-wide by
# `python -m repro.analysis.check`
from repro.analysis import jaxpr_shapes as _jaxpr_shapes  # noqa: E402


def test_no_database_sized_intermediates(spilled):
    """ISSUE 2 acceptance: no (n,)- or (nq, n)-shaped buffer anywhere in the
    traced pipeline — the dense scatter-max dedup and full-database top_k of
    the seed implementation must never come back."""
    ds, idx, packed = spilled
    n = idx.n_points
    closed = jax.make_jaxpr(
        lambda p, q: search_jit(p, q, top_t=TOP_T, final_k=FINAL_K,
                                rerank_budget=256))(packed,
                                                    jnp.asarray(ds.Q))
    bad = [s for s in _jaxpr_shapes(closed.jaxpr)
           if s == (n,) or (len(s) == 2 and s[1] == n)]
    assert not bad, f"database-sized intermediates in search_jit: {bad}"


def test_no_database_sized_intermediates_hlo(spilled):
    """Belt-and-braces: the lowered HLO text contains no 1-D f32[n] buffer
    (the seed's dense dedup allocated exactly that per query)."""
    ds, idx, packed = spilled
    n = idx.n_points
    hlo = jax.jit(
        lambda p, q: search_jit(p, q, top_t=TOP_T, final_k=FINAL_K,
                                rerank_budget=256)
    ).lower(packed, jnp.asarray(ds.Q)).as_text()
    assert f"f32[{n}]" not in hlo
    assert f"f32[{NQ},{n}]" not in hlo
