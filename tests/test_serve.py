import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import ServeEngine, make_serve_step


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("granite-3-2b").smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, ServeEngine(cfg, params, max_seq=64)


def test_generate_shapes_and_determinism(engine):
    cfg, eng = engine
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    out1 = eng.generate({"tokens": toks}, n_new=8)
    out2 = eng.generate({"tokens": toks}, n_new=8)
    assert out1.shape == (2, 8)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    assert int(jnp.max(out1)) < cfg.vocab_padded


def test_generate_matches_stepwise_forward(engine):
    """Greedy engine output == argmax over repeated full forwards."""
    cfg, eng = engine
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0,
                              cfg.vocab_size)
    out = np.asarray(eng.generate({"tokens": toks}, n_new=4))
    cur = np.asarray(toks)
    for i in range(4):
        x, _ = T.forward(eng.params, {"tokens": jnp.asarray(cur)}, cfg)
        logits = T.logits_from_hidden(eng.params, x[:, -1:, :], cfg)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == out[0, i], f"step {i}: {nxt} vs {out[0, i]}"
        cur = np.concatenate([cur, [[nxt]]], axis=1)


def test_serve_step_moe_arch():
    cfg = get_config("qwen3-moe-30b-a3b").smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), T.cache_defs(cfg, 2, 32))
    step = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((2, 1), jnp.int32)
    nxt, caches = step(params, tok, caches, jnp.asarray(0, jnp.int32))
    assert nxt.shape == (2, 1)
    nxt, _ = step(params, nxt, caches, jnp.asarray(1, jnp.int32))
    assert np.all(np.asarray(nxt) >= 0)
