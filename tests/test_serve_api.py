"""Unified serving request API (ISSUE 8 satellites, DESIGN.md §3.12).

Pins:

1. Shim parity: the legacy kwarg signatures (`AnnEngine.search`,
   `KNNMemory.retrieve`) are thin shims over SearchParams routing —
   results are BITWISE identical to calling the structured entry points
   directly, on both engines.
2. Shared validation: k=0 / top_t=0 / bool / NaN queries raise the same
   errors through every edge (one hardened path, SearchParams.validate);
   sanitize=True zeroes non-finite queries instead.
3. Default unification: KNNMemory's probe budget defaults to the same
   DEFAULT_TOP_T as AnnEngine (it historically hardcoded top_t=4 against
   the engine's 8), and the default round-trips through snapshots.
4. Distributed plumbing: the search makers accept a SearchParams and
   produce the same fn as the equivalent kwargs; replica fan-out on one
   device is bitwise the local pipeline.
5. Snapshot extras: caller-owned arrays ride a snapshot under `extra.`
   names and load back exactly (the front-end's tenant-bitmap channel).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mutable import MutableIVF
from repro.core.search import pad_queries, search_jit_batched
from repro.data.vectors import make_manifold
from repro.serve.api import (DEFAULT_TOP_T, SearchParams, SearchResult)
from repro.serve.engine import AnnEngine
from repro.serve.knn_memory import KNNMemory

N, D, NQ = 3_000, 24, 16


@pytest.fixture(scope="module")
def ds():
    return make_manifold(jax.random.PRNGKey(0), n=N, d=D, nq=NQ,
                         intrinsic_dim=8)


@pytest.fixture(scope="module")
def engine(ds):
    return AnnEngine.build(jax.random.PRNGKey(1), ds.X, 16,
                           spill_mode="soar", train_iters=5)


@pytest.fixture(scope="module", params=["numpy", "jit"])
def memory(request, ds):
    rng = np.random.default_rng(0)
    V = rng.normal(size=(N, D)).astype(np.float32)
    return KNNMemory.build(ds.X, V, n_partitions=16, engine=request.param)


# ------------------------------------------------------------- shim parity
def test_engine_shim_parity(ds, engine):
    """search(kwargs) ≡ search_request(SearchParams) — bitwise."""
    ids_a, sc_a = engine.search(ds.Q, k=7, top_t=6, escalate=False)
    r = engine.search_request(ds.Q, SearchParams(k=7, top_t=6,
                                                 escalate=False))
    assert np.array_equal(ids_a, r.ids)
    assert np.array_equal(sc_a, r.scores)
    # structured result also unpacks like the legacy tuple
    ids_b, sc_b = r
    assert ids_b is r.ids and sc_b is r.scores
    assert r.batch_size == NQ and r.epoch == engine.index._alive_epoch


def test_engine_shim_parity_filtered(ds, engine):
    mask = np.zeros(N, np.uint8)
    mask[: N // 3] = 1
    ids_a, sc_a = engine.search(ds.Q, k=5, filter_mask=mask)
    r = engine.search_request(ds.Q, SearchParams(k=5, filter_mask=mask))
    assert np.array_equal(ids_a, r.ids)
    assert np.array_equal(sc_a, r.scores)
    assert (r.ids < N // 3).all()


def test_memory_shim_parity(memory):
    rng = np.random.default_rng(3)
    q = rng.normal(size=(5, D)).astype(np.float32)
    ids_a, K_a, V_a = memory.retrieve(q, k=9, top_t=5, recency=1000)
    r, K_b, V_b = memory.retrieve_request(
        q, SearchParams(k=9, top_t=5, recency=1000))
    assert np.array_equal(ids_a, r.ids)
    assert np.array_equal(K_a, K_b) and np.array_equal(V_a, V_b)


# -------------------------------------------------------- shared validation
def test_validation_is_shared(ds, engine, memory):
    q = ds.Q[:2]
    for call in (lambda **kw: engine.search(q, **kw),
                 lambda **kw: memory.retrieve(q, **kw)):
        with pytest.raises(ValueError):
            call(k=0)
        with pytest.raises(ValueError):
            call(top_t=0)          # explicit 0 raises, never falls back
        with pytest.raises(ValueError):
            call(k=True)
    bad = q.copy()
    bad[0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        engine.search(bad, k=3)
    with pytest.raises(ValueError, match="non-finite"):
        memory.retrieve(bad, k=3)
    # sanitize zeroes instead — equivalent to searching the zeroed batch
    fixed = bad.copy()
    fixed[0, 0] = 0.0
    r = engine.search_request(bad, SearchParams(k=3, sanitize=True))
    ids_ref, _ = engine.search(fixed, k=3)
    assert np.array_equal(r.ids, ids_ref)


def test_params_validate_bounds():
    with pytest.raises(ValueError, match="deadline_ms"):
        SearchParams(deadline_ms=0).validate()
    with pytest.raises(ValueError, match="deadline_ms"):
        SearchParams(deadline_ms=float("nan")).validate()
    with pytest.raises(ValueError, match="recency"):
        SearchParams(recency=-1).validate()
    p = SearchParams(top_t=None).validate(default_top_t=11)
    assert p.top_t == 11 and p.k == 10
    # frozen: validate returns a resolved copy, original untouched
    p0 = SearchParams()
    p0.validate(default_top_t=5)
    assert p0.top_t is None


def test_batch_key_semantics():
    assert (SearchParams(k=5, tenant="a").validate(default_top_t=8)
            .batch_key() == (5, 8, None, True, "a"))
    # ad-hoc inline filters never coalesce
    assert SearchParams(filter_mask=np.ones(4)).batch_key() is None
    assert SearchParams(filter_ids=[1]).batch_key() is None
    assert SearchParams(recency=10).batch_key() is None
    assert SearchParams(segment=0).batch_key() is None


# ------------------------------------------------------ default unification
def test_default_top_t_unified(memory):
    """KNNMemory's default probe budget is THE serving default — the same
    constant AnnEngine uses — not a private hardcoded 4."""
    assert memory.top_t == DEFAULT_TOP_T
    assert AnnEngine(memory.index).top_t == DEFAULT_TOP_T
    rng = np.random.default_rng(4)
    q = rng.normal(size=(4, D)).astype(np.float32)
    ids_default, _, _ = memory.retrieve(q, k=6)
    ids_explicit, _, _ = memory.retrieve(q, k=6, top_t=DEFAULT_TOP_T)
    assert np.array_equal(ids_default, ids_explicit)


def test_memory_top_t_round_trips(tmp_path, ds):
    rng = np.random.default_rng(5)
    V = rng.normal(size=(N, D)).astype(np.float32)
    mem = KNNMemory.build(ds.X, V, n_partitions=16, engine="numpy")
    mem.top_t = 13
    mem.save(str(tmp_path / "mem"))
    back = KNNMemory.open(str(tmp_path / "mem"))
    assert back.top_t == 13
    q = rng.normal(size=(3, D)).astype(np.float32)
    a, _, _ = mem.retrieve(q, k=5)
    b, _, _ = back.retrieve(q, k=5)
    assert np.array_equal(a, b)


# ----------------------------------------------------- distributed plumbing
def test_distributed_makers_take_params(ds, engine):
    from repro.core.distributed import make_replicated_search
    mesh = jax.make_mesh((1,), ("r",))
    packed = engine.index.pack()
    mult = 1 + max(engine.index.n_spills, 1)
    kw = dict(final_k=6, rerank_budget=128, multiplicity=mult)
    f_kwargs = make_replicated_search(mesh, ("r",), top_t=5, **kw)
    f_params = make_replicated_search(
        mesh, ("r",), top_t=99,  # overridden by params
        params=SearchParams(k=6, top_t=5, rerank_budget=128), **kw)
    Qp, nq, bq = pad_queries(ds.Q, 128)
    ref = search_jit_batched(packed, jnp.asarray(Qp), top_t=5, final_k=6,
                             rerank_budget=128, bq=bq, multiplicity=mult)
    for f in (f_kwargs, f_params):
        ids, sc = jax.jit(f)(packed, jnp.asarray(Qp))
        # one-replica fan-out IS the local pipeline, bitwise
        assert np.array_equal(np.asarray(ids)[:nq], np.asarray(ref[0])[:nq])
        assert np.array_equal(np.asarray(sc)[:nq], np.asarray(ref[1])[:nq])


def test_shard_parallel_maker_takes_params(ds):
    from repro.core.distributed import build_sharded_ivf, \
        make_distributed_search
    mesh = jax.make_mesh((1,), ("data",))
    sharded = build_sharded_ivf(jax.random.PRNGKey(2), ds.X, n_shards=1,
                                n_partitions=16, train_iters=4)
    f_kw = make_distributed_search(mesh, ("data",), top_t=6, final_k=5)
    f_p = make_distributed_search(mesh, ("data",), top_t=1,
                                  params=SearchParams(k=5, top_t=6))
    ids_a, _ = jax.jit(f_kw)(sharded, jnp.asarray(ds.Q))
    ids_b, _ = jax.jit(f_p)(sharded, jnp.asarray(ds.Q))
    assert np.array_equal(np.asarray(ids_a), np.asarray(ids_b))


# --------------------------------------------------------- snapshot extras
def test_extra_arrays_round_trip(tmp_path, ds):
    from repro.ckpt.index_store import (load_extra_arrays, load_snapshot,
                                        save_snapshot)
    idx = MutableIVF.build(jax.random.PRNGKey(3), ds.X[:500], 8,
                           train_iters=3)
    extras = {"tenant.acme": (np.arange(500) % 3 == 0).astype(np.uint8),
              "tenant.b": np.ones(500, np.uint8)}
    save_snapshot(str(tmp_path / "s"), idx, extra={"frontend": {"x": 1}},
                  extra_arrays=extras)
    back = load_extra_arrays(str(tmp_path / "s"))
    assert sorted(back) == sorted(extras)
    for k in extras:
        assert np.array_equal(back[k], extras[k])
    # extras are invisible to the normal object load path
    obj, extra = load_snapshot(str(tmp_path / "s"),
                               expect_kind="MutableIVF")
    assert extra["frontend"] == {"x": 1}
    assert obj.n_total == 500


def test_extra_arrays_persist_through_engine_save(tmp_path, ds):
    """The AnnEngine.save seam the front-end rides: extras land in the
    SAME atomic snapshot and reload from it."""
    from repro.ckpt.index_store import load_extra_arrays
    eng = AnnEngine.build(jax.random.PRNGKey(4), ds.X[:500], 8,
                          train_iters=3)
    bm = (np.arange(500) % 2).astype(np.uint8)
    eng.save(str(tmp_path / "e"), extra={"frontend": {"max_batch": 32}},
             extra_arrays={"tenant.t0": bm})
    back = load_extra_arrays(str(tmp_path / "e" / "index"))
    assert np.array_equal(back["tenant.t0"], bm)
    reopened = AnnEngine.open(str(tmp_path / "e"))
    assert reopened.index.n_total == 500


def test_search_result_metadata(ds, engine):
    r = engine.search_request(ds.Q[:3],
                              SearchParams(k=4, deadline_ms=1000.0))
    assert isinstance(r, SearchResult)
    assert r.nq == 3 and r.k == 4
    assert r.engine_us > 0 and r.queued_us == 0.0
    assert r.deadline_met() is True
    assert r.total_us == r.engine_us
    r0 = engine.search_request(np.empty((0, D), np.float32))
    assert r0.nq == 0 and r0.ids.shape == (0, 10)
