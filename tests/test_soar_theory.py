"""Validation of the paper's theory: Theorem 3.1, Lemma 3.2, corollaries.

These are the strongest correctness checks available for the SOAR loss: the
closed form must match a Monte-Carlo evaluation of the defining expectation
E_q[w(cos θ) <q, r'>^2] over hypersphere-uniform queries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.soar import (soar_assign, soar_assign_multi,
                             naive_spill_assign, soar_loss_values)
from repro.core.kmeans import assign_euclidean


def _uniform_sphere(key, n, d):
    q = jax.random.normal(key, (n, d))
    return q / jnp.linalg.norm(q, axis=-1, keepdims=True)


@pytest.mark.parametrize("lam", [0.0, 1.0, 2.0, 4.0])
def test_theorem_3_1_closed_form(lam):
    """MC estimate of E[|cosθ|^λ <q,r'>^2] ∝ ||r'||^2 + λ||proj_r r'||^2."""
    d = 8
    key = jax.random.PRNGKey(0)
    kq, kr, kp = jax.random.split(key, 3)
    r = jax.random.normal(kr, (d,))
    rhat = r / jnp.linalg.norm(r)
    q = _uniform_sphere(kq, 400_000, d)
    cos = q @ rhat
    w = jnp.abs(cos) ** lam
    rps = jax.random.normal(kp, (12, d))                     # candidate r' set
    mc = jnp.mean(w[:, None] * (q @ rps.T) ** 2, axis=0)     # (12,)
    closed = (jnp.sum(rps * rps, -1) + lam * (rps @ rhat) ** 2)
    ratio = np.asarray(mc / closed)
    # proportionality: all ratios equal (up to MC noise)
    assert ratio.std() / ratio.mean() < 0.02, ratio


def test_lemma_3_2_projection_is_scaled_correlation():
    """||proj_r r'|| == ||r'|| * rho(<q,r>, <q,r'>) over hypersphere q."""
    d = 16
    k1, k2, kq = jax.random.split(jax.random.PRNGKey(1), 3)
    r = jax.random.normal(k1, (d,))
    rp = jax.random.normal(k2, (d,))
    q = _uniform_sphere(kq, 400_000, d)
    a, b = q @ r, q @ rp
    rho = np.corrcoef(np.asarray(a), np.asarray(b))[0, 1]
    proj = float(jnp.abs(jnp.dot(r, rp)) / jnp.linalg.norm(r))
    got = abs(rho) * float(jnp.linalg.norm(rp))
    assert abs(got - proj) / proj < 0.02, (got, proj)


def test_corollary_3_1_1_lam0_equals_euclidean():
    """λ=0 → standard (second-closest) Euclidean assignment."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    X = jax.random.normal(k1, (500, 24))
    C = jax.random.normal(k2, (64, 24))
    prim = assign_euclidean(X, C)
    s0 = soar_assign(X, C, prim, lam=0.0)
    nv = naive_spill_assign(X, C, prim)
    assert np.array_equal(np.asarray(s0), np.asarray(nv))
    # and it is indeed the 2nd closest centroid
    d2 = jnp.sum((X[:, None] - C[None]) ** 2, -1)
    d2 = jnp.where(jax.nn.one_hot(prim, 64, dtype=bool), jnp.inf, d2)
    assert np.array_equal(np.asarray(s0), np.asarray(jnp.argmin(d2, -1)))


def test_soar_assign_is_argmin_of_loss():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    X = jax.random.normal(k1, (200, 16))
    C = jax.random.normal(k2, (40, 16))
    prim = assign_euclidean(X, C)
    sec = soar_assign(X, C, prim, lam=1.5)
    # brute force: loss at every candidate
    losses = jnp.stack([soar_loss_values(X, C, prim,
                                         jnp.full((200,), j, jnp.int32), lam=1.5)
                        for j in range(40)], axis=1)
    losses = jnp.where(jax.nn.one_hot(prim, 40, dtype=bool), jnp.inf, losses)
    best = jnp.min(losses, axis=1)
    chosen = soar_loss_values(X, C, prim, sec, lam=1.5)
    np.testing.assert_allclose(np.asarray(chosen), np.asarray(best),
                               rtol=1e-5, atol=1e-5)


def test_orthogonality_amplification():
    """Corollary 3.1.2 in action: SOAR residual pairs are closer to
    orthogonal than naive-spill residual pairs."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    X = jax.random.normal(k1, (2000, 32))
    X = X / jnp.linalg.norm(X, axis=-1, keepdims=True)
    C = jax.random.normal(k2, (100, 32)) * 0.3
    prim = assign_euclidean(X, C)

    def mean_abs_cos(sec):
        r = X - C[prim]
        rp = X - C[sec]
        cos = (jnp.sum(r * rp, -1)
               / jnp.maximum(jnp.linalg.norm(r, -1) * jnp.linalg.norm(rp, -1), 1e-9))
        return float(jnp.mean(jnp.abs(cos)))

    soar_cos = mean_abs_cos(soar_assign(X, C, prim, lam=2.0))
    naive_cos = mean_abs_cos(naive_spill_assign(X, C, prim))
    assert soar_cos < naive_cos


def test_multi_spill_distinct_assignments():
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    X = jax.random.normal(k1, (300, 16))
    C = jax.random.normal(k2, (50, 16))
    prim = assign_euclidean(X, C)
    A = np.asarray(soar_assign_multi(X, C, prim, lam=1.0, n_spills=3))
    assert A.shape == (300, 4)
    assert np.array_equal(A[:, 0], np.asarray(prim))
    for i in range(300):
        assert len(set(A[i])) == 4, f"duplicate assignment row {i}: {A[i]}"


def test_multi_spill_lam0_agrees_with_naive_and_topk():
    """§3.5.1 pins: at λ=0 the multi-spill chain degenerates to plain
    k-nearest-centroid spilling — column 1 must equal `naive_spill_assign`
    and columns 0..k must enumerate the (k+1) closest centroids in order."""
    from repro.core.kmeans import assign_euclidean_topk

    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    X = jax.random.normal(k1, (400, 24))
    C = jax.random.normal(k2, (64, 24))
    prim = assign_euclidean(X, C)
    A = np.asarray(soar_assign_multi(X, C, prim, lam=0.0, n_spills=3))
    nv = np.asarray(naive_spill_assign(X, C, prim))
    assert np.array_equal(A[:, 1], nv)
    topk = np.asarray(assign_euclidean_topk(X, C, k=4))
    assert np.array_equal(A, topk)


@pytest.mark.parametrize("lam", [0.5, 1.5])
def test_multi_spill_loss_monotone(lam):
    """At λ>0 successive spills have non-decreasing loss: step k+1
    minimizes a pointwise-larger objective (one more orthogonality
    penalty term) over a strictly smaller feasible set, so the chosen
    minima must be ordered. Verified against brute-force objectives."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    n, c, d = 300, 48, 16
    X = jax.random.normal(k1, (n, d))
    C = jax.random.normal(k2, (c, d))
    prim = assign_euclidean(X, C)
    A = np.asarray(soar_assign_multi(X, C, prim, lam=lam, n_spills=3))
    rp_all = np.asarray(X)[:, None, :] - np.asarray(C)[None, :, :]
    chosen_losses = []
    pen = np.zeros((n, c))
    for k in range(1, 4):
        r = np.asarray(X) - np.asarray(C)[A[:, k - 1]]
        rhat = r / np.maximum(np.linalg.norm(r, axis=-1, keepdims=True),
                              1e-12)
        pen = pen + np.einsum("nd,ncd->nc", rhat, rp_all) ** 2
        loss_k = np.sum(rp_all * rp_all, -1) + lam * pen
        used = (A[:, :k, None] == np.arange(c)[None, None, :]).any(axis=1)
        masked = np.where(used, np.inf, loss_k)
        # the chain picks the argmin of objective k over unused centroids
        chosen = masked[np.arange(n), A[:, k]]
        np.testing.assert_allclose(chosen, masked.min(axis=1),
                                   rtol=1e-4, atol=1e-4)
        chosen_losses.append(chosen)
    L = np.stack(chosen_losses, axis=1)            # (n, 3)
    assert np.all(L[:, 1] >= L[:, 0] - 1e-4)
    assert np.all(L[:, 2] >= L[:, 1] - 1e-4)


def test_lambda_monotonicity():
    """Figure 9: higher λ → higher spilled distortion E||r'||^2, lower
    parallel component."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(6))
    X = jax.random.normal(k1, (3000, 32))
    X = X / jnp.linalg.norm(X, axis=-1, keepdims=True)
    C = jax.random.normal(k2, (128, 32)) * 0.3
    prim = assign_euclidean(X, C)
    r = X - C[prim]
    rhat = r / jnp.linalg.norm(r, -1, keepdims=True)
    dist, par = [], []
    for lam in (0.0, 1.0, 4.0):
        sec = soar_assign(X, C, prim, lam=lam)
        rp = X - C[sec]
        dist.append(float(jnp.mean(jnp.sum(rp * rp, -1))))
        par.append(float(jnp.mean(jnp.sum(rhat * rp, -1) ** 2)))
    assert dist[0] <= dist[1] <= dist[2]
    assert par[0] >= par[1] >= par[2]
