"""End-to-end behaviour tests: the paper's headline claims on the benchmark
dataset, through the full public API (build → KMR → search)."""
import jax
import numpy as np
import pytest

from repro.core import (build_ivf, kmr_curve, points_to_recall, search_numpy,
                        true_neighbors)
from repro.core.analysis import angle_correlation, pair_stats, pearson
from repro.data.vectors import glove_like


@pytest.fixture(scope="module")
def world():
    ds = glove_like(n=100_000, d=100, nq=300, seed=3)
    tn = true_neighbors(ds.X, ds.Q, k=100)
    idx = {m: build_ivf(jax.random.PRNGKey(1), ds.X, 500, spill_mode=m,
                        lam=1.0, train_iters=8, pq_subspaces=25)
           for m in ("none", "naive", "soar")}
    return ds, tn, idx


def test_headline_soar_beats_naive_everywhere(world):
    """SOAR dominates naive spilling at every recall target (Table 2)."""
    ds, tn, idx = world
    c_soar = kmr_curve(idx["soar"], ds.Q, tn, k=100)
    c_naive = kmr_curve(idx["naive"], ds.Q, tn, k=100)
    for t in (0.8, 0.85, 0.9, 0.95):
        assert points_to_recall(c_soar, t) < points_to_recall(c_naive, t), t


def test_headline_soar_beats_no_spill_at_high_recall(world):
    """At this scale the paper's Glove-1M regime: SOAR reads fewer points
    than a non-spilled index, with the gain GROWING with the target."""
    ds, tn, idx = world
    c_soar = kmr_curve(idx["soar"], ds.Q, tn, k=100)
    c_none = kmr_curve(idx["none"], ds.Q, tn, k=100)
    gains = [points_to_recall(c_none, t) / points_to_recall(c_soar, t)
             for t in (0.85, 0.95)]
    assert gains[0] > 1.0, gains
    assert gains[1] > gains[0] * 0.98, gains   # non-decreasing (tolerance)


def test_mechanism_angle_decorrelation(world):
    """Fig 4 vs 7: SOAR reduces cos-angle correlation vs naive spilling."""
    ds, tn, idx = world
    st_naive = pair_stats(ds.X, idx["naive"].centroids,
                          idx["naive"].assignments, ds.Q, tn)
    st_soar = pair_stats(ds.X, idx["soar"].centroids,
                         idx["soar"].assignments, ds.Q, tn)
    assert angle_correlation(st_soar) < angle_correlation(st_naive) - 0.05


def test_mechanism_cos_dominates_qr(world):
    """Fig 2: cos(theta) explains <q,r> far better than ||r||."""
    ds, tn, idx = world
    st = pair_stats(ds.X, idx["soar"].centroids, idx["soar"].assignments,
                    ds.Q, tn)
    assert pearson(st.qr, st.cos1) > pearson(st.qr, st.rnorm) + 0.3


def test_end_to_end_search_quality(world):
    """Full pipeline (centroids → PQ → dedup → rerank) reaches high recall
    reading a small fraction of the database."""
    ds, tn, idx = world
    ids, stats = search_numpy(idx["soar"], ds.Q, top_t=25, final_k=10,
                              rerank_budget=400)
    recall = (ids[:, :, None] == tn[:, None, :10]).any(-1).mean()
    assert recall > 0.9, recall
    assert stats.points_read.mean() < 0.12 * idx["soar"].n_assignments


def test_memory_overhead_within_paper_bounds(world):
    """Table 1: SOAR's relative growth is small (<= ~20% for int8)."""
    _, _, idx = world
    g_f32 = (idx["soar"].memory_bytes("f32")["total"]
             / idx["none"].memory_bytes("f32")["total"] - 1)
    g_int8 = (idx["soar"].memory_bytes("int8")["total"]
              / idx["none"].memory_bytes("int8")["total"] - 1)
    assert 0 < g_f32 < 0.10
    assert 0 < g_int8 < 0.25
