import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import for_model
from repro.models import transformer as T
from repro.train import optimizer as opt
from repro.train.train_loop import make_train_step, train


def _tiny_cfg():
    return get_config("granite-3-2b").smoke_config().replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64)


def test_loss_decreases():
    cfg = _tiny_cfg()
    pipe = for_model(cfg, seq_len=32, global_batch=8, mode="markov")
    params, _, losses = train(cfg, pipe, steps=30, lr=3e-3, log_every=1000)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_grad_accum_matches_full_batch():
    cfg = _tiny_cfg()
    pipe = for_model(cfg, seq_len=16, global_batch=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    lr_fn = opt.warmup_cosine(1e-3, 5, 100)
    batch = pipe.batch_at(0)

    s1 = make_train_step(cfg, lr_fn, accum=1)
    s4 = make_train_step(cfg, lr_fn, accum=4)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p4, _, m4 = jax.jit(s4)(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        # bf16 accumulation noise through Adam's rsqrt on near-zero second
        # moments: tolerate ~1 ulp-of-update absolute difference
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=6e-3, atol=5e-4)


def test_resume_from_checkpoint(tmp_path):
    cfg = _tiny_cfg()
    pipe = for_model(cfg, seq_len=16, global_batch=4)
    m = CheckpointManager(str(tmp_path))
    train(cfg, pipe, steps=6, ckpt_manager=m, ckpt_every=3, log_every=1000)
    assert m.latest_step() == 6
    # resuming continues from saved step without error
    params, _, losses = train(cfg, pipe, steps=8, ckpt_manager=m,
                              ckpt_every=100, log_every=1000)
    assert len(losses) == 2   # only steps 6,7 run


def test_optimizer_clipping():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    st = opt.init(params)
    _, _, metrics = opt.update(grads, st, params,
                               lambda s: jnp.asarray(1e-3), clip_norm=1.0)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip
